"""Unified language-model substrate.

A model is a stack of ``LayerSpec`` entries: an optional unscanned
``prologue`` (e.g. deepseek's first dense layer) followed by a
``superblock`` scanned ``n_repeat`` times (keeps HLO/compile time small and
gives remat a natural boundary).  Layer kinds:

  attn   — GQA self-attention (sliding window / softcap options)
  mla    — DeepSeek multi-head latent attention
  mamba2 — Mamba2 SSD block (zamba2)
  rwkv6  — RWKV6 time-mix + channel-mix
  xattn  — gated cross-attention to precomputed embeddings (llama-vision)
  dec    — self-attn + cross-attn + MLP (whisper decoder layer)
  shared_attn — attention whose *weights live outside the scan* and are
           shared across all applications (zamba2's shared block); its
           input is concat(hidden, initial embeddings), as in zamba2.

Decode caches roll: a cache buffer of length L < max_len is written at
``pos % L`` — this is how hybrid archs (zamba2) keep O(window) attention
state at 500k context.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rwkv as RW
from repro.models import ssm as SSM
from repro.parallel.sharding import constrain

ZERO_AUX = {"moe_lb_loss": 0.0, "moe_z_loss": 0.0}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_mlp(key, spec: LayerSpec, cfg: ModelConfig):
    if spec.mlp == "glu":
        return L.init_glu_mlp(key, cfg.d_model, cfg.d_ff, jnp.dtype(cfg.param_dtype))
    if spec.mlp == "gelu_mlp":
        return L.init_gelu_mlp(key, cfg.d_model, cfg.d_ff, jnp.dtype(cfg.param_dtype))
    if spec.mlp == "moe":
        return MOE.init_moe(key, cfg)
    return None


def init_layer(key, spec: LayerSpec, cfg: ModelConfig):
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm1": L.init_rmsnorm(cfg.d_model, dt)}
    if spec.kind == "attn":
        p["attn"] = L.init_attention(k1, cfg)
    elif spec.kind == "mla":
        p["attn"] = L.init_mla(k1, cfg)
    elif spec.kind == "mamba2":
        p["mamba"] = SSM.init_mamba2(k1, cfg)
    elif spec.kind == "rwkv6":
        p["rwkv"] = RW.init_rwkv6(k1, cfg)
    elif spec.kind == "xattn":
        p["attn"] = L.init_attention(k1, cfg)
        p["xgate"] = jnp.zeros((), dt)
    elif spec.kind == "dec":
        p["attn"] = L.init_attention(k1, cfg)
        p["xnorm"] = L.init_rmsnorm(cfg.d_model, dt)
        p["xattn"] = L.init_attention(k3, cfg)
    elif spec.kind == "shared_attn":
        pass  # weights live at top level (shared)
    else:
        raise ValueError(spec.kind)
    if spec.mlp != "none" and spec.kind != "rwkv6":
        p["norm2"] = L.init_rmsnorm(cfg.d_model, dt)
        p["mlp"] = _init_mlp(k2, spec, cfg)
        if spec.mlp == "moe" and cfg.moe_dense_residual:
            p["res_mlp"] = L.init_glu_mlp(jax.random.fold_in(k2, 7),
                                          cfg.d_model, cfg.d_ff, dt)
    if spec.kind == "rwkv6":
        p["norm2"] = L.init_rmsnorm(cfg.d_model, dt)
    if cfg.sandwich_norm:
        p["norm1_post"] = L.init_rmsnorm(cfg.d_model, dt)
        if "norm2" in p:
            p["norm2_post"] = L.init_rmsnorm(cfg.d_model, dt)
    return p


def _padded_vocab(cfg: ModelConfig) -> int:
    """Embedding/lm-head rows padded to a multiple of 256 so odd vocabs
    (granite 49155, whisper 51865) stay shardable over the `model` axis —
    replicating the table replicates its optimizer state too (+4 GB/chip
    measured on granite).  Logits are sliced back to the true vocab."""
    return -(-cfg.vocab_size // 256) * 256


def init_params(key, cfg: ModelConfig):
    cfg.validate()
    dt = jnp.dtype(cfg.param_dtype)
    vpad = _padded_vocab(cfg)
    ks = jax.random.split(key, 8 + len(cfg.prologue))
    p: Dict[str, Any] = {
        "embed": L.embed_init(ks[0], (vpad, cfg.d_model), dt),
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[1], (cfg.d_model, vpad),
                                    cfg.d_model, dt)
    p["prologue"] = [init_layer(ks[8 + i], s, cfg)
                     for i, s in enumerate(cfg.prologue)]
    # stacked superblock params: one stacked tree per spec position
    blocks = []
    for i, spec in enumerate(cfg.superblock):
        keys = jax.random.split(jax.random.fold_in(ks[2], i), cfg.n_repeat)
        blocks.append(jax.vmap(lambda k: init_layer(k, spec, cfg))(keys))
    p["blocks"] = blocks
    if any(s.kind == "shared_attn" for s in cfg.plan):
        sp = {"attn": L.init_attention(ks[3], cfg, d_in=2 * cfg.d_model),
              "norm1": L.init_rmsnorm(2 * cfg.d_model, dt),
              "norm2": L.init_rmsnorm(cfg.d_model, dt),
              "mlp": L.init_glu_mlp(ks[4], cfg.d_model, cfg.d_ff, dt)}
        p["shared_attn"] = sp
    if cfg.n_enc_layers:
        enc_spec = LayerSpec(kind="attn", mlp="gelu_mlp", causal=False)
        keys = jax.random.split(ks[5], cfg.n_enc_layers)
        p["encoder"] = {
            "blocks": jax.vmap(lambda k: init_layer(k, enc_spec, cfg))(keys),
            "final_norm": L.init_rmsnorm(cfg.d_model, dt),
        }
    if cfg.n_img_tokens:
        p["w_img"] = L.dense_init(ks[6], (cfg.d_vision, cfg.d_model),
                                  cfg.d_vision, dt)
    return p


# ---------------------------------------------------------------------------
# single layer application
# ---------------------------------------------------------------------------

def _post(p, name, y, cfg):
    if cfg.sandwich_norm and name in p:
        return L.rmsnorm(p[name], y, cfg.norm_eps)
    return y


def apply_layer(p, spec: LayerSpec, cfg: ModelConfig, x, *, positions,
                x0=None, enc=None, cache=None, cache_pos=None,
                shared_params=None):
    """Returns (x, new_cache, aux)."""
    aux = dict(ZERO_AUX)
    new_cache = cache

    if spec.kind == "shared_attn":
        sp = shared_params
        h = jnp.concatenate([x, x0], axis=-1)
        h = L.rmsnorm(sp["norm1"], h, cfg.norm_eps)
        y, nc = _self_attn(sp["attn"], h, cfg, spec, positions, cache, cache_pos)
        x = x + y
        h2 = L.rmsnorm(sp["norm2"], x, cfg.norm_eps)
        x = x + L.glu_mlp(sp["mlp"], h2.astype(jnp.dtype(cfg.compute_dtype)),
                          jnp.dtype(cfg.compute_dtype)).astype(x.dtype)
        return x, nc, aux

    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec.kind == "attn":
        y, nc = _self_attn(p["attn"], h, cfg, spec, positions, cache, cache_pos)
        x = x + _post(p, "norm1_post", y, cfg)
        new_cache = nc
    elif spec.kind == "mla":
        y, nc = L.mla_attention(p["attn"], h, cfg, spec, positions=positions,
                                cache=cache, cache_pos=cache_pos)
        x = x + _post(p, "norm1_post", y, cfg)
        new_cache = nc
    elif spec.kind == "mamba2":
        y, nc = SSM.mamba2_block(p["mamba"], h, cfg, cache=cache)
        x = x + y
        new_cache = nc if cache is not None else None
    elif spec.kind == "rwkv6":
        tm_cache = None if cache is None else cache
        y, nc = RW.rwkv6_time_mix(p["rwkv"], h, cfg, cache=tm_cache)
        x = x + y
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        cm_cache = None if cache is None else {"shift_c": cache["shift_c"]}
        y2, new_shift = RW.rwkv6_channel_mix(p["rwkv"], h2, cfg, cache=cm_cache)
        x = x + y2
        if cache is not None:
            nc = dict(nc)
            nc["shift_c"] = new_shift.astype(cache["shift_c"].dtype)
            new_cache = nc
        return x, new_cache, aux
    elif spec.kind == "xattn":
        y, nc = _cross_attn(p["attn"], h, cfg, spec, enc, cache)
        x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * y
        new_cache = nc
    elif spec.kind == "dec":
        y, nc_self = _self_attn(p["attn"], h, cfg, spec, positions, cache, cache_pos)
        x = x + y
        hx = L.rmsnorm(p["xnorm"], x, cfg.norm_eps)
        y2, nc_x = _cross_attn(p["xattn"], hx, cfg, spec, enc, cache)
        x = x + y2
        if cache is not None:
            new_cache = {**(nc_self or {}), **(nc_x or {})}

    if spec.mlp != "none":
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        cdt = jnp.dtype(cfg.compute_dtype)
        if spec.mlp == "moe":
            y, aux = MOE.moe_layer(p["mlp"], h2, cfg)
            if cfg.moe_dense_residual:  # arctic: dense MLP parallel to MoE
                y = y + L.glu_mlp(p["res_mlp"], h2.astype(cdt), cdt).astype(y.dtype)
        elif spec.mlp == "glu":
            y = L.glu_mlp(p["mlp"], h2.astype(cdt), cdt).astype(x.dtype)
        else:
            y = L.gelu_mlp(p["mlp"], h2.astype(cdt), cdt).astype(x.dtype)
        x = x + _post(p, "norm2_post", y, cfg)
    return x, new_cache, aux


def _self_attn(pa, h, cfg, spec, positions, cache, cache_pos):
    if cache is None:
        y, _ = L.attention(pa, h, cfg, spec, positions=positions)
        return y, None
    Lbuf = cache["k"].shape[1]
    S = h.shape[1]
    if S == 1:  # decode: rolling write
        write_pos = cache_pos % Lbuf
        kv_len = jnp.minimum(cache_pos + 1, Lbuf)
        y, nc = _attn_decode_rolling(pa, h, cfg, spec, positions, cache,
                                     write_pos, kv_len)
        return y, nc
    # prefill
    y, nc = _attn_prefill(pa, h, cfg, spec, positions, cache)
    return y, nc


def _attn_prefill(pa, h, cfg, spec, positions, cache):
    """Run full-sequence attention, then lay the (possibly rolled) tail of
    the roped K/V into the cache buffers (slot = position % Lbuf)."""
    y, k, v = L.attention(pa, h, cfg, spec, positions=positions, return_kv=True)
    S, Lbuf = h.shape[1], cache["k"].shape[1]
    if S <= Lbuf:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        return y, {"k": ck, "v": cv}
    # S > Lbuf (windowed cache smaller than prefill): token s -> slot s % Lbuf
    ck = jnp.roll(k[:, -Lbuf:], S % Lbuf, axis=1).astype(cache["k"].dtype)
    cv = jnp.roll(v[:, -Lbuf:], S % Lbuf, axis=1).astype(cache["v"].dtype)
    return y, {"k": ck, "v": cv}


def _attn_decode_rolling(pa, h, cfg, spec, positions, cache, write_pos, kv_len):
    import math as _m
    B = h.shape[0]
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    hc = h.astype(cdt)
    q = jnp.einsum("bsd,dh->bsh", hc, pa["wq"].astype(cdt)).reshape(B, 1, H, Dh)
    k = jnp.einsum("bsd,dh->bsh", hc, pa["wk"].astype(cdt)).reshape(B, 1, KV, Dh)
    v = jnp.einsum("bsd,dh->bsh", hc, pa["wv"].astype(cdt)).reshape(B, 1, KV, Dh)
    if cfg.qk_norm:
        q = L.rmsnorm(pa["qnorm"], q, cfg.norm_eps)
        k = L.rmsnorm(pa["knorm"], k, cfg.norm_eps)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, write_pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, write_pos, 0, 0))
    scale = cfg.attn_scale if cfg.attn_scale is not None else 1.0 / _m.sqrt(Dh)
    from repro.kernels import ops as kops
    out = kops.decode_attention(q, ck, cv, kv_len=kv_len, scale=scale,
                                softcap_val=cfg.attn_softcap, window=None)
    out = out.reshape(B, 1, H * Dh)
    o = jnp.einsum("bsh,hd->bsd", out, pa["wo"].astype(cdt))
    return o.astype(h.dtype), {"k": ck, "v": cv}


def _cross_attn(pa, h, cfg, spec, enc, cache):
    """Cross-attention; K/V over enc states are cached at prefill."""
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S, _ = h.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cache is not None and enc is None:
        xk, xv = cache["xk"].astype(cdt), cache["xv"].astype(cdt)
    else:
        src = enc.astype(cdt)
        T = src.shape[1]
        xk = jnp.einsum("btd,dh->bth", src, pa["wk"].astype(cdt)).reshape(B, T, KV, Dh)
        xv = jnp.einsum("btd,dh->bth", src, pa["wv"].astype(cdt)).reshape(B, T, KV, Dh)
    q = jnp.einsum("bsd,dh->bsh", h.astype(cdt), pa["wq"].astype(cdt)).reshape(B, S, H, Dh)
    import math as _m
    scale = 1.0 / _m.sqrt(Dh)
    from repro.kernels import ops as kops
    out = kops.flash_attention(q, xk, xv, causal=False, scale=scale,
                               use_pallas=cfg.use_pallas)
    out = out.reshape(B, S, H * Dh)
    o = jnp.einsum("bsh,hd->bsd", out, pa["wo"].astype(cdt)).astype(h.dtype)
    nc = None
    if cache is not None:
        nc = {"xk": xk.astype(cache["xk"].dtype), "xv": xv.astype(cache["xv"].dtype)}
    return o, nc


# ---------------------------------------------------------------------------
# full stacks
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.compute_dtype))
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, x.dtype))
    return constrain(x, "batch", None, None)


def _encode(params, cfg, enc_embed):
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    enc_spec = LayerSpec(kind="attn", mlp="gelu_mlp", causal=False)
    x = enc_embed.astype(jnp.dtype(cfg.compute_dtype))
    positions = jnp.arange(x.shape[1])

    def body(carry, pblk):
        y, _, _ = apply_layer(pblk, enc_spec, cfg, carry, positions=positions)
        return y, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"],
                        unroll=cfg.scan_unroll)
    return L.rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def _logits(params, x, cfg):
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.tie_embeddings:
        w = params["embed"].astype(cdt)
        logits = jnp.einsum("bsd,vd->bsv", x.astype(cdt), w)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x.astype(cdt),
                            params["lm_head"].astype(cdt))
    if cfg.final_softcap:
        logits = L.softcap(logits, cfg.final_softcap)
    logits = constrain(logits, "batch", None, "vocab")
    if logits.shape[-1] != cfg.vocab_size:  # drop the padded vocab rows
        logits = logits[..., :cfg.vocab_size]
    return logits


def _prep_enc(params, cfg, extra):
    if cfg.n_enc_layers:
        return _encode(params, cfg, extra["enc_embed"])
    if cfg.n_img_tokens:
        img = extra["img_embed"].astype(jnp.dtype(cfg.compute_dtype))
        return jnp.einsum("bnd,de->bne", img, params["w_img"].astype(
            jnp.dtype(cfg.compute_dtype)))
    return None


def forward_train(params, tokens, cfg: ModelConfig, extra=None):
    """Teacher-forced forward over full sequences -> logits, aux."""
    extra = extra or {}
    x = _embed(params, tokens, cfg)
    x0 = x
    enc = _prep_enc(params, cfg, extra)
    positions = jnp.arange(tokens.shape[1])
    aux_tot = dict(ZERO_AUX)
    for p, spec in zip(params["prologue"], cfg.prologue):
        x, _, aux = apply_layer(p, spec, cfg, x, positions=positions, x0=x0, enc=enc)
        aux_tot = {k: aux_tot[k] + aux[k] for k in aux_tot}

    shared = params.get("shared_attn")

    def body(carry, pblks):
        x, aux_c = carry
        aux_n = aux_c
        for i, spec in enumerate(cfg.superblock):
            x, _, aux = apply_layer(pblks[i], spec, cfg, x, positions=positions,
                                    x0=x0, enc=enc, shared_params=shared)
            aux_n = {k: aux_n[k] + aux[k] for k in aux_n}
        x = constrain(x, "batch", None, None)
        return (x, aux_n), None

    if cfg.remat == "dots":
        # save matmul outputs, recompute elementwise: trades temp memory for
        # backward-pass recompute traffic (§Perf lever)
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif cfg.remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux_tot), _ = jax.lax.scan(body, (x, aux_tot), tuple(params["blocks"]),
                                   unroll=cfg.scan_unroll)
    return _logits(params, x, cfg), aux_tot


def loss_fn(params, batch, cfg: ModelConfig):
    logits, aux = forward_train(params, batch["tokens"], cfg,
                                extra={k: v for k, v in batch.items()
                                       if k not in ("tokens", "labels")})
    labels = batch["labels"]
    # CE via gather + logsumexp: never materializes the (B,S,V) fp32
    # log-softmax (a §Perf memory-roofline win measured on rwkv6/train_4k;
    # fp32 accumulation over the bf16 logits preserves accuracy)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)  # (B,S)
    picked = jnp.take_along_axis(logits, labels[..., None].clip(0),
                                 axis=-1)[..., 0].astype(jnp.float32)
    ll = picked - lse
    mask = (labels >= 0).astype(jnp.float32)
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    loss = loss + 1e-2 * aux["moe_lb_loss"] + 1e-3 * aux["moe_z_loss"]
    metrics = {"loss": loss, "ntokens": mask.sum(), **aux}
    return loss, metrics


# ---------------------------------------------------------------------------
# caches / serving
# ---------------------------------------------------------------------------

def _cache_len(cfg: ModelConfig, spec: LayerSpec, max_len: int) -> int:
    w = spec.sliding_window or cfg.decode_window
    if spec.kind == "shared_attn" and cfg.decode_window:
        w = cfg.decode_window
    if w:
        return min(w, max_len)
    return max_len


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch, max_len, dtype):
    if spec.kind in ("attn", "shared_attn"):
        return L.init_attn_cache(cfg, batch, _cache_len(cfg, spec, max_len), dtype)
    if spec.kind == "mla":
        return L.init_mla_cache(cfg, batch, max_len, dtype)
    if spec.kind == "mamba2":
        return SSM.init_mamba2_cache(cfg, batch, dtype)
    if spec.kind == "rwkv6":
        return RW.init_rwkv6_cache(cfg, batch, dtype)
    if spec.kind == "xattn":
        T = cfg.n_img_tokens or cfg.enc_len
        KV, Dh = cfg.n_kv_heads, cfg.head_dim
        return {"xk": jnp.zeros((batch, T, KV, Dh), dtype),
                "xv": jnp.zeros((batch, T, KV, Dh), dtype)}
    if spec.kind == "dec":
        c = L.init_attn_cache(cfg, batch, _cache_len(cfg, spec, max_len), dtype)
        T = cfg.enc_len
        KV, Dh = cfg.n_kv_heads, cfg.head_dim
        c.update({"xk": jnp.zeros((batch, T, KV, Dh), dtype),
                  "xv": jnp.zeros((batch, T, KV, Dh), dtype)})
        return c
    raise ValueError(spec.kind)


def init_caches(cfg: ModelConfig, batch, max_len, dtype=jnp.bfloat16):
    pro = [init_layer_cache(cfg, s, batch, max_len, dtype) for s in cfg.prologue]
    blocks = []
    for spec in cfg.superblock:
        one = init_layer_cache(cfg, spec, batch, max_len, dtype)
        blocks.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_repeat,) + a.shape), one))
    return {"prologue": pro, "blocks": blocks, "pos": jnp.zeros((), jnp.int32)}


def forward_cached(params, tokens, caches, cfg: ModelConfig, extra=None):
    """Prefill (S>1) or decode (S=1) through the cache stack."""
    extra = extra or {}
    S = tokens.shape[1]
    pos0 = caches["pos"]
    x = _embed(params, tokens, cfg)
    x0 = x
    enc = _prep_enc(params, cfg, extra) if (cfg.n_enc_layers or cfg.n_img_tokens) \
        and S > 1 else None
    positions = pos0 + jnp.arange(S)
    aux = dict(ZERO_AUX)
    new_pro = []
    for p, spec, c in zip(params["prologue"], cfg.prologue, caches["prologue"]):
        x, nc, _ = apply_layer(p, spec, cfg, x, positions=positions, x0=x0,
                               enc=enc, cache=c, cache_pos=pos0)
        new_pro.append(nc)

    shared = params.get("shared_attn")

    def body(x, blk):
        pblks, cblks = blk
        ncs = []
        for i, spec in enumerate(cfg.superblock):
            x, nc, _ = apply_layer(pblks[i], spec, cfg, x, positions=positions,
                                   x0=x0, enc=enc, cache=cblks[i],
                                   cache_pos=pos0, shared_params=shared)
            ncs.append(nc)
        return x, tuple(ncs)

    x, new_blocks = jax.lax.scan(
        body, x, (tuple(params["blocks"]), tuple(caches["blocks"])),
        unroll=cfg.scan_unroll)
    logits = _logits(params, x[:, -1:] if S > 1 else x, cfg)
    new_caches = {"prologue": new_pro, "blocks": list(new_blocks),
                  "pos": pos0 + S}
    return logits, new_caches


def prefill(params, tokens, cfg: ModelConfig, max_len=None, extra=None,
            cache_dtype=jnp.bfloat16):
    caches = init_caches(cfg, tokens.shape[0], max_len or tokens.shape[1],
                         cache_dtype)
    return forward_cached(params, tokens, caches, cfg, extra=extra)


def decode_step(params, token, caches, cfg: ModelConfig):
    """token: (B, 1) int32. One autoregressive step."""
    logits, caches = forward_cached(params, token, caches, cfg)
    return logits[:, 0], caches
