"""RWKV6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Simplifications vs. the full Finch recipe (documented in DESIGN.md):
the five-way data-dependent token-shift interpolation (ddlerp) is reduced to
learned static per-channel mixes, while the *data-dependent decay* — the
architectural hallmark of RWKV6 — is kept (w = exp(-exp(w0 + lora(x)))).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models.layers import dense_init, init_rmsnorm, rmsnorm


def _heads(cfg: ModelConfig):
    return cfg.d_model // cfg.rwkv_head_dim


def init_rwkv6(key, cfg: ModelConfig):
    d, D = cfg.d_model, cfg.rwkv_head_dim
    H = _heads(cfg)
    r_dec, r_mix = cfg.rwkv_lora_decay, cfg.rwkv_lora_mix
    ks = jax.random.split(key, 12)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "mix_r": jnp.full((d,), 0.5, dt),
        "mix_k": jnp.full((d,), 0.5, dt),
        "mix_v": jnp.full((d,), 0.5, dt),
        "mix_w": jnp.full((d,), 0.5, dt),
        "mix_g": jnp.full((d,), 0.5, dt),
        "wr": dense_init(ks[0], (d, d), d, dt),
        "wk": dense_init(ks[1], (d, d), d, dt),
        "wv": dense_init(ks[2], (d, d), d, dt),
        "wg": dense_init(ks[3], (d, d), d, dt),
        "w0": jnp.full((d,), -0.6, dt),  # base decay: w ~ exp(-exp(-0.6)) ~ 0.58
        "w_lora_a": dense_init(ks[4], (d, r_dec), d, dt),
        "w_lora_b": (jax.random.normal(ks[5], (r_dec, d)) * 0.01).astype(dt),
        "u": (jax.random.normal(ks[6], (H, D)) * 0.1).astype(dt),
        "ln_x": init_rmsnorm(d, dt),
        "wo": dense_init(ks[7], (d, d), d, dt),
        # channel mix
        "cmix_k": jnp.full((d,), 0.5, dt),
        "cmix_r": jnp.full((d,), 0.5, dt),
        "ck": dense_init(ks[8], (d, cfg.d_ff), d, dt),
        "cv": dense_init(ks[9], (cfg.d_ff, d), cfg.d_ff, dt),
        "cr": dense_init(ks[10], (d, d), d, dt),
    }


def _token_shift(x, prev):
    """Shift sequence right by one; position 0 gets `prev` (B,1,D) or zeros."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def rwkv6_time_mix(p, x, cfg: ModelConfig, *, cache=None):
    """x: (B,S,D). cache: {"shift_t": (B,1,D), "state": (B,H,Dh,Dh)}."""
    B, S, d = x.shape
    D = cfg.rwkv_head_dim
    H = _heads(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    prev = cache["shift_t"].astype(cdt) if cache is not None else None
    xx = _token_shift(xc, prev)

    def mix(m):
        return xc + (xx - xc) * p[m].astype(cdt)

    r = jnp.einsum("bsd,de->bse", mix("mix_r"), p["wr"].astype(cdt))
    k = jnp.einsum("bsd,de->bse", mix("mix_k"), p["wk"].astype(cdt))
    v = jnp.einsum("bsd,de->bse", mix("mix_v"), p["wv"].astype(cdt))
    g = jnp.einsum("bsd,de->bse", mix("mix_g"), p["wg"].astype(cdt))
    # data-dependent decay (the Finch mechanism)
    wx = mix("mix_w")
    dd = jnp.einsum("bsd,dr->bsr", wx, p["w_lora_a"].astype(cdt))
    dd = jnp.einsum("bsr,rd->bsd", jnp.tanh(dd), p["w_lora_b"].astype(cdt))
    logdecay = -jnp.exp(p["w0"].astype(jnp.float32) + dd.astype(jnp.float32))
    w = jnp.exp(logdecay)  # in (0,1), per (B,S,d)

    rh = r.reshape(B, S, H, D)
    kh = k.reshape(B, S, H, D)
    vh = v.reshape(B, S, H, D)
    wh = w.reshape(B, S, H, D)
    new_cache = None
    if cache is not None and S == 1:
        st, y = kops.wkv6_decode(cache["state"], rh[:, 0], kh[:, 0], vh[:, 0],
                                 wh[:, 0], p["u"].astype(jnp.float32))
        y = y[:, None]
        new_cache = {"shift_t": xc[:, -1:].astype(cache["shift_t"].dtype), "state": st}
    else:
        y = kops.wkv6_scan(rh, kh, vh, wh, p["u"].astype(jnp.float32),
                           chunk=min(cfg.ssm_chunk, S),
                           use_pallas=cfg.use_pallas, impl=cfg.wkv_impl,
                           subchunk=cfg.wkv_subchunk)
        if cache is not None:  # prefill
            st = _wkv_final_state(kh, vh, wh)
            new_cache = {"shift_t": xc[:, -1:].astype(cache["shift_t"].dtype),
                         "state": st}
    y = y.reshape(B, S, d)
    y = rmsnorm(p["ln_x"], y.astype(cdt), cfg.norm_eps) * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", y, p["wo"].astype(cdt))
    return out.astype(x.dtype), new_cache


def _wkv_final_state(k, v, w):
    """State after the full sequence: sum_s (prod_{j>s} w_j) k_s v_s^T."""
    lw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-12, 1.0))
    cl = jnp.cumsum(lw, axis=1)
    tail = jnp.exp(cl[:, -1:] - cl)  # (B,S,H,D)
    return jnp.einsum("bshd,bshe->bhde", tail * k.astype(jnp.float32),
                      v.astype(jnp.float32))


def rwkv6_channel_mix(p, x, cfg: ModelConfig, *, cache=None):
    B, S, d = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    prev = cache["shift_c"].astype(cdt) if cache is not None else None
    xx = _token_shift(xc, prev)
    xk = xc + (xx - xc) * p["cmix_k"].astype(cdt)
    xr = xc + (xx - xc) * p["cmix_r"].astype(cdt)
    kk = jnp.einsum("bsd,df->bsf", xk, p["ck"].astype(cdt))
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("bsf,fd->bsd", kk, p["cv"].astype(cdt))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cr"].astype(cdt)))
    out = rr * vv
    new_shift = xc[:, -1:] if cache is not None else None
    return out.astype(x.dtype), new_shift


def init_rwkv6_cache(cfg: ModelConfig, batch, dtype):
    H, D = _heads(cfg), cfg.rwkv_head_dim
    return {
        "shift_t": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "shift_c": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "state": jnp.zeros((batch, H, D, D), jnp.float32),
    }
