"""Mamba2 (state-space dual) block — the SSM component of zamba2.

Dims: d_inner = expand * d_model; n_ssm_heads = d_inner / ssm_head_dim;
B/C projections are shared across heads (n_groups=1, as in zamba2).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models.layers import dense_init, init_rmsnorm, rmsnorm


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads


def init_mamba2(key, cfg: ModelConfig):
    d, N, W = cfg.d_model, cfg.ssm_state, cfg.ssm_conv_width
    d_inner, H = _dims(cfg)
    conv_ch = d_inner + 2 * N  # conv over (x, B, C)
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        # fused in-projection: [z, x, B, C, dt]
        "w_in": dense_init(ks[0], (d, 2 * d_inner + 2 * N + H), d, dt),
        "conv_w": (jax.random.normal(ks[1], (W, conv_ch)) * 0.1).astype(dt),
        "A_log": jnp.zeros((H,), dt),  # A = -exp(A_log) = -1 at init
        "dt_bias": jnp.zeros((H,), dt),
        "D": jnp.ones((H,), dt),
        "ssm_norm": init_rmsnorm(d_inner, dt),
        "w_out": dense_init(ks[2], (d_inner, d), d_inner, dt),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B,S,C); w: (W,C); state: (B,W-1,C)|None."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else None
    return out, new_state


def mamba2_block(p, x, cfg: ModelConfig, *, cache=None):
    """x: (B,S,D). cache: {"conv": (B,W-1,C), "ssm": (B,H,P,N)} for decode."""
    B, S, _ = x.shape
    N, P = cfg.ssm_state, cfg.ssm_head_dim
    d_inner, H = _dims(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    zxbcdt = jnp.einsum("bsd,de->bse", xc, p["w_in"].astype(cdt))
    z, xi, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xi, Bc, Cc], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"].astype(cdt), conv_state)
    conv_out = jax.nn.silu(conv_out)
    xi, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xi.reshape(B, S, H, P)

    if cache is not None and S == 1:
        h, y = kops.ssd_decode(cache["ssm"], xh[:, 0].astype(jnp.float32),
                               dtv[:, 0], A, Bc[:, 0].astype(jnp.float32),
                               Cc[:, 0].astype(jnp.float32))
        y = y[:, None]  # (B,1,H,P)
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": h}
    else:
        y = kops.ssd_scan(xh, dtv, A, Bc, Cc, chunk=min(cfg.ssm_chunk, S),
                          use_pallas=cfg.use_pallas)
        new_cache = None
        if cache is not None:  # prefill: recompute final state sequentially-free
            # final state = full scan state; compute via chunked tail (cheap)
            hfin = _final_state(xh, dtv, A, Bc, Cc)
            new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": hfin}
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(cdt)
    y = rmsnorm(p["ssm_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(cdt))
    return out.astype(x.dtype), new_cache


def _final_state(x, dt, A, B_, C):
    """Final SSM state after the whole sequence (for prefill->decode handoff)."""
    a = A[None, None, :] * dt  # (B,S,H)
    acs = jnp.cumsum(a, axis=1)
    tail = jnp.exp(acs[:, -1:, :] - acs)  # (B,S,H)
    xf = x.astype(jnp.float32)
    h = jnp.einsum("bsh,bshp,bsn->bhpn", tail * dt, xf, B_.astype(jnp.float32))
    return h


def init_mamba2_cache(cfg: ModelConfig, batch, dtype):
    N, W = cfg.ssm_state, cfg.ssm_conv_width
    d_inner, H = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, W - 1, d_inner + 2 * N), dtype),
        "ssm": jnp.zeros((batch, H, cfg.ssm_head_dim, N), jnp.float32),
    }
