"""Mixture-of-Experts layer (deepseek-v2-lite, arctic).

Two implementations, selected by ``cfg.moe_impl``:

* ``"gshard"`` (baseline, paper-era standard): capacity-bounded one-hot
  dispatch/combine einsums.  Tokens are re-grouped into fixed-size groups so
  the (group, tokens, E, C) dispatch tensor stays bounded regardless of
  sequence length.  Experts are sharded over the ``model`` mesh axis
  (expert parallelism); GSPMD inserts the all-to-all-equivalent collectives.

* ``"sort"`` (beyond-paper §Perf optimization): replaces the one-hot
  dispatch/combine *einsums* (which XLA counts — and executes — as dense
  FLOPs) with argsort + gather/scatter data movement.  Same capacity/drop
  semantics, ~zero dispatch FLOPs.

Both return (output, aux) where aux carries the load-balance and router
z-losses.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init



def init_moe(key, cfg: ModelConfig):
    d, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "w_router": dense_init(ks[0], (d, E), d, dt),
        "experts_wi": dense_init(ks[1], (E, d, F), d, dt),
        "experts_wg": dense_init(ks[2], (E, d, F), d, dt),
        "experts_wo": dense_init(ks[3], (E, F, d), F, dt),
    }
    if cfg.n_shared_experts:
        from repro.models.layers import init_glu_mlp
        p["shared"] = init_glu_mlp(ks[4], d, cfg.n_shared_experts * F, dt)
    return p


def _route(p, xf, cfg: ModelConfig):
    """xf: (G, T, D) grouped tokens -> top-k experts, gates, aux losses."""
    E, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("gtd,de->gte", xf, p["w_router"].astype(xf.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (G,T,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss + router z-loss
    me = probs.mean(axis=(0, 1))  # (E,)
    one_hot_top1 = jax.nn.one_hot(expert_ids[..., 0], E)
    ce = one_hot_top1.mean(axis=(0, 1))
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return expert_ids, gate_vals, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss}


def _positions_in_expert(expert_ids, E):
    """expert_ids: (G,T,k) -> per-slot position of each token in its expert's
    queue (G,T,k), counting all slots in token order then slot order."""
    G, T, k = expert_ids.shape
    oh = jax.nn.one_hot(expert_ids, E, dtype=jnp.int32)  # (G,T,k,E)
    tok_counts = oh.sum(2)  # (G,T,E)
    cum_prev_tokens = jnp.cumsum(tok_counts, axis=1) - tok_counts  # exclusive (G,T,E)
    intra = jnp.cumsum(oh, axis=2) - oh  # slots before this one, same token
    base = jnp.take_along_axis(
        cum_prev_tokens[:, :, None, :], expert_ids[..., None], axis=-1)[..., 0]
    off = jnp.take_along_axis(intra, expert_ids[..., None], axis=-1)[..., 0]
    return base + off  # (G,T,k)


def _capacity(cfg: ModelConfig, T):
    c = int(math.ceil(cfg.capacity_factor * T * cfg.top_k / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_layer(p, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """x: (B,S,D) -> (B,S,D), aux losses."""
    B, S, D = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    grp = cfg.moe_group
    T = grp if (B * S) % grp == 0 and (B * S) >= grp else B * S
    G = (B * S) // T
    xf = x.reshape(G, T, D).astype(cdt)
    expert_ids, gates, aux = _route(p, xf, cfg)
    C = _capacity(cfg, T)
    pos = _positions_in_expert(expert_ids, cfg.n_experts)  # (G,T,k)
    keep = pos < C
    gates = gates * keep

    if cfg.moe_impl == "sort":
        out = _moe_sort(p, xf, expert_ids, gates, pos, keep, C, cfg)
    else:
        out = _moe_gshard(p, xf, expert_ids, gates, pos, keep, C, cfg)

    if cfg.n_shared_experts:
        from repro.models.layers import glu_mlp
        out = out + glu_mlp(p["shared"], xf, cdt)
    return out.reshape(B, S, D).astype(x.dtype), aux


def _expert_ffn(p, xe, cdt):
    """xe: (E, G, C, D) -> (E, G, C, D); experts sharded over `model`."""
    h = jnp.einsum("egcd,edf->egcf", xe, p["experts_wi"].astype(cdt))
    g = jnp.einsum("egcd,edf->egcf", xe, p["experts_wg"].astype(cdt))
    return jnp.einsum("egcf,efd->egcd", jax.nn.silu(g) * h, p["experts_wo"].astype(cdt))


def _moe_gshard(p, xf, expert_ids, gates, pos, keep, C, cfg):
    G, T, D = xf.shape
    E, k = cfg.n_experts, cfg.top_k
    cdt = xf.dtype
    # combine[g,t,e,c] = sum_j gate_j * 1[e=e_j] * 1[c=pos_j]
    oh_e = jax.nn.one_hot(expert_ids, E, dtype=cdt)          # (G,T,k,E)
    oh_c = jax.nn.one_hot(pos, C, dtype=cdt)                 # (G,T,k,C)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", gates.astype(cdt), oh_e, oh_c)
    dispatch = (combine > 0).astype(cdt)
    xe = jnp.einsum("gtec,gtd->egcd", dispatch, xf)          # (E,G,C,D)
    ye = _expert_ffn(p, xe, cdt)
    return jnp.einsum("gtec,egcd->gtd", combine, ye)


def _moe_sort(p, xf, expert_ids, gates, pos, keep, C, cfg):
    """FLOP-free dispatch: scatter tokens into (E,G,C,D) slot table by index,
    gather back with gates.  Dropped tokens go to a trash slot."""
    G, T, D = xf.shape
    E, k = cfg.n_experts, cfg.top_k
    cdt = xf.dtype
    slot = jnp.where(keep, expert_ids * C + pos, E * C)  # (G,T,k); E*C = trash
    flat_slots = slot.reshape(G, T * k)
    tok_idx = jnp.repeat(jnp.arange(T)[None, :], G, axis=0)
    tok_idx = jnp.repeat(tok_idx[..., None], k, axis=-1).reshape(G, T * k)
    # scatter token vectors into slots (one writer per slot by construction)
    xe = jnp.zeros((G, E * C + 1, D), cdt)
    xe = jax.vmap(lambda buf, s, ti, xg: buf.at[s].set(xg[ti]))(
        xe, flat_slots, tok_idx, xf)
    xe = xe[:, :E * C].reshape(G, E, C, D).transpose(1, 0, 2, 3)  # (E,G,C,D)
    ye = _expert_ffn(p, xe, cdt)
    ye = ye.transpose(1, 0, 2, 3).reshape(G, E * C, D)
    # gather back per slot j and weight by gate
    gathered = jax.vmap(lambda yg, s: yg[jnp.minimum(s, E * C - 1)])(
        ye, flat_slots)  # (G, T*k, D); trash slots get zero gate anyway
    gathered = gathered.reshape(G, T, k, D)
    return jnp.einsum("gtk,gtkd->gtd", gates.astype(cdt), gathered)
