"""Core neural layers: norms, RoPE, MLPs, GQA attention (sliding window /
softcap / cache), and MLA (DeepSeek multi-head latent attention).

Everything is a pure function over nested-dict params.  Attention's inner
softmax(QK^T)V runs through :mod:`repro.kernels.ops`, which dispatches to the
Pallas TPU kernel on TPU and to a flash-style chunked jnp implementation
elsewhere (identical math; memory-bounded for 32k+ sequences).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.kernels import ops as kops

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(max(1, in_axis_size))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d, dtype):
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(p, x, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta, dim=None):
    """Apply rotary embeddings.  x: (..., S, H, D); positions: (..., S)."""
    d = dim if dim is not None else x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:d]
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    out = jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)
    if d == x.shape[-1]:
        return out
    return jnp.concatenate([out, x[..., d:]], axis=-1)


def softcap(x, cap):
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_glu_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, (d_model, d_ff), d_model, dtype),
        "wg": dense_init(k2, (d_model, d_ff), d_model, dtype),
        "wo": dense_init(k3, (d_ff, d_model), d_ff, dtype),
    }


def glu_mlp(p, x, cdtype, act=jax.nn.silu):
    h = jnp.einsum("...d,df->...f", x, p["wi"].astype(cdtype))
    g = jnp.einsum("...d,df->...f", x, p["wg"].astype(cdtype))
    return jnp.einsum("...f,fd->...d", act(g) * h, p["wo"].astype(cdtype))


def init_gelu_mlp(key, d_model, d_ff, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "wi": dense_init(k1, (d_model, d_ff), d_model, dtype),
        "wo": dense_init(k2, (d_ff, d_model), d_ff, dtype),
    }


def gelu_mlp(p, x, cdtype):
    h = jnp.einsum("...d,df->...f", x, p["wi"].astype(cdtype))
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(h), p["wo"].astype(cdtype))


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": dense_init(k1, (d, H * Dh), d, dt),
        "wk": dense_init(k2, (d, KV * Dh), d, dt),
        "wv": dense_init(k3, (d, KV * Dh), d, dt),
        "wo": dense_init(k4, (H * Dh, cfg.d_model), H * Dh, dt),
    }
    if cfg.qk_norm:
        p["qnorm"] = init_rmsnorm(Dh, dt)
        p["knorm"] = init_rmsnorm(Dh, dt)
    return p


def attention(
    p: Params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    spec: LayerSpec,
    *,
    positions: jnp.ndarray,
    kv_x: Optional[jnp.ndarray] = None,
    rope_on: bool = True,
    return_kv: bool = False,
):
    """GQA attention over a full sequence (train / prefill).

    x: (B, S, D).  Cross-attention: kv_x provides the encoder states.
    Cache handling (decode / rolling windows) lives in models/lm.py.
    """
    B, S, _ = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    q = jnp.einsum("bsd,dh->bsh", xc, p["wq"].astype(cdt)).reshape(B, S, H, Dh)
    src = xc if kv_x is None else kv_x.astype(cdt)
    Skv = src.shape[1]
    k = jnp.einsum("bsd,dh->bsh", src, p["wk"].astype(cdt)).reshape(B, Skv, KV, Dh)
    v = jnp.einsum("bsd,dh->bsh", src, p["wv"].astype(cdt)).reshape(B, Skv, KV, Dh)
    if cfg.qk_norm:
        q = rmsnorm(p["qnorm"], q, cfg.norm_eps)
        k = rmsnorm(p["knorm"], k, cfg.norm_eps)
    if rope_on and kv_x is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    scale = cfg.attn_scale if cfg.attn_scale is not None else 1.0 / math.sqrt(Dh)
    causal = spec.causal and kv_x is None
    out = kops.flash_attention(
        q, k, v, causal=causal, scale=scale, softcap_val=cfg.attn_softcap,
        window=spec.sliding_window, q_pos0=0, use_pallas=cfg.use_pallas)
    out = out.reshape(B, S, H * Dh)
    o = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cdt))
    if return_kv:
        return o.astype(x.dtype), k, v
    return o.astype(x.dtype), None


def init_attn_cache(cfg: ModelConfig, batch, max_len, dtype):
    Dh, KV = cfg.head_dim, cfg.n_kv_heads
    return {
        "k": jnp.zeros((batch, max_len, KV, Dh), dtype),
        "v": jnp.zeros((batch, max_len, KV, Dh), dtype),
    }


# ---------------------------------------------------------------------------
# quantized-cache helpers (int8 serving caches; §Perf hillclimb C)
# ---------------------------------------------------------------------------

CACHE_QSCALE = 40.0  # static scale: post-RMSNorm latents / roped keys ~ O(1)


def cache_store(x, dtype):
    if jnp.dtype(dtype) == jnp.int8:
        return jnp.clip(jnp.round(x.astype(jnp.float32) * CACHE_QSCALE),
                        -127, 127).astype(jnp.int8)
    return x.astype(dtype)


def cache_load(x, cdt):
    if x.dtype == jnp.int8:
        return (x.astype(cdt) * (1.0 / CACHE_QSCALE)).astype(cdt)
    return x.astype(cdt)


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V2 multi-head latent attention
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    r, dr, dn, dv = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wq": dense_init(ks[0], (d, H * (dn + dr)), d, dt),
        "wkv_a": dense_init(ks[1], (d, r), d, dt),           # latent down-proj
        "wk_rope": dense_init(ks[2], (d, dr), d, dt),        # shared rope key
        "kv_norm": init_rmsnorm(r, dt),
        "wk_b": dense_init(ks[3], (r, H * dn), r, dt),       # latent -> k_nope
        "wv_b": dense_init(ks[4], (r, H * dv), r, dt),       # latent -> v
        "wo": dense_init(ks[5], (H * dv, d), H * dv, dt),
    }


def mla_attention(p, x, cfg: ModelConfig, spec: LayerSpec, *, positions,
                  cache=None, cache_pos=None):
    """MLA: queries per-head (nope+rope); K/V reconstructed from a shared
    latent of rank ``kv_lora_rank``; the cache stores only latent + rope key.

    With ``cfg.mla_absorb`` (decode), the k up-projection is absorbed into the
    query and attention runs directly in the latent space — the published
    serving optimization, which we use as a §Perf lever.
    """
    B, S, _ = x.shape
    H = cfg.n_heads
    r, dr, dn, dv = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    q = jnp.einsum("bsd,dh->bsh", xc, p["wq"].astype(cdt)).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    latent = jnp.einsum("bsd,dr->bsr", xc, p["wkv_a"].astype(cdt))
    latent = rmsnorm(p["kv_norm"], latent, cfg.norm_eps)
    k_rope = rope(
        jnp.einsum("bsd,dr->bsr", xc, p["wk_rope"].astype(cdt))[:, :, None, :],
        positions, cfg.rope_theta)[:, :, 0, :]  # (B,S,dr) shared across heads

    scale = 1.0 / math.sqrt(dn + dr)
    new_cache = None
    if cache is not None:
        cl = jax.lax.dynamic_update_slice(
            cache["latent"], cache_store(latent, cache["latent"].dtype),
            (0, cache_pos, 0))
        cr = jax.lax.dynamic_update_slice(
            cache["k_rope"], cache_store(k_rope, cache["k_rope"].dtype),
            (0, cache_pos, 0))
        new_cache = {"latent": cl, "k_rope": cr}
        if S == 1:
            T = cl.shape[1]
            mask = (jnp.arange(T) <= cache_pos)[None, None, :]
            if cfg.mla_absorb:
                # absorb wk_b into q: q_lat (B,1,H,r) = q_nope @ wk_b^T per head
                wkb = p["wk_b"].astype(cdt).reshape(r, H, dn)
                q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, wkb)
                logits = jnp.einsum("bshr,btr->bhst", q_lat, cache_load(cl, cdt))
                logits += jnp.einsum("bshr,btr->bhst", q_rope, cache_load(cr, cdt))
                logits = (logits * scale)[:, :, 0, :]  # (B,H,T)
                logits = jnp.where(mask, logits, -1e30)
                w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(cdt)
                ctx_lat = jnp.einsum("bht,btr->bhr", w, cache_load(cl, cdt))
                wvb = p["wv_b"].astype(cdt).reshape(r, H, dv)
                out = jnp.einsum("bhr,rhv->bhv", ctx_lat, wvb)[:, None]  # (B,1,H,dv)
            else:
                k_nope = jnp.einsum("btr,rh->bth", cache_load(cl, cdt),
                                    p["wk_b"].astype(cdt)).reshape(B, T, H, dn)
                vv = jnp.einsum("btr,rh->bth", cache_load(cl, cdt),
                                p["wv_b"].astype(cdt)).reshape(B, T, H, dv)
                logits = jnp.einsum("bshn,bthn->bhst", q_nope, k_nope)
                logits += jnp.einsum("bshr,btr->bhst", q_rope, cache_load(cr, cdt))
                logits = (logits * scale)[:, :, 0, :]
                logits = jnp.where(mask, logits, -1e30)
                w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(cdt)
                out = jnp.einsum("bht,bthv->bhv", w, vv)[:, None]
            out = out.reshape(B, 1, H * dv)
            o = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cdt))
            return o.astype(x.dtype), new_cache

    # train / prefill: reconstruct full K,V and run flash attention
    k_nope = jnp.einsum("bsr,rh->bsh", latent, p["wk_b"].astype(cdt)).reshape(B, S, H, dn)
    vv = jnp.einsum("bsr,rh->bsh", latent, p["wv_b"].astype(cdt)).reshape(B, S, H, dv)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))], -1)
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    # pad v to qk dim for the shared kernel, then slice (dv <= dn+dr)
    v_pad = jnp.pad(vv, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
    out = kops.flash_attention(q_full, k_full, v_pad, causal=spec.causal, scale=scale,
                               use_pallas=cfg.use_pallas)[..., :dv]
    out = out.reshape(B, S, H * dv)
    o = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cdt))
    return o.astype(x.dtype), new_cache


def init_mla_cache(cfg: ModelConfig, batch, max_len, dtype):
    return {
        "latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }
