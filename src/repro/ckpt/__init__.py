from repro.ckpt.checkpoint import CheckpointManager, save_pytree, restore_pytree  # noqa
