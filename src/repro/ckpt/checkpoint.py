"""Sharded, atomic, async checkpointing (no orbax offline).

Layout: <root>/step_<n>/ with one .npy per pytree leaf (path-escaped) and a
manifest.json describing the tree.  Writes go to a tmp dir that is renamed
into place — a crashed writer never leaves a readable-but-partial
checkpoint.  ``restore(..., shardings=...)`` device_puts each leaf with the
given sharding, which is also the elastic-rescale path: restoring onto a
different mesh reshards automatically.

Async: saves run on a background thread against host copies of the arrays
(jax.device_get is the snapshot), so the training loop isn't blocked.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, List, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_names(treedef, n):
    return [f"leaf_{i:05d}" for i in range(n)]


def save_pytree(path: str, tree: Any) -> None:
    leaves, treedef = _flatten(tree)
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names = _leaf_names(treedef, len(leaves))
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    for name, arr in zip(names, host):
        np.save(os.path.join(tmp, name + ".npy"), arr)
    manifest = {"treedef": str(treedef), "n_leaves": len(leaves),
                "shapes": [list(a.shape) for a in host],
                "dtypes": [str(a.dtype) for a in host]}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)  # atomic publish


def restore_pytree(path: str, like: Any, shardings: Any = None) -> Any:
    leaves, treedef = _flatten(like)
    names = _leaf_names(treedef, len(leaves))
    out = []
    # None = "default placement" for that leaf; flatten with is_leaf so the
    # Nones survive (bare tree_flatten drops them as empty nodes)
    shard_leaves = (jax.tree_util.tree_flatten(
        shardings, is_leaf=lambda x: x is None)[0]
        if shardings is not None else [None] * len(leaves))
    assert len(shard_leaves) == len(leaves), (len(shard_leaves), len(leaves))
    for name, ref, sh in zip(names, leaves, shard_leaves):
        arr = np.load(os.path.join(path, name + ".npy"))
        dtype = ref.dtype if hasattr(ref, "dtype") else arr.dtype
        arr = arr.astype(dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3, async_save: bool = True):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, d, "manifest.json")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, tree: Any) -> None:
        # snapshot to host synchronously, write asynchronously
        host = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
        self.wait()

        def _do():
            save_pytree(self._step_dir(step), host)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        return restore_pytree(self._step_dir(step), like, shardings)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
