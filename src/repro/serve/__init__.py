from repro.serve.engine import ServeEngine, make_prefill_fn, make_decode_fn  # noqa
from repro.serve.gateway import SurrogateGateway  # noqa
