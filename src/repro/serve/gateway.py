"""HTTP/REST gateway serving a study's trained surrogate ensemble.

A Merlin study leaves behind bundled training rows and (via
:class:`repro.core.active.SurrogateSnapshot`) a resident deep-ensemble
surrogate.  This module puts a request-serving front end on that
snapshot so *other* tools — steering dashboards, calibration loops,
downstream samplers — can query the ensemble over plain HTTP while the
study keeps running:

    client -> HTTP handler thread -> ContinuousBatcher -> snapshot.predict
                                      (admission heap,      (one fused jit
                                       deadlines, shed)      launch/batch)

Everything is stdlib: ``http.server.ThreadingHTTPServer`` gives one
thread per connection; those threads park on their request's completion
event while the single batcher thread fuses concurrent requests into
bucket-sized device launches (see ``ContinuousBatcher`` in
core/engine.py for the admission policy).  No new dependencies.

Endpoints (JSON bodies in, JSON out):

* ``GET  /healthz``      — liveness + snapshot version (never auth'd)
* ``GET  /v1/stats``     — gateway + batcher + snapshot counters
* ``POST /v1/predict``   — ``{"points": [[...], ...]}`` -> mu/sigma
* ``POST /v1/calibrate`` — ``{"target": y}`` -> top-k candidate inputs
  whose predicted mean lands closest to the target (inverse query)
* ``POST /v1/what-if``   — ``{"point": [...]}`` -> prediction plus a
  local perturbation cloud (sensitivity around an operating point)
* ``POST /v1/refresh``   — fold newly bundled rows into the snapshot

Status mapping is the contract the benchmark and tests pin down:
``429`` (queue at ``--max-inflight``, shed before admission, with
``Retry-After``), ``504`` (per-request deadline passed while queued —
the request never executed), ``503`` (draining/stopped), ``401``
(``REPRO_AUTH_TOKEN`` set but Bearer token missing/wrong), ``400``
(malformed body).

Auth is the same shared secret the broker hello handshake uses
(``REPRO_AUTH_TOKEN``): client sends ``Authorization: Bearer <token>``;
comparison is constant-time.  Deadlines come from ``deadline_ms`` in the
body or an ``X-Deadline-Ms`` header.
"""

from __future__ import annotations

import hmac
import json
import os
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional

import numpy as np

from repro.core.engine import ContinuousBatcher, DeadlineExpired, EngineClosed
from repro.core.queue import BrokerFull


class _BadRequest(ValueError):
    """Malformed request body -> HTTP 400 with the message."""


def _require(body: dict, key: str):
    if key not in body:
        raise _BadRequest(f"missing required field {key!r}")
    return body[key]


def _as_points(value, dims: int, what: str = "points") -> np.ndarray:
    try:
        X = np.asarray(value, np.float32)
    except (TypeError, ValueError) as e:
        raise _BadRequest(f"{what} is not numeric: {e}")
    if X.ndim == 1:
        X = X[None, :]
    if X.ndim != 2 or X.shape[0] == 0:
        raise _BadRequest(f"{what} must be a non-empty (n, d) array, "
                          f"got shape {tuple(X.shape)}")
    if X.shape[1] != dims:
        raise _BadRequest(f"{what} has {X.shape[1]} dims, "
                          f"snapshot expects {dims}")
    if not np.isfinite(X).all():
        raise _BadRequest(f"{what} contains non-finite values")
    return X


class SurrogateGateway:
    """Serve a :class:`SurrogateSnapshot` over HTTP with continuous
    batching, deadlines, load shedding, and graceful drain.

    ``naive=True`` swaps the batcher into its flush-per-request baseline
    mode (same wire protocol, one device launch per request) — the A/B
    arm of ``benchmarks/serve_latency.py``.

    ``refresh_s`` starts a background thread folding newly bundled rows
    into the snapshot every that-many seconds (the snapshot retrains off
    the serving path and swaps the model ref atomically, so inference
    never blocks on a retrain).
    """

    def __init__(self, snapshot, host: str = "127.0.0.1", port: int = 0,
                 max_inflight: int = 64, max_batch_rows: int = 256,
                 naive: bool = False, auth_token: Optional[str] = None,
                 default_deadline_ms: Optional[float] = None,
                 refresh_s: Optional[float] = None):
        self.snapshot = snapshot
        self.host = host
        self._requested_port = int(port)
        self.port: Optional[int] = None
        self.auth_token = (auth_token if auth_token is not None
                           else os.environ.get("REPRO_AUTH_TOKEN"))
        self.default_deadline_ms = default_deadline_ms
        self.refresh_s = refresh_s
        self.batcher = ContinuousBatcher(snapshot.predict,
                                         max_batch_rows=max_batch_rows,
                                         max_inflight=max_inflight,
                                         naive=naive)
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._serve_thread: Optional[threading.Thread] = None
        self._refresh_thread: Optional[threading.Thread] = None
        self._stop_refresh = threading.Event()
        self._draining = False
        self._lock = threading.Lock()
        self._http_stats: Dict[str, object] = {"requests": 0, "status": {}}

    # -- request plumbing ----------------------------------------------------
    def _count(self, status: int) -> None:
        with self._lock:
            self._http_stats["requests"] += 1
            st = self._http_stats["status"]
            st[str(status)] = st.get(str(status), 0) + 1

    def _authorized(self, handler) -> bool:
        if self.auth_token is None:
            return True
        hdr = handler.headers.get("Authorization", "")
        if not hdr.startswith("Bearer "):
            return False
        return hmac.compare_digest(hdr[len("Bearer "):].strip(),
                                   self.auth_token)

    def _deadline_s(self, body: dict, handler) -> Optional[float]:
        ms = body.get("deadline_ms")
        if ms is None:
            hdr = handler.headers.get("X-Deadline-Ms")
            if hdr is not None:
                try:
                    ms = float(hdr)
                except ValueError:
                    raise _BadRequest(f"bad X-Deadline-Ms header {hdr!r}")
        if ms is None:
            ms = self.default_deadline_ms
        if ms is None:
            return None
        ms = float(ms)
        if ms <= 0:
            raise _BadRequest("deadline_ms must be > 0")
        return ms / 1000.0

    def _infer(self, X: np.ndarray, deadline_s: Optional[float]):
        """Route rows through the batcher; returns ``(mu, sigma)``.

        Raises the batcher's typed errors; the dispatcher maps them to
        status codes.  The wait cap is the deadline plus slack for the
        in-flight launch — a request the batcher admitted always
        resolves, so a ``wait`` timeout only guards a wedged backend."""
        req = self.batcher.submit(X, deadline_s=deadline_s)
        cap = (deadline_s + 30.0) if deadline_s is not None else 300.0
        if not req.wait(timeout=cap):
            raise EngineClosed("inference did not complete in time")
        if req.error is not None:
            raise req.error
        return req.result

    # -- endpoint bodies -----------------------------------------------------
    def _do_predict(self, body: dict, handler) -> dict:
        X = _as_points(_require(body, "points"), self.snapshot.dims)
        mu, sd = self._infer(X, self._deadline_s(body, handler))
        return {"mu": np.asarray(mu, float).tolist(),
                "sigma": np.asarray(sd, float).tolist(),
                "n": int(len(X)),
                "version": self.snapshot.version}

    def _do_calibrate(self, body: dict, handler) -> dict:
        """Inverse query: which inputs does the ensemble predict to land
        nearest the target objective?  Candidates are uniform over the
        unit hypercube (the study's normalized input domain)."""
        target = float(_require(body, "target"))
        n_cand = int(body.get("n_candidates", 128))
        top_k = int(body.get("top_k", 4))
        if not 1 <= n_cand <= 4096:
            raise _BadRequest("n_candidates must be in [1, 4096]")
        if not 1 <= top_k <= n_cand:
            raise _BadRequest("top_k must be in [1, n_candidates]")
        rng = np.random.default_rng(int(body.get("seed", 0)))
        cand = rng.random((n_cand, self.snapshot.dims), np.float32)
        mu, sd = self._infer(cand, self._deadline_s(body, handler))
        mu = np.asarray(mu, float)
        sd = np.asarray(sd, float)
        order = np.argsort(np.abs(mu - target), kind="stable")[:top_k]
        return {"target": target,
                "version": self.snapshot.version,
                "candidates": [{"point": cand[i].astype(float).tolist(),
                                "mu": float(mu[i]),
                                "sigma": float(sd[i]),
                                "gap": float(abs(mu[i] - target))}
                               for i in order]}

    def _do_what_if(self, body: dict, handler) -> dict:
        """Local sensitivity: predict at a point and across a clipped
        Gaussian cloud around it, in one fused inference."""
        base = _as_points(_require(body, "point"), self.snapshot.dims,
                          "point")[0]
        radius = float(body.get("radius", 0.02))
        n_pert = int(body.get("n_perturb", 16))
        if not 0 < radius <= 0.5:
            raise _BadRequest("radius must be in (0, 0.5]")
        if not 1 <= n_pert <= 1024:
            raise _BadRequest("n_perturb must be in [1, 1024]")
        rng = np.random.default_rng(int(body.get("seed", 0)))
        cloud = np.clip(base[None, :]
                        + rng.normal(0.0, radius,
                                     (n_pert, self.snapshot.dims)),
                        0.0, 1.0).astype(np.float32)
        X = np.concatenate([base[None, :], cloud])
        mu, sd = self._infer(X, self._deadline_s(body, handler))
        mu = np.asarray(mu, float)
        nb = mu[1:]
        return {"mu": float(mu[0]),
                "sigma": float(np.asarray(sd, float)[0]),
                "radius": radius,
                "n_perturb": n_pert,
                "neighborhood": {"mu_mean": float(nb.mean()),
                                 "mu_std": float(nb.std()),
                                 "mu_min": float(nb.min()),
                                 "mu_max": float(nb.max())},
                "version": self.snapshot.version}

    def _do_refresh(self, body: dict, handler) -> dict:
        refreshed = self.snapshot.refresh()
        return {"refreshed": bool(refreshed),
                "version": self.snapshot.version,
                "rows": self.snapshot.rows}

    def stats(self) -> dict:
        with self._lock:
            http_stats = {"requests": self._http_stats["requests"],
                          "status": dict(self._http_stats["status"])}
        return {"http": http_stats,
                "batcher": self.batcher.stats(),
                "snapshot": {"version": self.snapshot.version,
                             "rows": self.snapshot.rows,
                             "dims": self.snapshot.dims},
                "draining": self._draining}

    # -- HTTP server ---------------------------------------------------------
    def _make_handler(self):
        gw = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"  # keep-alive: reuse connections
            server_version = "merlin-serve"
            # headers and body leave as separate small writes; without
            # TCP_NODELAY, Nagle holds the body until the client's
            # delayed ACK (~40 ms on Linux) — which in continuous mode
            # gates the whole next batch, not just one client's latency
            disable_nagle_algorithm = True

            def log_message(self, *args):  # quiet: stats() has counters
                pass

            def _reply(self, status: int, payload: dict,
                       extra: Optional[dict] = None) -> None:
                blob = json.dumps(payload).encode()
                self.send_response(status)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(blob)))
                for k, v in (extra or {}).items():
                    self.send_header(k, v)
                self.end_headers()
                try:
                    self.wfile.write(blob)
                except (BrokenPipeError, ConnectionError):
                    pass  # client gave up; reply already counted
                gw._count(status)

            def do_GET(self) -> None:
                if self.path == "/healthz":
                    self._reply(200, {"ok": True,
                                      "draining": gw._draining,
                                      "version": gw.snapshot.version,
                                      "rows": gw.snapshot.rows})
                    return
                if not gw._authorized(self):
                    self._reply(401, {"error": "missing or bad "
                                               "Authorization bearer token"})
                    return
                if self.path == "/v1/stats":
                    self._reply(200, gw.stats())
                    return
                self._reply(404, {"error": f"no route {self.path!r}"})

            def do_POST(self) -> None:
                # drain the body FIRST, even on early-exit replies: with
                # HTTP/1.1 keep-alive an unread body would be parsed as
                # the connection's next request line
                try:
                    n = int(self.headers.get("Content-Length") or 0)
                except ValueError:
                    n = 0
                raw = self.rfile.read(n) if n > 0 else b""
                if not gw._authorized(self):
                    self._reply(401, {"error": "missing or bad "
                                               "Authorization bearer token"})
                    return
                route = {"/v1/predict": gw._do_predict,
                         "/v1/calibrate": gw._do_calibrate,
                         "/v1/what-if": gw._do_what_if,
                         "/v1/refresh": gw._do_refresh}.get(self.path)
                if route is None:
                    self._reply(404, {"error": f"no route {self.path!r}"})
                    return
                if gw._draining:
                    self._reply(503, {"error": "gateway is draining"})
                    return
                try:
                    body = json.loads(raw or b"{}")
                    if not isinstance(body, dict):
                        raise _BadRequest("body must be a JSON object")
                    self._reply(200, route(body, self))
                except _BadRequest as e:
                    self._reply(400, {"error": str(e)})
                except (json.JSONDecodeError, UnicodeDecodeError) as e:
                    self._reply(400, {"error": f"bad JSON body: {e}"})
                except BrokerFull as e:
                    self._reply(429, {"error": str(e)},
                                extra={"Retry-After": "1"})
                except DeadlineExpired as e:
                    self._reply(504, {"error": str(e)})
                except EngineClosed as e:
                    self._reply(503, {"error": str(e)})
                except Exception as e:  # inference blew up: typed 500
                    self._reply(500, {"error":
                                      f"{type(e).__name__}: {e}"})

        return Handler

    def start(self) -> "SurrogateGateway":
        httpd = ThreadingHTTPServer((self.host, self._requested_port),
                                    self._make_handler())
        httpd.daemon_threads = True
        self._httpd = httpd
        self.port = httpd.server_address[1]
        self._serve_thread = threading.Thread(
            target=httpd.serve_forever, kwargs={"poll_interval": 0.2},
            daemon=True, name=f"merlin-serve-http-{self.port}")
        self._serve_thread.start()
        if self.refresh_s is not None:
            self._refresh_thread = threading.Thread(
                target=self._refresh_loop, daemon=True,
                name="merlin-serve-refresh")
            self._refresh_thread.start()
        return self

    def _refresh_loop(self) -> None:
        while not self._stop_refresh.wait(self.refresh_s):
            try:
                self.snapshot.refresh()
            except Exception:
                pass  # transient archive read races; next tick retries

    def stop(self, drain: bool = True, timeout: float = 30.0) -> bool:
        """Graceful shutdown: stop admitting (503), let admitted requests
        finish, then tear the listener down.  Returns True when the
        backlog fully drained within the timeout."""
        self._draining = True
        drained = True
        if drain:
            drained = self.batcher.drain(timeout=timeout)
        self.batcher.close()
        self._stop_refresh.set()
        if self._refresh_thread is not None:
            self._refresh_thread.join(timeout=5.0)
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
        if self._serve_thread is not None:
            self._serve_thread.join(timeout=5.0)
        return drained

    @property
    def address(self) -> str:
        return f"http://{self.host}:{self.port}"

    def __enter__(self) -> "SurrogateGateway":
        if self._httpd is None:
            self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
