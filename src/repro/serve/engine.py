"""Batched serving engine: prefill + autoregressive decode over the cache
stack (models/lm.py), with sharding-aware jitted step functions.

``decode_32k`` / ``long_500k`` shapes lower :func:`make_decode_fn` — one new
token against a seq_len-deep cache — NOT the train step, per the assignment.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.parallel.sharding import activation_rules


def make_prefill_fn(cfg: ModelConfig, max_len: int, mesh=None, rules=None,
                    cache_dtype=jnp.bfloat16):
    def prefill_fn(params, tokens, *extra_kv):
        extra = dict(zip(_extra_keys(cfg), extra_kv))
        with activation_rules(mesh, rules):
            logits, caches = lm.prefill(params, tokens, cfg, max_len=max_len,
                                        extra=extra, cache_dtype=cache_dtype)
        return logits, caches
    return prefill_fn


def make_decode_fn(cfg: ModelConfig, mesh=None, rules=None):
    def decode_fn(params, token, caches):
        with activation_rules(mesh, rules):
            logits, caches = lm.decode_step(params, token, caches, cfg)
        return logits, caches
    return decode_fn


def _extra_keys(cfg: ModelConfig):
    keys = []
    if cfg.n_enc_layers:
        keys.append("enc_embed")
    if cfg.n_img_tokens:
        keys.append("img_embed")
    return keys


class ServeEngine:
    """Greedy batched generation with throughput accounting."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 mesh=None, rules=None, cache_dtype=jnp.bfloat16):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.prefill_fn = jax.jit(
            make_prefill_fn(cfg, max_len, mesh, rules, cache_dtype))
        self.decode_fn = jax.jit(make_decode_fn(cfg, mesh, rules))
        self.stats = {"prefill_tokens": 0, "decode_tokens": 0,
                      "prefill_s": 0.0, "decode_s": 0.0}

    def generate(self, tokens, n_new: int, extra: Optional[Dict] = None):
        extra = extra or {}
        t0 = time.monotonic()
        extra_vals = [extra[k] for k in _extra_keys(self.cfg)]
        logits, caches = self.prefill_fn(self.params, tokens, *extra_vals)
        logits.block_until_ready()
        self.stats["prefill_s"] += time.monotonic() - t0
        self.stats["prefill_tokens"] += tokens.size
        out = [jnp.argmax(logits[:, -1], axis=-1)]
        t0 = time.monotonic()
        for _ in range(n_new - 1):
            tok = out[-1][:, None].astype(jnp.int32)
            logits, caches = self.decode_fn(self.params, tok, caches)
            out.append(jnp.argmax(logits, axis=-1))
        out[-1].block_until_ready()
        self.stats["decode_s"] += time.monotonic() - t0
        self.stats["decode_tokens"] += (n_new - 1) * tokens.shape[0]
        return jnp.stack(out, axis=1)
