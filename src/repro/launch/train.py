"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch jag-surrogate --steps 200
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-27b --reduced --steps 20

Full configs train on the production mesh (real TPUs); on this CPU host use
--reduced (the smoke-scale config of the same family).  Checkpoint/restart
is automatic: re-running with the same --workdir resumes.
"""
from __future__ import annotations

import argparse
import json
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="jag-surrogate")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", default="synthetic",
                    help="synthetic | path to a bundler root of JAG results")
    args = ap.parse_args(argv)

    from repro.configs import registry
    from repro.data.pipeline import SyntheticTokens, ensemble_token_stream
    from repro.train.trainer import Trainer

    cfg = (registry.reduced_config(args.arch) if args.reduced
           else registry.get_config(args.arch))
    extras = {}
    if cfg.n_enc_layers:
        extras["enc_embed"] = ((args.batch, cfg.enc_len, cfg.d_model), "bfloat16")
    if cfg.n_img_tokens:
        extras["img_embed"] = ((args.batch, cfg.n_img_tokens, cfg.d_vision),
                               "bfloat16")
    if args.data == "synthetic":
        data = iter(SyntheticTokens(args.batch, args.seq, cfg.vocab_size,
                                    extras=extras))
    else:
        from repro.core.bundler import Bundler
        archive = Bundler(args.data).load_all()
        data = ensemble_token_stream(
            archive, ["yield", "tion", "velocity", "bang_time"],
            batch=args.batch, vocab=cfg.vocab_size)

    tr = Trainer(cfg, args.workdir, data, lr=args.lr,
                 ckpt_every=args.ckpt_every)
    t0 = time.time()
    state = tr.train(args.steps)
    dt = time.time() - t0
    done = len(tr.history)
    print(json.dumps({
        "arch": cfg.arch_id, "steps": int(state.step),
        "ran_steps": done, "final_loss": tr.history[-1]["loss"] if done else None,
        "first_loss": tr.history[0]["loss"] if done else None,
        "wall_s": round(dt, 1), "stragglers": tr.stragglers,
        "tokens_per_s": round(done * args.batch * args.seq / max(dt, 1e-9)),
    }, indent=1))


if __name__ == "__main__":
    main()
