"""Production mesh construction.

A function, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
only launch/dryrun.py is allowed to set the 512-device host-platform flag).
"""
from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (TPU v5e pod); 2 pods when multi_pod.

    Uses the first prod(shape) devices, so a 512-device dry-run environment
    can build both the single-pod (256) and multi-pod (512) meshes.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    assert len(devs) >= n, f"need {n} devices, have {len(devs)}"
    return jax.sharding.Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (real or forced) host devices exist."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))
