"""Serving launchers.

LLM serving (batched prefill + decode with throughput report):

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --reduced \
      --batch 4 --prompt-len 32 --new-tokens 16

Broker serving (a standalone BrokerServer process — the deployable
RabbitMQ stand-in of paper Sec. 2-3; workers on other nodes connect with
``MerlinRuntime(broker="tcp://host:port")``, no shared filesystem needed):

  PYTHONPATH=src python -m repro.launch.serve broker-serve \
      [--backend mem|file] [--root DIR] [--host H] [--port P] \
      [--port-file PATH] [--visibility-timeout S] [--fairness priority|weighted] \
      [--max-queue-depth N] [--put-timeout S] [--shard-of I/N]

``--port 0`` picks a free port; ``--port-file`` atomically publishes the
bound port for launcher scripts (examples/quickstart.py --two-process).
``--max-queue-depth``/``--put-timeout`` arm backpressure: producers block
when a queue is full, then get a structured BrokerFull.  ``--shard-of I/N``
labels this server as shard I of an N-server federation (clients connect
with ``shard://h1:p1,...,hN:pN`` or ``MerlinRuntime(broker=[...])``; the
label is bookkeeping for launchers — routing is client-side by queue hash).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def broker_serve_main(argv=None):
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve broker-serve",
        description="Run a standalone broker server for remote "
                    "MerlinRuntime/WorkerPool processes.")
    ap.add_argument("--backend", choices=("mem", "file"), default="mem",
                    help="queue backend the server fronts")
    ap.add_argument("--root", default=None,
                    help="FileBroker directory (required for --backend file;"
                         " makes the queue itself crash-durable)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="TCP port (0 = pick a free one)")
    ap.add_argument("--port-file", default=None,
                    help="write the bound port here (atomic) once listening")
    ap.add_argument("--visibility-timeout", type=float, default=60.0)
    ap.add_argument("--fairness", choices=("priority", "weighted"),
                    default="priority")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="backpressure bound: puts against a queue holding "
                         "this many pending tasks block, then raise "
                         "BrokerFull (relayed to clients as a typed error)")
    ap.add_argument("--put-timeout", type=float, default=5.0,
                    help="seconds a put may block on a full queue before "
                         "BrokerFull (keep below the clients' request "
                         "grace, default 10s, or they see a timeout "
                         "instead of the structured error)")
    ap.add_argument("--shard-of", default=None, metavar="I/N",
                    help="label this server as shard I of an N-endpoint "
                         "federation (advisory: sharding is client-side "
                         "queue-hash routing via shard:// URLs)")
    args = ap.parse_args(argv)

    shard_of = None
    if args.shard_of is not None:
        try:
            i_s, n_s = args.shard_of.split("/", 1)
            shard_of = (int(i_s), int(n_s))
            if not 0 <= shard_of[0] < shard_of[1]:
                raise ValueError(args.shard_of)
        except ValueError:
            ap.error(f"--shard-of must be I/N with 0 <= I < N, "
                     f"got {args.shard_of!r}")

    from repro.core.netbroker import BrokerServer
    from repro.core.queue import FileBroker, InMemoryBroker

    kw = dict(visibility_timeout=args.visibility_timeout,
              fairness=args.fairness,
              max_queue_depth=args.max_queue_depth,
              put_timeout=args.put_timeout)
    if args.backend == "file":
        if not args.root:
            ap.error("--backend file requires --root DIR")
        backend = FileBroker(args.root, **kw)
    else:
        backend = InMemoryBroker(**kw)
    server = BrokerServer(backend, host=args.host, port=args.port)
    server.start()
    print(json.dumps({"event": "listening", "host": args.host,
                      "port": server.port, "backend": args.backend,
                      "shard_of": None if shard_of is None
                      else f"{shard_of[0]}/{shard_of[1]}",
                      "max_queue_depth": args.max_queue_depth}),
          flush=True)
    if args.port_file:
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(server.port))
        os.rename(tmp, args.port_file)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.stop()


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "broker-serve":
        return broker_serve_main(argv[1:])
    return llm_serve_main(argv)


def llm_serve_main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro.configs import registry
    from repro.models import lm
    from repro.serve.engine import ServeEngine

    cfg = (registry.reduced_config(args.arch) if args.reduced
           else registry.get_config(args.arch))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.new_tokens + 8)
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (args.batch, args.prompt_len), 0,
                              cfg.vocab_size)
    extra = {}
    if cfg.n_enc_layers:
        extra["enc_embed"] = jnp.zeros((args.batch, cfg.enc_len, cfg.d_model),
                                       jnp.bfloat16)
    if cfg.n_img_tokens:
        extra["img_embed"] = jnp.zeros(
            (args.batch, cfg.n_img_tokens, cfg.d_vision), jnp.bfloat16)
    out = eng.generate(toks, args.new_tokens, extra=extra)
    s = eng.stats
    print(json.dumps({
        "arch": cfg.arch_id, "batch": args.batch,
        "prefill_tok_per_s": round(s["prefill_tokens"] / max(s["prefill_s"], 1e-9)),
        "decode_tok_per_s": round(s["decode_tokens"] / max(s["decode_s"], 1e-9)),
        "generated_shape": list(out.shape),
    }, indent=1))


if __name__ == "__main__":
    main()
