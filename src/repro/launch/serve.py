"""Serving launchers.

LLM serving (batched prefill + decode with throughput report):

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --reduced \
      --batch 4 --prompt-len 32 --new-tokens 16

Broker serving (a standalone BrokerServer process — the deployable
RabbitMQ stand-in of paper Sec. 2-3; workers on other nodes connect with
``MerlinRuntime(broker="tcp://host:port")``, no shared filesystem needed):

  PYTHONPATH=src python -m repro.launch.serve broker-serve \
      [--backend mem|file] [--root DIR] [--host H] [--port P] \
      [--port-file PATH] [--visibility-timeout S] [--fairness priority|weighted] \
      [--max-queue-depth N] [--queue-depth Q=N ...] [--put-timeout S] \
      [--shard-of I/N] [--announce PATH] [--codecs bin1,json] [--shm PATH]

``--port 0`` picks a free port; ``--port-file`` atomically publishes the
bound port for launcher scripts (examples/quickstart.py --two-process).
``--max-queue-depth``/``--put-timeout`` arm backpressure: producers block
when a queue is full, then get a structured BrokerFull; ``--queue-depth
Q=N`` (repeatable) bounds single named queues.  ``--shard-of I/N`` labels
this server as shard I of an N-server federation (clients connect with
``shard://h1:p1,...,hN:pN`` or ``MerlinRuntime(broker=[...])``; the label
is bookkeeping for launchers — routing is client-side by queue hash).
``--announce PATH`` atomically publishes the bound endpoint into a shared
discovery file: clients assemble the whole federation from it with
``make_broker("shard+file://PATH")`` instead of hand-building URL lists.
``--codecs`` restricts the wire codecs offered at handshake (default
``bin1,json``; ``json`` emulates a binary-unaware server — see the README
"Wire protocol" section); ``--shm PATH`` additionally serves the backend
over same-host shared-memory channels registered at PATH (clients connect
with ``make_broker("shm://PATH")``).  The process applies ``repro.env``
runtime tuning at entry (REPRO_* env knobs) so serving throughput is
produced on recorded defaults.

Surrogate serving (``merlin-serve``: the inference gateway over a
study's trained surrogate ensemble — continuous batching, per-request
deadlines, 429 load shedding, graceful drain on SIGINT; see the README
"Serving tier" section):

  PYTHONPATH=src python -m repro.launch.serve merlin-serve \
      --study DIR [--host H] [--port P] [--port-file PATH] \
      [--max-inflight N] [--max-batch-rows N] [--deadline-ms MS] \
      [--members N] [--hidden N] [--steps N] [--refresh-s S] [--naive]

Set ``REPRO_AUTH_TOKEN`` to require ``Authorization: Bearer <token>``
on every request (the same shared secret arms the broker hello HMAC).

Broker status (the ops view of any broker URL — per-queue depth, in-flight
leases, and live consumers from the heartbeat registry).  With ``--watch``
it keeps history between polls and derives per-queue throughput (acked
tasks/s) from the ``acked_by_queue`` counter deltas; ``--json`` turns the
watch into a machine-readable stream, one snapshot object per line:

  PYTHONPATH=src python -m repro.launch.serve merlin-status \
      --broker tcp://host:port [--watch S] [--json] [--ring]

``--ring`` renders the elastic-federation view instead: membership
version, per-member owned-queue counts, in-flight migrations, and
replica candidate health (requires a shard:// / shard+file:// /
ring+file:// broker URL).

Autoscaling (the stats-driven policy loop of ``core/autoscale.py`` —
one-shot ``--plan`` prints what it would do; ``--watch S`` applies,
starting/stopping local worker pools and sweeping dead members out of
the membership file):

  PYTHONPATH=src python -m repro.launch.serve merlin-scale \
      --broker URL [--membership PATH] [--plan | --watch S] [--json]

Elastic federation: ``broker-serve --join PATH`` registers the server in
the membership file at PATH, pulls the queues the new ring assigns to it
from their previous owners (live drain-and-forward migration), and
heartbeats until shutdown, when it drains its queues back out and
leaves.  See the README "Elastic federation" section.

Dead-letter queue operations (the operator's side of ``on_failure:
dead_letter`` — inspect what was parked and feed it back after fixing
the cause; works against any broker URL):

  PYTHONPATH=src python -m repro.launch.serve merlin-dlq \
      --broker URL list|show|requeue [--queue Q] [--json]

Spec validation (load + compile every workflow spec into its task DAG,
reporting the first structural error — cycles, unknown dependencies,
unequal %zip lists, unsatisfiable edges; CI runs this over
examples/specs/*.yaml):

  PYTHONPATH=src python -m repro.launch.serve merlin-validate \
      examples/specs/*.yaml [--json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional


def broker_serve_main(argv=None):
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve broker-serve",
        description="Run a standalone broker server for remote "
                    "MerlinRuntime/WorkerPool processes.")
    ap.add_argument("--backend", choices=("mem", "file"), default="mem",
                    help="queue backend the server fronts")
    ap.add_argument("--root", default=None,
                    help="FileBroker directory (required for --backend file;"
                         " makes the queue itself crash-durable)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="TCP port (0 = pick a free one)")
    ap.add_argument("--port-file", default=None,
                    help="write the bound port here (atomic) once listening")
    ap.add_argument("--visibility-timeout", type=float, default=60.0)
    ap.add_argument("--fairness", choices=("priority", "weighted"),
                    default="priority")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="backpressure bound: puts against a queue holding "
                         "this many pending tasks block, then raise "
                         "BrokerFull (relayed to clients as a typed error)")
    ap.add_argument("--queue-depth", action="append", default=[],
                    metavar="QUEUE=N",
                    help="per-queue depth override (repeatable); takes "
                         "precedence over --max-queue-depth for that queue")
    ap.add_argument("--put-timeout", type=float, default=5.0,
                    help="seconds a put may block on a full queue before "
                         "BrokerFull (keep below the clients' request "
                         "grace, default 10s, or they see a timeout "
                         "instead of the structured error)")
    ap.add_argument("--shard-of", default=None, metavar="I/N",
                    help="label this server as shard I of an N-endpoint "
                         "federation (advisory: sharding is client-side "
                         "queue-hash routing via shard:// URLs)")
    ap.add_argument("--announce", default=None, metavar="PATH",
                    help="atomically publish the bound endpoint into this "
                         "shared discovery file; clients build the shard "
                         "list with make_broker('shard+file://PATH')")
    ap.add_argument("--codecs", default="bin1,json", metavar="C1,C2",
                    help="preference-ordered wire codecs offered at the "
                         "connection handshake (json is always the "
                         "compatibility floor; '--codecs json' emulates a "
                         "binary-unaware server)")
    ap.add_argument("--shm", default=None, metavar="PATH",
                    help="also serve same-host clients over shared-memory "
                         "channels registered at PATH "
                         "(make_broker('shm://PATH'))")
    ap.add_argument("--announce-host", default=None, metavar="HOST",
                    help="hostname to publish in the discovery file. "
                         "Default: --host, except the wildcard binds "
                         "(0.0.0.0/::) publish this machine's hostname — "
                         "a wildcard is not dialable.  A loopback --host "
                         "publishes loopback, which is correct: such a "
                         "server only accepts local connections anyway; "
                         "bind 0.0.0.0 (or set this flag) for "
                         "cross-node federations")
    ap.add_argument("--join", default=None, metavar="PATH",
                    help="join the elastic federation whose membership "
                         "registry lives at PATH: register this server, "
                         "pull the queues the new ring assigns to it from "
                         "their previous owners (live migration), "
                         "heartbeat until shutdown, then drain out and "
                         "leave.  Clients follow the registry with "
                         "make_broker('ring+file://PATH')")
    ap.add_argument("--membership-ttl", type=float, default=15.0,
                    metavar="S",
                    help="heartbeat TTL for --join: peers/sweepers evict "
                         "this member when its heartbeat is older than S "
                         "seconds (heartbeats are sent every S/3)")
    args = ap.parse_args(argv)

    queue_depths = {}
    for spec_s in args.queue_depth:
        try:
            q, _, n_s = spec_s.partition("=")
            queue_depths[q] = int(n_s)
        except ValueError:
            ap.error(f"--queue-depth must be QUEUE=N, got {spec_s!r}")

    shard_of = None
    if args.shard_of is not None:
        try:
            i_s, n_s = args.shard_of.split("/", 1)
            shard_of = (int(i_s), int(n_s))
            if not 0 <= shard_of[0] < shard_of[1]:
                raise ValueError(args.shard_of)
        except ValueError:
            ap.error(f"--shard-of must be I/N with 0 <= I < N, "
                     f"got {args.shard_of!r}")

    from repro import env as repro_env
    repro_env.configure()

    from repro.core.netbroker import BrokerServer
    from repro.core.queue import FileBroker, InMemoryBroker

    codecs = tuple(c for c in args.codecs.split(",") if c)
    kw = dict(visibility_timeout=args.visibility_timeout,
              fairness=args.fairness,
              max_queue_depth=args.max_queue_depth,
              put_timeout=args.put_timeout,
              queue_depths=queue_depths or None)
    if args.backend == "file":
        if not args.root:
            ap.error("--backend file requires --root DIR")
        backend = FileBroker(args.root, **kw)
    else:
        backend = InMemoryBroker(**kw)
    auth_token = os.environ.get("REPRO_AUTH_TOKEN")
    try:
        server = BrokerServer(backend, host=args.host, port=args.port,
                              codecs=codecs, shm_path=args.shm,
                              auth_token=auth_token)
    except ValueError as e:
        ap.error(str(e))  # e.g. a typo'd codec name
    server.start()
    print(json.dumps({"event": "listening", "host": args.host,
                      "port": server.port, "backend": args.backend,
                      "codecs": list(codecs), "shm": args.shm,
                      "auth": auth_token is not None,
                      "shard_of": None if shard_of is None
                      else f"{shard_of[0]}/{shard_of[1]}",
                      "max_queue_depth": args.max_queue_depth}),
          flush=True)
    if args.port_file:
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(server.port))
        os.rename(tmp, args.port_file)
    if args.announce:
        import socket as _socket
        from repro.core.shardbroker import announce_endpoint
        host = args.announce_host or args.host
        if host in ("0.0.0.0", "::", ""):
            # the wildcard bind address is not a connectable endpoint;
            # publish something peers can actually dial
            host = _socket.gethostname()
        announce_endpoint(args.announce, f"tcp://{host}:{server.port}",
                          index=None if shard_of is None else shard_of[0],
                          total=None if shard_of is None else shard_of[1])
    join_url, hb_stop, hb_thread = None, None, None
    if args.join:
        import socket as _socket
        import threading as _threading
        from repro.core.hashring import heartbeat_membership
        from repro.core.shardbroker import join_federation
        host = args.announce_host or args.host
        if host in ("0.0.0.0", "::", ""):
            host = _socket.gethostname()
        join_url = f"tcp://{host}:{server.port}"
        res = join_federation(args.join, join_url)
        print(json.dumps({"event": "joined", "membership": args.join,
                          "url": join_url, "version": res["version"],
                          "queues_pulled": len(res["moved"])}),
              flush=True)
        hb_stop = _threading.Event()
        hb_period = max(args.membership_ttl / 3.0, 0.2)

        def _heartbeat_loop():
            while not hb_stop.wait(hb_period):
                try:
                    heartbeat_membership(args.join, join_url)
                except OSError:
                    pass  # registry briefly unwritable; retry next beat

        hb_thread = _threading.Thread(target=_heartbeat_loop, daemon=True,
                                      name="membership-heartbeat")
        hb_thread.start()
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        if join_url is not None:
            hb_stop.set()
            hb_thread.join(timeout=2.0)
            # drain our queues to the surviving members BEFORE stopping
            # the server (the migration pulls through our own endpoint);
            # an unclean death instead relies on heartbeat-TTL eviction +
            # a replacement adopting the durable root
            from repro.core.shardbroker import leave_federation
            try:
                res = leave_federation(args.join, join_url)
                print(json.dumps({"event": "left",
                                  "membership": args.join,
                                  "version": res["version"],
                                  "queues_drained": len(res["moved"])}),
                      flush=True)
            except Exception as e:
                print(json.dumps({"event": "leave-failed",
                                  "error": str(e)}), flush=True)
        server.stop()


def status_snapshot(broker) -> dict:
    """One consistent-ish view of a broker: per-queue depth, in-flight
    leases, and live consumers (heartbeat registry), plus the counter
    totals.  Works against any Broker — local, NetBroker, ShardedBroker —
    because it only uses protocol ops."""
    stats = dict(broker.stats)
    consumers = dict(stats.pop("consumers", None) or {})
    inflight_by_q: dict = {}
    for task, _age in broker.inflight_tasks():
        inflight_by_q[task.queue] = inflight_by_q.get(task.queue, 0) + 1
    queues = sorted(set(broker.queue_names())
                    | set(inflight_by_q)
                    | {q for q in consumers if q != "*"})
    rows = {q: {"depth": broker.qsize((q,)),
                "inflight": inflight_by_q.get(q, 0),
                "consumers": consumers.get(q, 0)} for q in queues}
    snap = {
        "queues": rows,
        "totals": {"depth": sum(r["depth"] for r in rows.values()),
                   "inflight": sum(r["inflight"] for r in rows.values())},
        # "*"-subscribed consumers (no named queues) can drain anything
        "wildcard_consumers": consumers.get("*", 0),
        "counters": {k: v for k, v in stats.items()
                     if isinstance(v, (int, float))},
        # per-queue ack totals: the watch loop differences consecutive
        # snapshots into tasks/s
        "acked_by_queue": {q: int(c) for q, c
                           in (stats.get("acked_by_queue") or {}).items()
                           if isinstance(c, (int, float))},
    }
    # federation health: per-shard epoch + replica liveness (failover view)
    shard_health = getattr(broker, "shard_health", None)
    if shard_health is not None:
        snap["shards"] = shard_health()
    return snap


def _render_status(snap: dict, broker_url: str) -> str:
    rates = (snap.get("rates") or {}).get("tasks_per_s")
    lines = [f"broker {broker_url}"]
    header = f"{'queue':<24} {'depth':>8} {'inflight':>9} {'consumers':>10}"
    if rates is not None:
        header += f" {'tasks/s':>9}"
    lines.append(header)
    lines.append("-" * len(header))
    qnames = sorted(set(snap["queues"]) | set(rates or {}))
    for q in qnames:
        r = snap["queues"].get(q, {"depth": 0, "inflight": 0, "consumers": 0})
        row = (f"{q:<24} {r['depth']:>8} {r['inflight']:>9} "
               f"{r['consumers']:>10}")
        if rates is not None:
            row += f" {rates.get(q, 0.0):>9.1f}"
        lines.append(row)
    if not qnames:
        lines.append("(no queues)")
    t = snap["totals"]
    total = (f"{'TOTAL':<24} {t['depth']:>8} {t['inflight']:>9} "
             f"{snap['wildcard_consumers']:>9}*")
    if rates is not None:
        total += f" {snap['rates']['total_tasks_per_s']:>9.1f}"
    lines.append(total)
    c = snap["counters"]
    lines.append("counters: " + ", ".join(
        f"{k}={c[k]}" for k in sorted(c)))
    for sh in snap.get("shards", ()):
        cands = ", ".join(
            f"{'*' if ce['active'] else ''}{ce['endpoint']}"
            f"[{'up' if ce['alive'] else 'DOWN'}]"
            for ce in sh["candidates"])
        lines.append(f"shard {sh['shard']} epoch {sh['epoch']}: {cands}")
    return "\n".join(lines)


def watch_rates(prev: Optional[dict], prev_t: float, snap: dict,
                now: float) -> Optional[dict]:
    """Per-queue throughput between two snapshots: difference the
    ``acked_by_queue`` counters and divide by the wall-clock interval.
    None on the first poll (no history yet).  Negative deltas (a broker
    restart reset its counters) clamp to zero rather than reporting
    nonsense."""
    if prev is None:
        return None
    dt = max(now - prev_t, 1e-9)
    cur = snap.get("acked_by_queue") or {}
    old = prev.get("acked_by_queue") or {}
    per_q = {q: max(0, cur.get(q, 0) - old.get(q, 0)) / dt
             for q in sorted(set(cur) | set(old))}
    return {"interval_s": round(dt, 3),
            "tasks_per_s": {q: round(r, 2) for q, r in per_q.items()},
            "total_tasks_per_s": round(sum(per_q.values()), 2)}


def _render_ring(info: dict, broker_url: str) -> str:
    """The ``merlin-status --ring`` table: membership version, per-member
    owned-queue counts, in-flight migrations, candidate health."""
    mode = "elastic" if info.get("elastic") else "static"
    lines = [f"broker {broker_url}",
             f"ring version {info['version']} ({mode}, "
             f"vnodes={info['vnodes']})"]
    header = (f"{'slot':>4} {'member':<28} {'epoch':>5} {'queues':>7} "
              f"{'migrating':<18} candidates")
    lines.append(header)
    lines.append("-" * len(header))
    for m in info.get("members", ()):
        cands = ", ".join(
            f"{'*' if c['active'] else ''}{c['endpoint']}"
            f"[{'up' if c['alive'] else 'DOWN'}]"
            for c in m.get("candidates", ()))
        mig = ",".join(m.get("migrating", ())) or "-"
        lines.append(f"{m['slot']:>4} {m['member']:<28} {m['epoch']:>5} "
                     f"{m['queues_owned']:>7} {mig:<18} {cands}")
    if not info.get("members"):
        lines.append("(no members)")
    if info.get("pins"):
        lines.append("pins: " + ", ".join(
            f"{q}->{u}" for q, u in sorted(info["pins"].items())))
    if info.get("queue_pins"):
        lines.append("index pins: " + ", ".join(
            f"{q}->{i}" for q, i in sorted(info["queue_pins"].items())))
    if info.get("retired_slots"):
        lines.append("retired slots: " + ", ".join(
            f"{s} ({u})" for s, u in sorted(info["retired_slots"].items())))
    return "\n".join(lines)


def merlin_status_main(argv=None):
    """``merlin-status``: the ROADMAP's 'surface consumers in a CLI' item —
    one-shot (or --watch) per-queue depth/inflight/consumers against any
    broker URL.  --watch keeps history between polls and adds a per-queue
    throughput column from the acked-counter deltas."""
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve merlin-status",
        description="Show per-queue depth, in-flight leases, and live "
                    "consumers for a broker.")
    ap.add_argument("--broker", required=True,
                    help="broker URL: tcp://host:port, file://dir, "
                         "shard://h:p,h:p, or shard+file://announce-path")
    ap.add_argument("--watch", type=float, default=None, metavar="S",
                    help="refresh every S seconds until interrupted; each "
                         "refresh reports tasks/s per queue since the "
                         "previous poll")
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable JSON instead of the table "
                         "(with --watch: a stream, one object per line)")
    ap.add_argument("--ring", action="store_true",
                    help="show the elastic-federation view instead: "
                         "membership version, per-member owned-queue "
                         "counts, migrating queues, candidate health "
                         "(sharded broker URLs only)")
    args = ap.parse_args(argv)

    import time as _time
    from repro.core.netbroker import make_broker
    broker = make_broker(args.broker)
    if args.ring and not hasattr(broker, "ring_info"):
        ap.error(f"--ring needs a sharded broker URL (shard://, "
                 f"shard+file://, ring+file://), got {args.broker!r}")
    prev, prev_t = None, 0.0
    try:
        while True:
            if args.ring:
                info = broker.ring_info()
                if args.json:
                    print(json.dumps({"broker": args.broker, **info}),
                          flush=True)
                else:
                    print(_render_ring(info, args.broker), flush=True)
                if args.watch is None:
                    return
                _time.sleep(args.watch)
                if not args.json:
                    print()
                continue
            snap = status_snapshot(broker)
            now = _time.monotonic()
            rates = watch_rates(prev, prev_t, snap, now)
            if rates is not None:
                snap["rates"] = rates
            prev, prev_t = snap, now
            if args.json:
                print(json.dumps({"broker": args.broker, **snap}),
                      flush=True)
            else:
                print(_render_status(snap, args.broker), flush=True)
            if args.watch is None:
                return
            _time.sleep(args.watch)
            if not args.json:
                print()
    except KeyboardInterrupt:
        pass
    finally:
        close = getattr(broker, "close", None)
        if close is not None:
            close()


def _render_plan(plan) -> str:
    o = plan.observed
    lines = [f"depth {o['depth']}  inflight {o['inflight']}  "
             f"consumers {o['consumers']}  managed workers "
             f"{o['managed_workers']} ({o['pools']} pool(s))  "
             f"backlog/worker {o.get('backlog_per_worker', 0)}"]
    if o.get("members") is not None:
        lines[0] += (f"  members {o['members']} "
                     f"(ring v{o.get('ring_version', '?')})")
    for a in plan.actions:
        lines.append(f"  action: {a.kind} n={a.n} — {a.reason}")
    for a in plan.recommendations:
        lines.append(f"  recommend: {a.kind} — {a.reason}")
    for a in o.get("applied", ()):
        lines.append(f"  applied: {a['kind']} n={a['n']}")
    if o.get("evicted_members"):
        lines.append("  evicted: " + ", ".join(o["evicted_members"]))
    if not plan.actions and not plan.recommendations:
        lines.append("  steady (no action)")
    return "\n".join(lines)


def merlin_scale_main(argv=None):
    """``merlin-scale``: the autoscaler policy loop as a CLI.  ``--plan``
    (default) samples the broker once and prints what the policy would
    do; ``--watch S`` runs plan-then-apply every S seconds — scaling a
    set of local :class:`~repro.core.worker.WorkerPool`\\ s attached to
    ``--broker`` up and down, sweeping heartbeat-dead members out of the
    ``--membership`` registry, and printing shard join/leave
    recommendations for the operator to act on (``broker-serve
    --join``)."""
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve merlin-scale",
        description="Plan or apply stats-driven autoscaling against a "
                    "broker (worker pools + shard recommendations).")
    ap.add_argument("--broker", required=True,
                    help="broker URL: tcp://host:port, file://dir, "
                         "shard://..., shard+file:// or ring+file://PATH")
    ap.add_argument("--membership", default=None, metavar="PATH",
                    help="federation membership file: apply mode evicts "
                         "heartbeat-expired members; plan mode sizes "
                         "shard recommendations against the member count")
    ap.add_argument("--plan", action="store_true",
                    help="one-shot: print the plan, change nothing "
                         "(default when --watch is absent)")
    ap.add_argument("--watch", type=float, default=None, metavar="S",
                    help="apply loop: plan-then-apply every S seconds "
                         "until interrupted")
    ap.add_argument("--workspace", default="/tmp/merlin-scale",
                    help="runtime workspace for worker pools started in "
                         "apply mode")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable plans, one object per line")
    pol = ap.add_argument_group("policy knobs")
    pol.add_argument("--up-backlog", type=float, default=8.0,
                     help="scale up above this many pending tasks per "
                          "worker (default 8)")
    pol.add_argument("--pool-size", type=int, default=2,
                     help="workers per scale-up increment (default 2)")
    pol.add_argument("--min-workers", type=int, default=0)
    pol.add_argument("--max-workers", type=int, default=16)
    pol.add_argument("--down-idle", type=float, default=10.0, metavar="S",
                     help="retire a pool after this long continuously "
                          "idle (default 10s)")
    pol.add_argument("--cooldown", type=float, default=5.0, metavar="S",
                     help="minimum spacing between applied worker "
                          "actions (default 5s)")
    pol.add_argument("--shard-up-depth", type=int, default=5000,
                     help="recommend joining a shard above this total "
                          "backlog (default 5000)")
    pol.add_argument("--shard-down-depth", type=int, default=0,
                     help="recommend draining a shard at/below this "
                          "total backlog (default 0)")
    pol.add_argument("--membership-ttl", type=float, default=15.0,
                     help="evict members with heartbeats older than this "
                          "when sweeping --membership (default 15s)")
    args = ap.parse_args(argv)
    if args.plan and args.watch is not None:
        ap.error("--plan and --watch are mutually exclusive")

    import time as _time
    from repro.core.autoscale import Autoscaler, AutoscalePolicy
    from repro.core.netbroker import make_broker
    policy = AutoscalePolicy(
        up_backlog_per_worker=args.up_backlog, pool_size=args.pool_size,
        min_workers=args.min_workers, max_workers=args.max_workers,
        down_idle_s=args.down_idle, cooldown_s=args.cooldown,
        shard_up_depth=args.shard_up_depth,
        shard_down_depth=args.shard_down_depth,
        membership_ttl=args.membership_ttl)

    apply_mode = args.watch is not None
    runtime = None
    if apply_mode:
        # pools need a runtime to execute against; it shares the broker
        from repro.core.runtime import MerlinRuntime
        from repro.core.worker import WorkerPool
        runtime = MerlinRuntime(broker=args.broker,
                                workspace=args.workspace)
        broker = runtime.broker

        def pool_factory(n):
            return WorkerPool(runtime, n_workers=n)

        def engine_stats():
            eng = runtime._engine
            return dict(eng.stats) if eng is not None else {}
    else:
        broker = make_broker(args.broker)
        pool_factory = None
        engine_stats = None

    scaler = Autoscaler(broker, policy, pool_factory=pool_factory,
                        membership_path=args.membership,
                        engine_stats=engine_stats)
    try:
        while True:
            plan = scaler.step() if apply_mode else scaler.plan()
            if args.json:
                print(json.dumps(plan.to_doc()), flush=True)
            else:
                print(_render_plan(plan), flush=True)
            if not apply_mode:
                return 0
            _time.sleep(args.watch)
    except KeyboardInterrupt:
        return 0
    finally:
        scaler.shutdown()
        close = getattr(broker, "close", None)
        if close is not None:
            close()


def merlin_dlq_main(argv=None):
    """``merlin-dlq``: inspect and drain dead-letter queues.

    ``list`` shows every ``dlq.*`` queue with its depth; ``show`` leases
    the parked tasks, prints them, and releases them back (their
    redelivery count ticks up — the broker protocol has no peek);
    ``requeue`` feeds each task back to its original queue with a fresh
    retry budget, putting BEFORE acking the DLQ lease so a crash
    mid-requeue duplicates (harmless, once-markers) instead of losing."""
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve merlin-dlq",
        description="List, inspect, or requeue dead-lettered tasks.")
    ap.add_argument("--broker", required=True,
                    help="broker URL: tcp://host:port, file://dir, "
                         "shard://..., or shard+file://announce-path")
    ap.add_argument("action", choices=("list", "show", "requeue"))
    ap.add_argument("--queue", default=None,
                    help="operate on one original queue (its dlq.<queue>); "
                         "default: every dlq.* queue the broker reports")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output, one object per line")
    args = ap.parse_args(argv)

    from repro.core.netbroker import make_broker
    from repro.core.queue import (Task, dlq_queue_name, is_dlq,
                                  original_queue)
    broker = make_broker(args.broker)
    try:
        if args.queue is not None:
            dlqs = [dlq_queue_name(args.queue)]
        else:
            dlqs = sorted(q for q in broker.queue_names() if is_dlq(q))

        if args.action == "list":
            rows = [{"queue": q, "original": original_queue(q),
                     "depth": broker.qsize((q,))} for q in dlqs]
            if args.json:
                for r in rows:
                    print(json.dumps(r), flush=True)
            elif not rows:
                print("(no dead-letter queues)")
            else:
                for r in rows:
                    print(f"{r['queue']:<28} {r['depth']:>6} task(s) "
                          f"-> {r['original']}")
            return 0

        n_seen = 0
        for q in dlqs:
            # hold every lease until the queue is drained — nacking
            # mid-drain would make the same task visible again and spin
            held = []
            while True:
                leases = broker.get_many(64, timeout=0.2, queues=(q,))
                if not leases:
                    break
                for lease in leases:
                    t = lease.task
                    n_seen += 1
                    if args.action == "requeue":
                        # fresh retry budget; put-then-ack so a crash here
                        # duplicates instead of losing the task
                        broker.put(Task(id=t.id, kind=t.kind,
                                        payload=dict(t.payload),
                                        priority=t.priority,
                                        queue=original_queue(t.queue)))
                        broker.ack(lease.tag)
                    else:
                        held.append(lease)
                    info = {"queue": q, "id": t.id, "kind": t.kind,
                            "retries": t.retries,
                            "study": t.payload.get("study")
                            if isinstance(t.payload, dict) else None,
                            "requeued": args.action == "requeue"}
                    if args.json:
                        print(json.dumps(info), flush=True)
                    else:
                        verb = "requeued" if args.action == "requeue" \
                            else "parked"
                        print(f"{verb} {t.id} ({t.kind}, retries="
                              f"{t.retries}) {q} -> "
                              f"{original_queue(q)}")
            # release the inspection leases (no peek in the protocol;
            # their redelivery count ticks up)
            for lease in held:
                broker.nack(lease.tag)
        if not args.json:
            verb = "requeued" if args.action == "requeue" else "shown"
            print(f"{n_seen} task(s) {verb}")
        return 0
    finally:
        close = getattr(broker, "close", None)
        if close is not None:
            close()


def merlin_validate_main(argv=None):
    """``merlin-validate``: load each workflow spec and compile it into its
    task DAG, surfacing structural errors (cycles, unknown dependencies,
    unequal %zip lists, unsatisfiable edges) without executing anything.
    Exit status 1 if any spec fails — CI gates on it."""
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve merlin-validate",
        description="Validate workflow spec files by compiling them into "
                    "task DAGs.")
    ap.add_argument("specs", nargs="+", metavar="SPEC.yaml",
                    help="YAML workflow spec files to validate")
    ap.add_argument("--json", action="store_true",
                    help="one JSON result object per spec")
    args = ap.parse_args(argv)

    from repro.core.dag import compile_dag
    from repro.core.spec import SpecError, StudySpec
    failures = 0
    for path in args.specs:
        try:
            with open(path) as f:
                spec = StudySpec.from_yaml(f.read())
            dag = compile_dag(spec)
            info = {"spec": path, "ok": True, "name": spec.name,
                    "nodes": [n.name for n in dag.nodes],
                    "instances": len(list(dag.all_instances()))}
        except (OSError, SpecError, ValueError) as e:
            failures += 1
            info = {"spec": path, "ok": False, "error": str(e)}
        if args.json:
            print(json.dumps(info), flush=True)
        elif info["ok"]:
            print(f"OK   {path}: {info['name']} — "
                  f"{len(info['nodes'])} node(s) "
                  f"[{', '.join(info['nodes'])}], "
                  f"{info['instances']} instance(s)")
        else:
            print(f"FAIL {path}: {info['error']}")
    return 1 if failures else 0


def merlin_serve_main(argv=None):
    """``merlin-serve``: HTTP gateway over a study's surrogate ensemble.

    Trains a resident snapshot from the study archive's bundled rows,
    then serves predict/calibrate/what-if with continuous batching
    (see repro/serve/gateway.py).  SIGINT/SIGTERM triggers a graceful
    drain: new requests get 503, admitted requests complete.
    """
    ap = argparse.ArgumentParser(prog="merlin-serve")
    ap.add_argument("--study", required=True, metavar="DIR",
                    help="study archive root (the Bundler directory "
                         "holding the training bundles)")
    ap.add_argument("--objective-key", default="yield")
    ap.add_argument("--input-key", default="inputs")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks a free port (published via --port-file)")
    ap.add_argument("--port-file", default=None,
                    help="atomically publish the bound port to this path")
    ap.add_argument("--max-inflight", type=int, default=64,
                    help="admission queue bound; requests beyond it are "
                         "shed with 429 before admission")
    ap.add_argument("--max-batch-rows", type=int, default=256)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="default per-request deadline (504 when it "
                         "passes while queued); requests can override")
    ap.add_argument("--members", type=int, default=3)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--refresh-s", type=float, default=None,
                    help="poll the archive for new rows every S seconds "
                         "and fold them into the snapshot")
    ap.add_argument("--naive", action="store_true",
                    help="flush-per-request baseline mode (benchmark A/B)")
    args = ap.parse_args(argv)

    from repro import env as repro_env
    repro_env.configure()

    import signal
    import threading
    from repro.core.active import SurrogateSnapshot
    from repro.serve.gateway import SurrogateGateway

    try:
        snap = SurrogateSnapshot(args.study,
                                 objective_key=args.objective_key,
                                 input_key=args.input_key,
                                 n_members=args.members,
                                 hidden=args.hidden, steps=args.steps)
    except ValueError as e:
        ap.error(str(e))  # e.g. archive has no training rows yet
    gw = SurrogateGateway(snap, host=args.host, port=args.port,
                          max_inflight=args.max_inflight,
                          max_batch_rows=args.max_batch_rows,
                          default_deadline_ms=args.deadline_ms,
                          refresh_s=args.refresh_s,
                          naive=args.naive).start()
    print(json.dumps({"event": "listening", "host": args.host,
                      "port": gw.port, "study": args.study,
                      "rows": snap.rows, "version": snap.version,
                      "mode": "naive" if args.naive else "continuous",
                      "auth": gw.auth_token is not None}), flush=True)
    if args.port_file:
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(gw.port))
        os.rename(tmp, args.port_file)

    stop = threading.Event()
    for sig in (signal.SIGINT, signal.SIGTERM):
        signal.signal(sig, lambda *_: stop.set())
    try:
        stop.wait()
    finally:
        drained = gw.stop(drain=True)
        print(json.dumps({"event": "drained", "clean": bool(drained),
                          "stats": gw.stats()}), flush=True)
    return 0


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "merlin-serve":
        return merlin_serve_main(argv[1:])
    if argv and argv[0] == "broker-serve":
        return broker_serve_main(argv[1:])
    if argv and argv[0] == "merlin-status":
        return merlin_status_main(argv[1:])
    if argv and argv[0] == "merlin-validate":
        return merlin_validate_main(argv[1:])
    if argv and argv[0] == "merlin-dlq":
        return merlin_dlq_main(argv[1:])
    if argv and argv[0] == "merlin-scale":
        return merlin_scale_main(argv[1:])
    return llm_serve_main(argv)


def llm_serve_main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro.configs import registry
    from repro.models import lm
    from repro.serve.engine import ServeEngine

    cfg = (registry.reduced_config(args.arch) if args.reduced
           else registry.get_config(args.arch))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.new_tokens + 8)
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (args.batch, args.prompt_len), 0,
                              cfg.vocab_size)
    extra = {}
    if cfg.n_enc_layers:
        extra["enc_embed"] = jnp.zeros((args.batch, cfg.enc_len, cfg.d_model),
                                       jnp.bfloat16)
    if cfg.n_img_tokens:
        extra["img_embed"] = jnp.zeros(
            (args.batch, cfg.n_img_tokens, cfg.d_vision), jnp.bfloat16)
    out = eng.generate(toks, args.new_tokens, extra=extra)
    s = eng.stats
    print(json.dumps({
        "arch": cfg.arch_id, "batch": args.batch,
        "prefill_tok_per_s": round(s["prefill_tokens"] / max(s["prefill_s"], 1e-9)),
        "decode_tok_per_s": round(s["decode_tokens"] / max(s["decode_s"], 1e-9)),
        "generated_shape": list(out.shape),
    }, indent=1))


if __name__ == "__main__":
    sys.exit(main())
