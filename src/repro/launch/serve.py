"""Serving launcher: batched prefill + decode with throughput report.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --reduced \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import json


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro.configs import registry
    from repro.models import lm
    from repro.serve.engine import ServeEngine

    cfg = (registry.reduced_config(args.arch) if args.reduced
           else registry.get_config(args.arch))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.new_tokens + 8)
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (args.batch, args.prompt_len), 0,
                              cfg.vocab_size)
    extra = {}
    if cfg.n_enc_layers:
        extra["enc_embed"] = jnp.zeros((args.batch, cfg.enc_len, cfg.d_model),
                                       jnp.bfloat16)
    if cfg.n_img_tokens:
        extra["img_embed"] = jnp.zeros(
            (args.batch, cfg.n_img_tokens, cfg.d_vision), jnp.bfloat16)
    out = eng.generate(toks, args.new_tokens, extra=extra)
    s = eng.stats
    print(json.dumps({
        "arch": cfg.arch_id, "batch": args.batch,
        "prefill_tok_per_s": round(s["prefill_tokens"] / max(s["prefill_s"], 1e-9)),
        "decode_tok_per_s": round(s["decode_tokens"] / max(s["decode_s"], 1e-9)),
        "generated_shape": list(out.shape),
    }, indent=1))


if __name__ == "__main__":
    main()
