import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count at first init.
# This flag lives ONLY here (and in subprocesses spawned from here) so smoke
# tests and benchmarks keep seeing one real device.
#
# Multi-pod dry-run: AOT lower + compile every (arch x shape) cell on the
# production mesh, prove it fits (memory_analysis) and extract the roofline
# inputs (cost_analysis + collective bytes parsed from the partitioned HLO).
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

import argparse
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.parallel import sharding as shd
from repro.serve.engine import make_decode_fn, make_prefill_fn, _extra_keys
from repro.train.optimizer import make_optimizer
from repro.train.trainstep import init_state, make_train_step

CACHE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------

def _rules(arch: str, mesh) -> Dict:
    r = dict(shd.DEFAULT_RULES)
    over = registry.arch_rules(arch)
    if over:
        r.update(over)
    return {k: tuple(a for a in v if a in mesh.shape) for k, v in r.items()}


def batch_shardings(cfg: ModelConfig, specs: Dict[str, jax.ShapeDtypeStruct],
                    mesh, rules) -> Dict[str, NamedSharding]:
    out = {}
    for k, v in specs.items():
        logical = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, shd.spec_for(v.shape, logical, mesh, rules))
    return out


def cache_shardings(cfg: ModelConfig, caches_sds, mesh, rules):
    tp = rules.get("tensor", ())
    tp_size = 1
    for a in tp:
        tp_size *= mesh.shape[a]

    def f(path, leaf):
        keys = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        name = keys[-1]
        shape = leaf.shape
        stacked = any(k == "blocks" for k in keys)
        rank = len(shape) - (1 if stacked else 0)
        if name in ("k", "v", "xk", "xv"):
            kvh = shape[-2]
            if tp_size > 1 and kvh % tp_size == 0:
                logical = ("batch", None, "kv_heads", None)
            else:
                logical = ("batch", "kv_seq", None, None)
        elif name in ("latent", "k_rope"):
            logical = ("batch", "kv_seq", None)
        elif name == "ssm":
            logical = ("batch", "heads", None, None)
        elif name == "state":
            logical = ("batch", "heads", None, None)
        elif name == "conv":
            logical = ("batch", None, "tensor")
        elif name in ("shift_t", "shift_c"):
            logical = ("batch", None, None)
        else:  # pos and misc scalars
            logical = (None,) * rank
        if stacked:
            logical = (None,) + tuple(logical)
        logical = logical[:len(shape)]
        return NamedSharding(mesh, shd.spec_for(shape, logical, mesh, rules))

    return jax.tree_util.tree_map_with_path(f, caches_sds)


# ---------------------------------------------------------------------------
# lowering per workload kind
# ---------------------------------------------------------------------------

def lower_cell(arch: str, shape_name: str, mesh, cfg: Optional[ModelConfig] = None):
    cfg = cfg or registry.get_config(arch)
    shape = SHAPES[shape_name]
    rules = _rules(arch, mesh)
    specs = registry.input_specs(cfg, shape, abstract=True)
    b_sh = batch_shardings(cfg, specs, mesh, rules)

    params_sds = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    params_sh = shd.param_spec_tree(params_sds, mesh,
                                    registry.arch_rules(arch))

    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer)
        state_sds = jax.eval_shape(
            lambda: init_state(jax.random.PRNGKey(0), cfg, opt))
        state_sh = type(state_sds)(
            params_sh,
            _opt_shardings(state_sds.opt, mesh, rules),
            NamedSharding(mesh, P()))
        step = make_train_step(cfg, opt, mesh=mesh, rules=registry.arch_rules(arch))
        jitted = jax.jit(step, in_shardings=(state_sh, b_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        lowered = jitted.lower(state_sds, specs)
    elif shape.kind == "prefill":
        fn = make_prefill_fn(cfg, max_len=shape.seq_len, mesh=mesh,
                             rules=registry.arch_rules(arch),
                             cache_dtype=CACHE_DTYPE)
        args = [specs["tokens"]] + [specs[k] for k in _extra_keys(cfg)]
        in_sh = [b_sh["tokens"]] + [b_sh[k] for k in _extra_keys(cfg)]
        jitted = jax.jit(fn, in_shardings=(params_sh, *in_sh))
        lowered = jitted.lower(params_sds, *args)
    else:  # decode
        caches_sds = jax.eval_shape(
            lambda: lm.init_caches(cfg, shape.global_batch, shape.seq_len,
                                   CACHE_DTYPE))
        caches_sh = cache_shardings(cfg, caches_sds, mesh, rules)
        fn = make_decode_fn(cfg, mesh=mesh, rules=registry.arch_rules(arch))
        jitted = jax.jit(fn, in_shardings=(params_sh, b_sh["token"], caches_sh),
                         out_shardings=(None, caches_sh),
                         donate_argnums=(2,))
        lowered = jitted.lower(params_sds, specs["token"], caches_sds)
    return lowered


def _opt_shardings(opt_sds, mesh, rules):
    def f(path, leaf):
        keys = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        return NamedSharding(mesh, shd.param_spec(keys, leaf.shape, mesh, rules))
    return jax.tree_util.tree_map_with_path(f, opt_sds)


# ---------------------------------------------------------------------------
# analysis extraction
# ---------------------------------------------------------------------------

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in (partitioned) HLO text."""
    out = {k: 0 for k in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        for kind in COLLECTIVES:
            # match "= TYPE[dims] kind(" or "kind-start("
            m = re.search(rf"=\s+(\S+)\s+{kind}(?:-start)?\(([^)]*)\)", s)
            if m is None:
                continue
            operands = m.group(2)
            b = sum(_shape_bytes(t) for t in re.findall(r"\w+\[[\d,]*\]",
                                                        operands))
            if b == 0:  # operand list may omit shapes; use result shape
                b = _shape_bytes(m.group(1).split("(")[0])
                # tuple results: sum inner shapes
                if b == 0:
                    b = sum(_shape_bytes(t) for t in
                            re.findall(r"\w+\[[\d,]*\]", m.group(1)))
            out[kind] += b
            out["count"] += 1
            break
    return out


def analyze(lowered, compile_=True) -> Dict[str, Any]:
    info: Dict[str, Any] = {}
    t0 = time.time()
    compiled = lowered.compile()
    info["compile_s"] = round(time.time() - t0, 1)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    info["flops"] = float(ca.get("flops", 0.0))
    info["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            info["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "generated_code_bytes": int(ma.generated_code_size_in_bytes),
                "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
            }
    except Exception as e:  # pragma: no cover
        info["memory_error"] = str(e)
    info["collectives"] = collective_bytes(compiled.as_text())
    return info


def _probe_cfg(cfg: ModelConfig, n_rep: int) -> ModelConfig:
    """Reduced-depth probe for loop-trip-count reconstruction.

    XLA's cost_analysis counts a while-loop (lax.scan) body ONCE, so the
    scanned-layer flops/bytes/collectives must be reconstructed: probe with
    n_repeat=1 and 2 (microbatch=1), take the delta as the per-superblock
    cost, and extrapolate to the true depth.  Enc-dec configs scale the
    encoder depth alongside so its scan is reconstructed too.
    """
    over = {"n_repeat": n_rep, "microbatch": 1, "scan_unroll": True,
            "n_layers": len(cfg.prologue) + len(cfg.superblock) * n_rep}
    if cfg.n_enc_layers:
        over["n_enc_layers"] = n_rep
    return cfg.replace(**over)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             cfg: Optional[ModelConfig] = None,
             skip_probes: bool = False) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    cfg = cfg or registry.get_config(arch)
    t0 = time.time()
    lowered = lower_cell(arch, shape_name, mesh, cfg=cfg)
    res = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(str(s) for s in mesh.devices.shape),
           "chips": n_chips, "lower_s": round(time.time() - t0, 1)}
    res.update(analyze(lowered))

    if not skip_probes:
        p1 = analyze(lower_cell(arch, shape_name, mesh, cfg=_probe_cfg(cfg, 1)))
        p2 = analyze(lower_cell(arch, shape_name, mesh, cfg=_probe_cfg(cfg, 2)))
        reps = cfg.n_repeat
        rec = {}
        for key in ("flops", "bytes_accessed"):
            delta = p2[key] - p1[key]
            rec[key] = p1[key] + delta * (reps - 1)
        coll = {}
        for k in COLLECTIVES:
            delta = p2["collectives"][k] - p1["collectives"][k]
            coll[k] = int(p1["collectives"][k] + delta * (reps - 1))
        rec["collectives"] = coll
        rec["probe_compile_s"] = p1["compile_s"] + p2["compile_s"]
        # decomposition: base (embed/logits/loss/optimizer) vs per-superblock
        rec["base_flops"] = 2 * p1["flops"] - p2["flops"]
        rec["layer_flops"] = p2["flops"] - p1["flops"]
        rec["base_bytes"] = 2 * p1["bytes_accessed"] - p2["bytes_accessed"]
        rec["layer_bytes"] = p2["bytes_accessed"] - p1["bytes_accessed"]
        res["reconstructed"] = rec
    res.update(model_flops_info(cfg, SHAPES[shape_name]))
    return res


def model_flops_info(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Analytic MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference),
    the 'useful compute' yardstick for the roofline table."""
    params_sds = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_sds)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if any("experts" in str(getattr(k, "key", k)) for k in path):
            expert += n
    n_active = total - expert
    if cfg.n_experts:
        n_active += expert * cfg.top_k / cfg.n_experts
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return {"n_params": int(total), "n_active_params": int(n_active),
            "model_flops": float(mult * n_active * tokens)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--override", type=str, default=None,
                    help="JSON dict of ModelConfig overrides (perf iteration)")
    ap.add_argument("--cache-dtype", type=str, default=None,
                    help="decode cache dtype override (e.g. int8 KV)")
    args = ap.parse_args(argv)

    if args.cache_dtype:
        global CACHE_DTYPE
        CACHE_DTYPE = jnp.dtype(args.cache_dtype)
    overrides = json.loads(args.override) if args.override else None

    cells = []
    if args.all:
        for arch in registry.ARCHS:
            if arch == "jag-surrogate":
                continue
            cfg = registry.get_config(arch)
            for s in SHAPES.values():
                if shape_applicable(arch, s.name, cfg.family):
                    cells.append((arch, s.name))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        print(f"=== dry-run {arch} x {shape} "
              f"({'multi-pod 2x16x16' if args.multi_pod else 'single-pod 16x16'}) ===",
              flush=True)
        try:
            cfg = None
            if overrides:
                cfg = registry.get_config(arch).replace(**overrides)
            res = run_cell(arch, shape, args.multi_pod, cfg=cfg)
            if overrides:
                res["overrides"] = overrides
            res["ok"] = True
            print(json.dumps(res, indent=1), flush=True)
        except Exception as e:
            res = {"arch": arch, "shape": shape, "ok": False,
                   "error": f"{type(e).__name__}: {e}"[:2000]}
            print("FAILED:", res["error"], flush=True)
        results.append(res)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    nok = sum(r["ok"] for r in results)
    print(f"\n{nok}/{len(results)} cells passed")
    return 0 if nok == len(results) else 1


if __name__ == "__main__":
    sys.exit(main())
