"""ExecutionEngine: the shared micro-batching scheduler between lease
pumps and device execution.

Before this module, batching policy lived inside each worker thread: one
``get_many`` lease batch was the largest unit the runtime could fuse into
a single device launch (``execute_real_many``), so the fusion width was
capped by ``batch`` *per worker* — and under a multi-worker pool the
interleaved claims shredded contiguity, so most "batches" degenerated to
per-task launches anyway.  The engine moves that policy into one shared,
testable component:

* **Workers become pure lease pumps.**  A worker leases, submits its real
  fn-step tasks here, waits for the per-task outcomes, and acks/nacks —
  it never calls the executor itself.  Leases stay worker-held, so the
  broker's at-least-once / visibility-timeout story is unchanged.
* **Deadline-based micro-batching.**  Submissions accumulate in a buffer
  that is flushed when it reaches ``max_batch`` tasks or when the oldest
  submission has waited ``max_wait_ms`` — whichever comes first (the
  classic size-or-deadline batching rule).  A flush hands the whole
  buffer to ``MerlinRuntime.execute_real_many``, which coalesces
  compatible tasks (same study/stage/combo, contiguous sample ranges)
  into fused device launches — **across get_many batches, across
  workers, and across queues**, because every worker of a runtime feeds
  the same buffer.
* **Per-task semantics preserved.**  ``execute_real_many`` keeps the
  ``ctx.sub_ranges`` contract (one bundle file + once-marker +
  ``_bundle_done`` per original task).  If a fused flush fails, the
  engine falls back to per-task ``execute_real`` so a poison task
  resolves with *its own* error while its batch-mates complete — the
  worker then acks the survivors and retries/dead-letters only the
  poison task.
* **Observability.**  ``stats()`` reports batches fused, a tasks-per-
  batch histogram, how many flushes were triggered by size vs deadline
  vs an explicit ``flush()``, and the busy fraction (the share of
  wall-clock the engine spent inside fused execution — the scheduler's
  proxy for device utilization; the sample-level view, real vs padded
  device rows, is ``EnsembleExecutor.stats``).

Lifecycle: engines are shared and reference-counted.  ``MerlinRuntime.
shared_engine()`` hands every WorkerPool of a runtime the same instance
(cross-pool coalescing); each pool ``attach()``-es on start and
``detach()``-es on shutdown, and the last detach closes the dispatcher
thread.  ``flush()`` forces the current partial buffer out immediately —
``WorkerPool.drain``/``shutdown`` call it so a partially-filled
micro-batch never strands leased tasks until their visibility timeout.

Tuning ``max_wait_ms``: it is the latency floor a lone task pays for the
chance to be fused.  Keep it well below the broker visibility timeout
and in the order of one device launch (a few ms on CPU); raise it when
many slow pumps feed one engine, lower it toward zero to approximate
per-batch execution.

With ``adaptive=True`` (the default) the engine also tracks an EMA of
submission inter-arrival gaps.  When arrivals are *slower* than
``max_wait_ms`` — i.e. waiting out the full deadline cannot buy extra
fusion because the next task will not arrive in time — the dispatcher
flushes early once the buffer has sat idle for a short grace period
(``max_wait / 4``).  Bursty pumps (gaps well under the window) are
unaffected, so fusion behaviour under load is identical; only the
lone-straggler latency improves.  Such flushes are counted in
``stats()["adaptive_flushes"]``.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence

from repro.core.queue import Task


class EngineClosed(RuntimeError):
    """Submission after the engine's dispatcher has been shut down."""


class PendingTask:
    """A submitted task's completion handle (resolved by the dispatcher).

    ``error`` is None on success, or the exception the task's (fallback,
    per-task) execution raised — the worker maps it to nack/dead-letter.
    """

    __slots__ = ("task", "event", "error")

    def __init__(self, task: Task):
        self.task = task
        self.event = threading.Event()
        self.error: Optional[BaseException] = None

    def done(self) -> bool:
        return self.event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self.event.wait(timeout)

    def _resolve(self, error: Optional[BaseException]) -> None:
        self.error = error
        self.event.set()


class ExecutionEngine:
    """Shared size-or-deadline micro-batching scheduler over one runtime."""

    _GAP_ALPHA = 0.4  # EMA smoothing for submission inter-arrival gaps

    def __init__(self, runtime, max_batch: int = 32,
                 max_wait_ms: float = 8.0, adaptive: bool = True):
        self.runtime = runtime
        self.max_batch = max(1, int(max_batch))
        self.max_wait = max(0.0, float(max_wait_ms) / 1000.0)
        self.adaptive = bool(adaptive)
        self._idle_grace = self.max_wait * 0.25
        self._cv = threading.Condition()
        self._buf: List[PendingTask] = []
        self._deadline: Optional[float] = None
        self._flush_asked = False
        self._closed = False
        self._refs = 0
        self._thread: Optional[threading.Thread] = None
        self._t0: Optional[float] = None  # first submission (uptime clock)
        self._last_submit: Optional[float] = None
        self._ema_gap: Optional[float] = None
        self._stats: Dict[str, object] = {
            "submitted": 0, "executed": 0, "failed_tasks": 0,
            "batches": 0, "size_flushes": 0, "deadline_flushes": 0,
            "forced_flushes": 0, "adaptive_flushes": 0, "max_batch_seen": 0,
            "exec_s": 0.0, "batch_hist": {},
        }

    # -- lifecycle -----------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def refs(self) -> int:
        """How many users (WorkerPools) are currently attached."""
        with self._cv:
            return self._refs

    def buffered(self) -> int:
        """Tasks currently waiting in the micro-batch buffer (cheap,
        local — lets drain loops avoid broker round-trips when there is
        nothing to flush anyway)."""
        with self._cv:
            return len(self._buf)

    def attach(self) -> "ExecutionEngine":
        """Reference-count a user (a WorkerPool); pair with detach()."""
        with self._cv:
            if self._closed:
                raise EngineClosed("cannot attach to a closed engine")
            self._refs += 1
        return self

    def detach(self) -> None:
        """Drop one reference; the last detach closes the dispatcher."""
        with self._cv:
            self._refs -= 1
            last = self._refs <= 0
        if last:
            self.close()

    def close(self, timeout: float = 10.0) -> None:
        """Flush whatever is buffered, then stop the dispatcher thread."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
        # belt-and-braces: the dispatcher drains the buffer before exiting,
        # but if it died (or never ran), nobody may wait forever on us
        with self._cv:
            leftovers, self._buf = self._buf, []
        for p in leftovers:
            p._resolve(EngineClosed("engine closed before execution"))

    def _ensure_thread_locked(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._loop, daemon=True, name="merlin-exec-engine")
            self._thread.start()

    # -- submission ----------------------------------------------------------
    def submit(self, task: Task) -> PendingTask:
        return self.submit_many([task])[0]

    def submit_many(self, tasks: Sequence[Task]) -> List[PendingTask]:
        """Queue tasks for fused execution; returns per-task handles.

        The caller (a worker holding the leases) waits on the handles and
        acks/nacks per task — the engine never touches the broker."""
        pendings = [PendingTask(t) for t in tasks]
        if not pendings:
            return pendings
        with self._cv:
            if self._closed:
                raise EngineClosed("engine is closed")
            self._ensure_thread_locked()
            now = time.monotonic()
            if self._t0 is None:
                self._t0 = now
            if self._last_submit is not None:
                gap = now - self._last_submit
                self._ema_gap = gap if self._ema_gap is None else (
                    self._GAP_ALPHA * gap
                    + (1.0 - self._GAP_ALPHA) * self._ema_gap)
            self._last_submit = now
            if not self._buf:
                self._deadline = now + self.max_wait
            self._buf.extend(pendings)
            self._stats["submitted"] += len(pendings)
            self._cv.notify_all()
        return pendings

    def flush(self) -> None:
        """Dispatch the current partial buffer without waiting for the
        deadline (drain/shutdown path).

        The request is STICKY when the buffer is empty: a worker may hold
        leased-but-not-yet-submitted tasks at the instant shutdown calls
        this (the lease->submit window), and dropping the request would
        strand that batch — the worker parks on its handles for the full
        deadline while shutdown's join times out.  Persisting the flag
        makes the next submitted batch dispatch immediately; it is
        cleared the moment a dispatch empties the buffer."""
        with self._cv:
            self._flush_asked = True
            self._cv.notify_all()

    # -- dispatcher ----------------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._buf and not self._closed:
                    self._cv.wait()
                if not self._buf and self._closed:
                    return
                # size-or-deadline wait (closed/flush cut it short); with
                # adaptation, a buffer whose feed has gone quiet flushes
                # after a short idle grace instead of the full window
                while (len(self._buf) < self.max_batch and not self._closed
                       and not self._flush_asked):
                    cutoff = self._deadline
                    if (self.adaptive and self._ema_gap is not None
                            and self._ema_gap > self.max_wait
                            and self._last_submit is not None):
                        cutoff = min(cutoff,
                                     self._last_submit + self._idle_grace)
                    remaining = cutoff - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cv.wait(remaining)
                if len(self._buf) >= self.max_batch:
                    reason = "size_flushes"
                elif self._flush_asked or self._closed:
                    reason = "forced_flushes"
                elif time.monotonic() < self._deadline:
                    reason = "adaptive_flushes"
                else:
                    reason = "deadline_flushes"
                batch = self._buf[:self.max_batch]
                self._buf = self._buf[self.max_batch:]
                if self._buf:
                    # the remainder was submitted later: restart its clock
                    self._deadline = time.monotonic() + self.max_wait
                else:
                    self._flush_asked = False
            self._execute(batch, reason)

    def _execute(self, batch: List[PendingTask], reason: str) -> None:
        t0 = time.monotonic()
        # a handle must NEVER resolve as success unless its task's
        # execution actually returned — tasks left at this default (e.g.
        # a step fn raising SystemExit aborts both attempts below) come
        # back as failures, so the worker nacks them for redelivery
        # instead of acking work that never ran (at-least-once preserved)
        outcomes: List[Optional[BaseException]] = [
            RuntimeError("engine dispatcher aborted before this task "
                         "executed")] * len(batch)
        try:
            try:
                self.runtime.execute_real_many([p.task for p in batch])
                outcomes = [None] * len(batch)
            except BaseException:
                # fused path failed: isolate the poison task by re-running
                # per task (already-completed tasks no-op on once-markers)
                for i, p in enumerate(batch):
                    try:
                        self.runtime.execute_real(p.task)
                        outcomes[i] = None
                    except BaseException as e:
                        outcomes[i] = e
        finally:
            dt = time.monotonic() - t0
            failed = sum(1 for e in outcomes if e is not None)
            with self._cv:
                s = self._stats
                s["batches"] += 1
                s[reason] += 1
                s["executed"] += len(batch)
                s["failed_tasks"] += failed
                s["max_batch_seen"] = max(s["max_batch_seen"], len(batch))
                s["exec_s"] += dt
                hist = s["batch_hist"]
                hist[len(batch)] = hist.get(len(batch), 0) + 1
            # resolve OUTSIDE the lock, always — a handle left unresolved
            # would hang its worker forever
            for p, err in zip(batch, outcomes):
                p._resolve(err)

    # -- observability -------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        """Counters plus derived figures (see module docstring)."""
        with self._cv:
            s = dict(self._stats)
            s["batch_hist"] = dict(s["batch_hist"])
            s["buffered"] = len(self._buf)
            s["ema_gap_ms"] = (self._ema_gap * 1000.0
                               if self._ema_gap is not None else None)
            t0 = self._t0
        s["avg_batch"] = (s["executed"] / s["batches"]) if s["batches"] else 0.0
        up = (time.monotonic() - t0) if t0 is not None else 0.0
        s["uptime_s"] = up
        s["utilization"] = (s["exec_s"] / up) if up > 0 else 0.0
        return s

    def __enter__(self) -> "ExecutionEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
