"""ExecutionEngine: the shared micro-batching scheduler between lease
pumps and device execution.

Before this module, batching policy lived inside each worker thread: one
``get_many`` lease batch was the largest unit the runtime could fuse into
a single device launch (``execute_real_many``), so the fusion width was
capped by ``batch`` *per worker* — and under a multi-worker pool the
interleaved claims shredded contiguity, so most "batches" degenerated to
per-task launches anyway.  The engine moves that policy into one shared,
testable component:

* **Workers become pure lease pumps.**  A worker leases, submits its real
  fn-step tasks here, waits for the per-task outcomes, and acks/nacks —
  it never calls the executor itself.  Leases stay worker-held, so the
  broker's at-least-once / visibility-timeout story is unchanged.
* **Deadline-based micro-batching.**  Submissions accumulate in a buffer
  that is flushed when it reaches ``max_batch`` tasks or when the oldest
  submission has waited ``max_wait_ms`` — whichever comes first (the
  classic size-or-deadline batching rule).  A flush hands the whole
  buffer to ``MerlinRuntime.execute_real_many``, which coalesces
  compatible tasks (same study/stage/combo, contiguous sample ranges)
  into fused device launches — **across get_many batches, across
  workers, and across queues**, because every worker of a runtime feeds
  the same buffer.
* **Per-task semantics preserved.**  ``execute_real_many`` keeps the
  ``ctx.sub_ranges`` contract (one bundle file + once-marker +
  ``_bundle_done`` per original task).  If a fused flush fails, the
  engine falls back to per-task ``execute_real`` so a poison task
  resolves with *its own* error while its batch-mates complete — the
  worker then acks the survivors and retries/dead-letters only the
  poison task.
* **Observability.**  ``stats()`` reports batches fused, a tasks-per-
  batch histogram, how many flushes were triggered by size vs deadline
  vs an explicit ``flush()``, and the busy fraction (the share of
  wall-clock the engine spent inside fused execution — the scheduler's
  proxy for device utilization; the sample-level view, real vs padded
  device rows, is ``EnsembleExecutor.stats``).

Lifecycle: engines are shared and reference-counted.  ``MerlinRuntime.
shared_engine()`` hands every WorkerPool of a runtime the same instance
(cross-pool coalescing); each pool ``attach()``-es on start and
``detach()``-es on shutdown, and the last detach closes the dispatcher
thread.  ``flush()`` forces the current partial buffer out immediately —
``WorkerPool.drain``/``shutdown`` call it so a partially-filled
micro-batch never strands leased tasks until their visibility timeout.

Tuning ``max_wait_ms``: it is the latency floor a lone task pays for the
chance to be fused.  Keep it well below the broker visibility timeout
and in the order of one device launch (a few ms on CPU); raise it when
many slow pumps feed one engine, lower it toward zero to approximate
per-batch execution.

With ``adaptive=True`` (the default) the engine also tracks an EMA of
submission inter-arrival gaps.  When arrivals are *slower* than
``max_wait_ms`` — i.e. waiting out the full deadline cannot buy extra
fusion because the next task will not arrive in time — the dispatcher
flushes early once the buffer has sat idle for a short grace period
(``max_wait / 4``).  Bursty pumps (gaps well under the window) are
unaffected, so fusion behaviour under load is identical; only the
lone-straggler latency improves.  Such flushes are counted in
``stats()["adaptive_flushes"]``.

Two further scheduler policies live here:

* **Affinity-keyed batching.**  Each pending task carries an affinity
  key (``runtime.affinity_key(task)``, ``(study, simulator)`` for real
  runtimes) and a dispatch only ever takes tasks sharing the key of the
  oldest buffered task — two studies' bundles never interleave inside
  one fused launch, which would otherwise shred ``execute_real_many``'s
  contiguity grouping into per-study fragments of a half-empty batch.

* **Write pipelining.**  When the runtime offers
  ``execute_real_many_deferred``, device compute is dispatched on the
  engine thread while the host-side completion (``block_until_ready`` +
  bundle writes + once-markers) runs on a single writer thread — so the
  dispatch of batch N+1 overlaps the write of batch N.  Handles still
  resolve only after the durable write (ack-after-durable is preserved);
  ``stats()["write_overlap_s"]`` reports how much write time was hidden
  behind concurrent dispatch.

:class:`ContinuousBatcher` (bottom of this module) is the engine's
serving-side sibling: instead of leased workflow tasks it batches
latency-sensitive inference *requests* — admitted continuously at
power-of-two bucket boundaries, deadline-ordered, with a bounded
admission queue that sheds load as ``BrokerFull``.  The HTTP gateway
(``repro.serve.gateway``) fronts it.
"""
from __future__ import annotations

import heapq
import math
import threading
import time
from queue import Queue
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.queue import BrokerFull, Task


class EngineClosed(RuntimeError):
    """Submission after the engine's dispatcher has been shut down."""


class DeadlineExpired(RuntimeError):
    """A serve request's deadline passed before it was admitted to a
    batch; it was dropped without executing (the gateway maps it to 504)."""


class PendingTask:
    """A submitted task's completion handle (resolved by the dispatcher).

    ``error`` is None on success, or the exception the task's (fallback,
    per-task) execution raised — the worker maps it to nack/dead-letter.
    """

    __slots__ = ("task", "event", "error", "key", "submitted_at")

    def __init__(self, task: Task, key=None):
        self.task = task
        self.event = threading.Event()
        self.error: Optional[BaseException] = None
        self.key = key  # affinity bucket: tasks only batch with key-mates
        self.submitted_at: float = 0.0

    def done(self) -> bool:
        return self.event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self.event.wait(timeout)

    def _resolve(self, error: Optional[BaseException]) -> None:
        self.error = error
        self.event.set()


class ExecutionEngine:
    """Shared size-or-deadline micro-batching scheduler over one runtime."""

    _GAP_ALPHA = 0.4  # EMA smoothing for submission inter-arrival gaps

    def __init__(self, runtime, max_batch: int = 32,
                 max_wait_ms: float = 8.0, adaptive: bool = True):
        self.runtime = runtime
        self.max_batch = max(1, int(max_batch))
        self.max_wait = max(0.0, float(max_wait_ms) / 1000.0)
        self.adaptive = bool(adaptive)
        self._idle_grace = self.max_wait * 0.25
        self._cv = threading.Condition()
        self._buf: List[PendingTask] = []
        self._deadline: Optional[float] = None
        self._flush_asked = False
        self._closed = False
        self._refs = 0
        self._thread: Optional[threading.Thread] = None
        self._t0: Optional[float] = None  # first submission (uptime clock)
        self._last_submit: Optional[float] = None
        self._ema_gap: Optional[float] = None
        # write pipeline: the dispatcher hands (batch, finalize) pairs to a
        # single writer thread so host syncs + bundle writes overlap the
        # next batch's device dispatch.  Bounded: the dispatcher stalls
        # when the writer falls more than two batches behind.
        self._wq: Optional[Queue] = None
        self._writer: Optional[threading.Thread] = None
        self._busy_since: Optional[float] = None  # dispatch-in-progress mark
        self._busy_accum = 0.0  # completed dispatch time (overlap metric)
        self._stats: Dict[str, object] = {
            "submitted": 0, "executed": 0, "failed_tasks": 0,
            "batches": 0, "size_flushes": 0, "deadline_flushes": 0,
            "forced_flushes": 0, "adaptive_flushes": 0, "max_batch_seen": 0,
            "exec_s": 0.0, "batch_hist": {}, "affinity_splits": 0,
            "deferred_batches": 0, "write_s": 0.0, "write_overlap_s": 0.0,
        }

    # -- lifecycle -----------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def refs(self) -> int:
        """How many users (WorkerPools) are currently attached."""
        with self._cv:
            return self._refs

    def buffered(self) -> int:
        """Tasks currently waiting in the micro-batch buffer (cheap,
        local — lets drain loops avoid broker round-trips when there is
        nothing to flush anyway)."""
        with self._cv:
            return len(self._buf)

    def attach(self) -> "ExecutionEngine":
        """Reference-count a user (a WorkerPool); pair with detach()."""
        with self._cv:
            if self._closed:
                raise EngineClosed("cannot attach to a closed engine")
            self._refs += 1
        return self

    def detach(self) -> None:
        """Drop one reference; the last detach closes the dispatcher."""
        with self._cv:
            self._refs -= 1
            last = self._refs <= 0
        if last:
            self.close()

    def close(self, timeout: float = 10.0) -> None:
        """Flush whatever is buffered, then stop the dispatcher thread."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
        if self._writer is not None:
            self._wq.put(None)  # sentinel after the dispatcher drained
            self._writer.join(timeout=timeout)
        # belt-and-braces: the dispatcher drains the buffer before exiting,
        # but if it died (or never ran), nobody may wait forever on us
        with self._cv:
            leftovers, self._buf = self._buf, []
        for p in leftovers:
            p._resolve(EngineClosed("engine closed before execution"))

    def _ensure_thread_locked(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._loop, daemon=True, name="merlin-exec-engine")
            self._thread.start()

    # -- submission ----------------------------------------------------------
    def submit(self, task: Task) -> PendingTask:
        return self.submit_many([task])[0]

    def submit_many(self, tasks: Sequence[Task]) -> List[PendingTask]:
        """Queue tasks for fused execution; returns per-task handles.

        The caller (a worker holding the leases) waits on the handles and
        acks/nacks per task — the engine never touches the broker."""
        keyfn = getattr(self.runtime, "affinity_key", None)
        pendings = [PendingTask(t, keyfn(t) if keyfn is not None else None)
                    for t in tasks]
        if not pendings:
            return pendings
        with self._cv:
            if self._closed:
                raise EngineClosed("engine is closed")
            self._ensure_thread_locked()
            now = time.monotonic()
            for p in pendings:
                p.submitted_at = now
            if self._t0 is None:
                self._t0 = now
            if self._last_submit is not None:
                gap = now - self._last_submit
                self._ema_gap = gap if self._ema_gap is None else (
                    self._GAP_ALPHA * gap
                    + (1.0 - self._GAP_ALPHA) * self._ema_gap)
            self._last_submit = now
            if not self._buf:
                self._deadline = now + self.max_wait
            self._buf.extend(pendings)
            self._stats["submitted"] += len(pendings)
            self._cv.notify_all()
        return pendings

    def flush(self) -> None:
        """Dispatch the current partial buffer without waiting for the
        deadline (drain/shutdown path).

        The request is STICKY when the buffer is empty: a worker may hold
        leased-but-not-yet-submitted tasks at the instant shutdown calls
        this (the lease->submit window), and dropping the request would
        strand that batch — the worker parks on its handles for the full
        deadline while shutdown's join times out.  Persisting the flag
        makes the next submitted batch dispatch immediately; it is
        cleared the moment a dispatch empties the buffer."""
        with self._cv:
            self._flush_asked = True
            self._cv.notify_all()

    # -- dispatcher ----------------------------------------------------------
    def _front_group_locked(self) -> List[PendingTask]:
        """The oldest task's affinity group — the only tasks the next
        dispatch may take (two keys never share a fused launch)."""
        key = self._buf[0].key
        return [p for p in self._buf if p.key == key]

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._buf and not self._closed:
                    self._cv.wait()
                if not self._buf and self._closed:
                    return
                # size-or-deadline wait (closed/flush cut it short); with
                # adaptation, a buffer whose feed has gone quiet flushes
                # after a short idle grace instead of the full window
                while (len(self._front_group_locked()) < self.max_batch
                       and not self._closed and not self._flush_asked):
                    cutoff = self._deadline
                    if (self.adaptive and self._ema_gap is not None
                            and self._ema_gap > self.max_wait
                            and self._last_submit is not None):
                        cutoff = min(cutoff,
                                     self._last_submit + self._idle_grace)
                    remaining = cutoff - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cv.wait(remaining)
                group = self._front_group_locked()
                if len(group) >= self.max_batch:
                    reason = "size_flushes"
                elif self._flush_asked or self._closed:
                    reason = "forced_flushes"
                elif time.monotonic() < self._deadline:
                    reason = "adaptive_flushes"
                else:
                    reason = "deadline_flushes"
                batch = group[:self.max_batch]
                taken = set(map(id, batch))
                self._buf = [p for p in self._buf if id(p) not in taken]
                if self._buf:
                    if (len(batch) < self.max_batch
                            and any(p.key != batch[0].key
                                    for p in self._buf)):
                        # a second study/simulator was waiting: this batch
                        # dispatched short rather than interleave keys
                        self._stats["affinity_splits"] += 1
                    self._deadline = self._buf[0].submitted_at + self.max_wait
                else:
                    self._flush_asked = False
            self._execute(batch, reason)

    def _ensure_writer(self) -> Queue:
        if self._wq is None:
            self._wq = Queue(maxsize=2)  # bounded: dispatch stalls if the
            self._writer = threading.Thread(  # writer falls 2 batches behind
                target=self._writer_loop, daemon=True,
                name="merlin-engine-writer")
            self._writer.start()
        return self._wq

    def _busy_time_locked(self, now: float) -> float:
        """Cumulative dispatch-thread busy seconds up to ``now`` (the
        writer samples this at finalize start/end to measure overlap)."""
        extra = (now - self._busy_since) if self._busy_since is not None \
            else 0.0
        return self._busy_accum + extra

    def _execute(self, batch: List[PendingTask], reason: str) -> None:
        t0 = time.monotonic()
        deferred = getattr(self.runtime, "execute_real_many_deferred", None)
        if deferred is not None:
            # pipelined path: dispatch device compute here, hand the host
            # sync + bundle writes + once-markers (finalize) to the writer
            # thread, and loop straight to the next batch.  Handles resolve
            # only after finalize — ack-after-durable is preserved.
            with self._cv:
                self._busy_since = t0
            finalize = None
            try:
                finalize = deferred([p.task for p in batch])
            except BaseException:
                pass  # compute-stage failure: writer runs per-task fallback
            finally:
                now = time.monotonic()
                with self._cv:
                    self._busy_accum += now - self._busy_since
                    self._busy_since = None
            self._ensure_writer().put((batch, finalize, reason, now - t0))
            return
        outcomes = self._run_fallback_capable(batch, fused=True)
        self._finish(batch, outcomes, reason,
                     exec_dt=time.monotonic() - t0)

    def _run_fallback_capable(
            self, batch: List[PendingTask],
            fused: bool) -> List[Optional[BaseException]]:
        """Execute a batch with per-task isolation on failure.

        A handle must NEVER resolve as success unless its task's execution
        actually returned — tasks left at the default outcome (e.g. a step
        fn raising SystemExit aborts both attempts) come back as failures,
        so the worker nacks them for redelivery instead of acking work
        that never ran (at-least-once preserved)."""
        outcomes: List[Optional[BaseException]] = [
            RuntimeError("engine dispatcher aborted before this task "
                         "executed")] * len(batch)
        try:
            if fused:
                self.runtime.execute_real_many([p.task for p in batch])
                return [None] * len(batch)
        except BaseException:
            pass  # fused path failed: isolate the poison task below
        # per-task retry (already-completed tasks no-op on once-markers)
        for i, p in enumerate(batch):
            try:
                self.runtime.execute_real(p.task)
                outcomes[i] = None
            except BaseException as e:
                outcomes[i] = e
        return outcomes

    def _writer_loop(self) -> None:
        while True:
            item = self._wq.get()
            if item is None:
                return
            batch, finalize, reason, exec_dt = item
            tf0 = time.monotonic()
            with self._cv:
                b0 = self._busy_time_locked(tf0)
            if finalize is not None:
                try:
                    finalize()
                    outcomes: List[Optional[BaseException]] = \
                        [None] * len(batch)
                except BaseException:
                    finalize = None  # fall through to per-task isolation
            if finalize is None:
                outcomes = self._run_fallback_capable(batch, fused=False)
            tf1 = time.monotonic()
            with self._cv:
                # overlap = dispatch-thread busy time during this finalize:
                # the write seconds hidden behind the next batch's compute
                overlap = self._busy_time_locked(tf1) - b0
                self._stats["deferred_batches"] += 1
                self._stats["write_s"] += tf1 - tf0
                self._stats["write_overlap_s"] += max(0.0, overlap)
            self._finish(batch, outcomes, reason,
                         exec_dt=exec_dt + (tf1 - tf0))

    def _finish(self, batch: List[PendingTask],
                outcomes: List[Optional[BaseException]], reason: str,
                exec_dt: float) -> None:
        failed = sum(1 for e in outcomes if e is not None)
        with self._cv:
            s = self._stats
            s["batches"] += 1
            s[reason] += 1
            s["executed"] += len(batch)
            s["failed_tasks"] += failed
            s["max_batch_seen"] = max(s["max_batch_seen"], len(batch))
            s["exec_s"] += exec_dt
            hist = s["batch_hist"]
            hist[len(batch)] = hist.get(len(batch), 0) + 1
        # resolve OUTSIDE the lock, always — a handle left unresolved
        # would hang its worker forever
        for p, err in zip(batch, outcomes):
            p._resolve(err)

    # -- observability -------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        """Counters plus derived figures (see module docstring)."""
        with self._cv:
            s = dict(self._stats)
            s["batch_hist"] = dict(s["batch_hist"])
            s["buffered"] = len(self._buf)
            s["ema_gap_ms"] = (self._ema_gap * 1000.0
                               if self._ema_gap is not None else None)
            t0 = self._t0
        s["avg_batch"] = (s["executed"] / s["batches"]) if s["batches"] else 0.0
        up = (time.monotonic() - t0) if t0 is not None else 0.0
        s["uptime_s"] = up
        s["utilization"] = (s["exec_s"] / up) if up > 0 else 0.0
        return s

    def __enter__(self) -> "ExecutionEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# continuous batching for inference requests (the serving tier)
# ---------------------------------------------------------------------------

def _pow2_bucket(n: int) -> int:
    """Smallest power-of-two >= n (mirrors ``ensemble.bucket_for`` without
    importing the jax-backed module — the batcher itself is pure threads)."""
    if n <= 1:
        return 1
    return 1 << (n - 1).bit_length()


class ServeRequest:
    """One inference request's completion handle.

    Resolved by the batcher thread with either ``result`` set (success),
    or ``error`` holding :class:`DeadlineExpired` / :class:`EngineClosed`
    / the inference exception."""

    __slots__ = ("rows", "deadline", "seq", "event", "result", "error",
                 "submitted_at")

    def __init__(self, rows: np.ndarray, deadline: Optional[float],
                 seq: int):
        self.rows = rows
        self.deadline = deadline  # absolute monotonic time, or None
        self.seq = seq
        self.event = threading.Event()
        self.result = None
        self.error: Optional[BaseException] = None
        self.submitted_at = time.monotonic()

    def done(self) -> bool:
        return self.event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self.event.wait(timeout)

    def _resolve(self, result, error: Optional[BaseException]) -> None:
        self.result = result
        self.error = error
        self.event.set()


class ContinuousBatcher:
    """Continuous micro-batcher for surrogate inference requests.

    The workflow-side :class:`ExecutionEngine` batches by size-or-deadline
    because leased tasks are throughput work: waiting out ``max_wait`` for
    a fuller batch is free.  Serving is the opposite regime — every
    request carries a caller waiting on the wire — so this batcher never
    idles while work is queued.  The loop thread runs back-to-back
    launches; requests that arrive while batch N executes are admitted
    into batch N+1 at the next *bucket boundary* (the same power-of-two
    grid the ensemble jit cache compiles for doubles as the admission
    grid): the batch takes requests in deadline order until adding the
    next one would overflow ``max_batch_rows``, then keeps topping up
    only while the rows still fit inside the bucket the batch already
    pays padding for.  Fusion therefore comes from concurrency (as in
    vLLM's continuous batching), not from waiting — modulo a tiny
    adaptive admission window (``ADMISSION_FRAC`` of the EMA launch
    time, hard-capped at ``ADMISSION_CAP_S``) that lets a fused
    cohort's clients, which all resolved together and turn around one
    scheduler quantum apart, rejoin the same launch instead of
    degenerating into batches of one.

    * **Deadline-ordered admission.**  The queue is a min-heap on each
      request's absolute deadline (no deadline sorts last, FIFO within a
      tie), so under backlog the most urgent requests execute first.
    * **Per-request deadlines.**  A request whose deadline passes while
      still queued resolves with :class:`DeadlineExpired` *without
      executing* — the gateway maps it to 504.
    * **Load shedding.**  ``submit`` raises :class:`~repro.core.queue.
      BrokerFull` (the broker tier's backpressure type — one shed
      vocabulary across the system) when ``max_inflight`` requests are
      already waiting; the gateway maps it to 429.
    * **Naive mode** (``naive=True``) admits exactly one request per
      launch — the flush-per-request baseline the serving benchmark
      A/Bs against.

    ``infer_fn(rows)`` receives a float32 ``(n, d)`` block spanning the
    whole fused batch and may return an array, a tuple of arrays, or a
    dict of arrays, each with leading dimension ``n``; the batcher slices
    the per-request spans back out.
    """

    # adaptive admission window: after the first request of a batch is
    # seen, hold admission open for this fraction of the EMA launch time
    # (hard-capped) so peers mid-turnaround join the same launch.  A
    # zero-wait loop degenerates to one-request batches whenever client
    # turnaround skew rivals the launch time (all of a fused batch's
    # clients resolve together, then trickle back one scheduler quantum
    # apart — the first arrival would launch alone, and the pattern
    # locks in).  Scaling the window to the launch itself keeps the
    # added latency second-order: fast models wait microseconds, slow
    # models amortize a few ms against tens.
    ADMISSION_FRAC = 1.0
    ADMISSION_CAP_S = 0.050
    # the window only engages when there is evidence of concurrency —
    # more than one request already queued, or recent batches fused —
    # so a lone steady client never pays it
    FUSION_ENGAGE = 1.5

    def __init__(self, infer_fn: Callable, max_batch_rows: int = 256,
                 max_inflight: int = 64, naive: bool = False):
        self.infer_fn = infer_fn
        self.max_batch_rows = max(1, int(max_batch_rows))
        self.max_inflight = max(1, int(max_inflight))
        self.naive = bool(naive)
        self._cv = threading.Condition()
        self._heap: list = []  # (deadline-or-inf, seq, ServeRequest)
        self._seq = 0
        self._active = 0  # requests inside the currently-executing batch
        self._launch_ema = 0.0  # EMA of launch seconds (admission window)
        self._fusion_ema = 1.0  # EMA of requests/batch (window trigger)
        self._draining = False
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        self._stats: Dict[str, object] = {
            "submitted": 0, "completed": 0, "failed": 0, "shed": 0,
            "expired": 0, "batches": 0, "rows": 0, "padded_rows": 0,
            "exec_s": 0.0, "batch_requests_hist": {}, "occupancy_hist": {},
        }

    # -- submission ----------------------------------------------------------
    def submit(self, rows, deadline_s: Optional[float] = None) -> ServeRequest:
        """Queue an inference request; returns its completion handle.

        ``deadline_s`` is the per-request latency budget in seconds from
        now; once it passes, a still-queued request is dropped unexecuted.
        Raises ``BrokerFull`` when the admission queue is at
        ``max_inflight`` (shed *before* admission — the queue bound is
        also the worst-case queueing delay bound) and ``EngineClosed``
        when draining or closed."""
        rows = np.asarray(rows, np.float32)
        if rows.ndim != 2 or len(rows) == 0:
            raise ValueError(f"rows must be a non-empty (n, d) block, "
                             f"got shape {rows.shape}")
        deadline = (time.monotonic() + float(deadline_s)
                    if deadline_s is not None else None)
        with self._cv:
            if self._closed or self._draining:
                raise EngineClosed("serve batcher is "
                                   + ("closed" if self._closed
                                      else "draining"))
            if len(self._heap) >= self.max_inflight:
                self._stats["shed"] += 1
                raise BrokerFull(
                    f"admission queue full: {len(self._heap)} requests "
                    f"waiting (max_inflight={self.max_inflight})")
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(
                    target=self._loop, daemon=True,
                    name="merlin-serve-batcher")
                self._thread.start()
            self._seq += 1
            req = ServeRequest(rows, deadline, self._seq)
            key = deadline if deadline is not None else math.inf
            heapq.heappush(self._heap, (key, req.seq, req))
            self._stats["submitted"] += 1
            self._cv.notify_all()
        return req

    # -- batch formation + execution -----------------------------------------
    def _admit_locked(self, now: float):
        """Pop expired requests and the next batch (deadline order)."""
        expired, batch, rows_total = [], [], 0
        while self._heap:
            _, _, req = self._heap[0]
            if req.deadline is not None and req.deadline <= now:
                heapq.heappop(self._heap)
                expired.append(req)
                self._stats["expired"] += 1
                continue
            n = len(req.rows)
            if batch:
                if self.naive:
                    break  # flush-per-request baseline: one request/launch
                # bucket-boundary admission: grow freely up to
                # max_batch_rows, then only while the padding the batch
                # already pays for absorbs the extra rows
                if (rows_total + n > self.max_batch_rows
                        and rows_total + n > _pow2_bucket(rows_total)):
                    break
            heapq.heappop(self._heap)
            batch.append(req)
            rows_total += n
        self._active = len(batch)
        return expired, batch, rows_total

    @staticmethod
    def _slice_out(out, sl: slice):
        if isinstance(out, dict):
            return {k: v[sl] for k, v in out.items()}
        if isinstance(out, (tuple, list)):
            return type(out)(v[sl] for v in out)
        return out[sl]

    def _execute(self, batch: List[ServeRequest], rows_total: int) -> None:
        X = batch[0].rows if len(batch) == 1 else \
            np.concatenate([r.rows for r in batch])
        t0 = time.monotonic()
        resolved: List = []
        try:
            out = self.infer_fn(X)
            lo = 0
            for req in batch:
                resolved.append((req, self._slice_out(
                    out, slice(lo, lo + len(req.rows))), None))
                lo += len(req.rows)
        except BaseException:
            # isolate the poison request: batch-mates still complete
            for req in batch:
                try:
                    resolved.append((req, self.infer_fn(req.rows), None))
                except BaseException as e:
                    resolved.append((req, None, e))
        dt = time.monotonic() - t0
        bucket = _pow2_bucket(rows_total)
        with self._cv:
            self._launch_ema = (dt if self._launch_ema == 0.0
                                else 0.7 * self._launch_ema + 0.3 * dt)
            self._fusion_ema = (0.7 * self._fusion_ema
                                + 0.3 * len(batch))
            s = self._stats
            s["batches"] += 1
            s["rows"] += rows_total
            s["padded_rows"] += bucket - rows_total
            s["exec_s"] += dt
            s["completed"] += sum(1 for _, _, e in resolved if e is None)
            s["failed"] += sum(1 for _, _, e in resolved if e is not None)
            h = s["batch_requests_hist"]
            h[len(batch)] = h.get(len(batch), 0) + 1
            o = s["occupancy_hist"]
            o[bucket] = o.get(bucket, 0) + 1
            self._active = 0
            self._cv.notify_all()  # drain() waiters
        for req, result, err in resolved:
            req._resolve(result, err)

    def _queued_rows_locked(self) -> int:
        return sum(len(r.rows) for _, _, r in self._heap)

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._heap and not self._closed:
                    self._cv.wait()
                if not self._heap and self._closed:
                    return
                window = min(self.ADMISSION_CAP_S,
                             self.ADMISSION_FRAC * self._launch_ema)
                if (window > 0 and not self.naive and not self._closed
                        and (len(self._heap) > 1
                             or self._fusion_ema >= self.FUSION_ENGAGE)):
                    # hold the window only while the queue can still
                    # grow: at max_inflight requests (every closed-loop
                    # client is back; submit would shed anyway) or a full
                    # max_batch_rows there is nothing left to wait for
                    until = time.monotonic() + window
                    while (len(self._heap) < self.max_inflight
                           and self._queued_rows_locked()
                           < self.max_batch_rows
                           and not self._closed):
                        left = until - time.monotonic()
                        if left <= 0:
                            break
                        self._cv.wait(left)
                expired, batch, rows_total = \
                    self._admit_locked(time.monotonic())
            for req in expired:
                req._resolve(None, DeadlineExpired(
                    "deadline passed before admission "
                    f"(queued {time.monotonic() - req.submitted_at:.3f}s)"))
            if batch:
                self._execute(batch, rows_total)

    # -- lifecycle -----------------------------------------------------------
    def drain(self, timeout: float = 30.0) -> bool:
        """Stop admitting new requests (submit raises EngineClosed) and
        wait until every already-admitted request has resolved.  Returns
        True when the queue fully drained within the timeout."""
        deadline = time.monotonic() + timeout
        with self._cv:
            self._draining = True
            self._cv.notify_all()
            while self._heap or self._active:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._cv.wait(min(remaining, 0.1))
        return True

    def close(self, timeout: float = 10.0) -> None:
        """Stop the loop thread; the backlog executes first (pair with
        ``drain()`` for a bounded graceful stop)."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
        with self._cv:
            leftovers, self._heap = [r for _, _, r in self._heap], []
        for req in leftovers:
            req._resolve(None, EngineClosed("batcher closed before "
                                            "execution"))

    # -- observability -------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        with self._cv:
            s = dict(self._stats)
            s["batch_requests_hist"] = dict(s["batch_requests_hist"])
            s["occupancy_hist"] = dict(s["occupancy_hist"])
            s["queued"] = len(self._heap)
        s["avg_requests_per_batch"] = (
            (s["completed"] + s["failed"]) / s["batches"]
            if s["batches"] else 0.0)
        return s

    def __enter__(self) -> "ContinuousBatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
