"""Consistent-hash ring + versioned federation membership.

The static federation hashed ``crc32(queue) % N``: correct while N never
changes, catastrophic the moment it does — every queue's owner moves, so
a shard join/leave means restarting every producer and consumer.  This
module replaces that substrate with the two pieces elastic membership
needs:

* :class:`HashRing` — a deterministic, seedless consistent-hash ring
  with virtual nodes.  Each member key is hashed onto ``vnodes`` points
  of a 64-bit circle; a queue is owned by the member whose point follows
  the queue's hash.  Adding or removing ONE member moves only the keys
  that fall between the affected points — ~K/N of them — instead of all
  of them.  blake2b (not Python ``hash()``) keeps the mapping identical
  across processes, runs, and PYTHONHASHSEED values.

* :class:`Membership` — the versioned membership record persisted into
  the ``shard+file://`` announce file.  Members carry a *slot* (a
  monotonically increasing integer that is never reused), a join
  timestamp and a heartbeat timestamp; the record carries a version that
  bumps on every join/leave/eviction/pin change — clients re-resolve
  routing when the version moves, and lease tags minted under a retired
  slot are fenced exactly like the PR 7 failover epochs.  All writers go
  through :func:`jsonstore.update_json` (fcntl lock sidecar + atomic
  rename), so concurrent joiners/leavers/sweepers on a shared filesystem
  serialize instead of dropping each other's version bumps.

The membership record LAYERS onto the legacy announce format — the
``endpoints``/``n`` keys are kept mirrored (slot -> url), so old readers
(``read_endpoints``, static ``shard+file://`` discovery) keep working on
a membership-managed file.
"""
from __future__ import annotations

import bisect
import hashlib
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import jsonstore

DEFAULT_VNODES = 64


def _hash64(key: str) -> int:
    """Deterministic 64-bit point on the ring (stable across processes)."""
    return int.from_bytes(
        hashlib.blake2b(key.encode("utf-8"), digest_size=8).digest(), "big")


class HashRing:
    """Consistent-hash ring over member keys with virtual nodes.

    Construction is pure: same members (any order) + same ``vnodes`` =>
    same ring on every process, which is the whole routing contract —
    producers and consumers resolve queue ownership independently and
    must agree.
    """

    def __init__(self, members: Iterable[str],
                 vnodes: int = DEFAULT_VNODES):
        self.members: Tuple[str, ...] = tuple(sorted(set(members)))
        self.vnodes = int(vnodes)
        if self.vnodes < 1:
            raise ValueError("vnodes must be >= 1")
        points: List[Tuple[int, str]] = []
        for m in self.members:
            for v in range(self.vnodes):
                points.append((_hash64(f"{m}#{v}"), m))
        points.sort()
        self._points = points
        self._keys = [p[0] for p in points]

    def owner(self, key: str) -> str:
        """The member owning ``key`` (first ring point at/after its hash)."""
        if not self._points:
            raise ValueError("empty ring has no owners")
        i = bisect.bisect_right(self._keys, _hash64(key))
        return self._points[i % len(self._points)][1]

    def owners(self, keys: Sequence[str]) -> Dict[str, str]:
        return {k: self.owner(k) for k in keys}

    def spread(self, keys: Sequence[str]) -> Dict[str, int]:
        """Per-member owned-key counts (every member present, even at 0)."""
        out = {m: 0 for m in self.members}
        for k in keys:
            out[self.owner(k)] += 1
        return out


def moved_keys(old: "HashRing", new: "HashRing",
               keys: Sequence[str]) -> List[str]:
    """The keys whose owner differs between two rings — the movement a
    membership change actually causes.  For a single join/leave on a
    balanced ring this is ~K/N of ``keys`` (the elastic-rebalance bar
    asserts <= 2/N)."""
    return [k for k in keys if not (old.members and new.members)
            or old.owner(k) != new.owner(k)]


# ---------------------------------------------------------------------------
# versioned membership record
# ---------------------------------------------------------------------------

@dataclass
class Membership:
    """A parsed membership record.

    ``members`` maps member url -> {"slot", "joined_at", "heartbeat_at"}.
    Slots are never reused: a member that leaves and rejoins gets a fresh
    slot, so lease tags minted against its previous incarnation stay
    fenced.  ``pins`` maps queue -> member url (operator overrides that
    win over the ring).  ``version`` bumps on every membership or pin
    change — never on heartbeats.
    """
    version: int = 0
    members: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    pins: Dict[str, str] = field(default_factory=dict)
    next_slot: int = 0

    def urls(self) -> List[str]:
        """Member urls in slot order — the stable positional order every
        client derives shard indices from."""
        return [u for u, _ in sorted(self.members.items(),
                                     key=lambda kv: kv[1]["slot"])]

    def slot_of(self, url: str) -> int:
        return int(self.members[url]["slot"])

    def ring(self, vnodes: int = DEFAULT_VNODES) -> HashRing:
        return HashRing(self.members.keys(), vnodes=vnodes)

    def to_doc(self) -> Dict[str, Any]:
        return {"version": self.version, "next_slot": self.next_slot,
                "members": self.members, "pins": self.pins}

    @classmethod
    def from_doc(cls, doc: Dict[str, Any]) -> "Membership":
        return cls(version=int(doc.get("version", 0)),
                   members=dict(doc.get("members", {})),
                   pins=dict(doc.get("pins", {})),
                   next_slot=int(doc.get("next_slot", 0)))


def _membership_from_file_doc(doc: Dict[str, Any]) -> Optional[Membership]:
    if "membership" in doc:
        return Membership.from_doc(doc["membership"])
    eps = doc.get("endpoints")
    if not eps:
        return None
    # legacy announce-only file: synthesize a static membership (version
    # 0, slots = announce indices) so elastic clients can read it too
    indexed = sorted((int(k), u) for k, u in eps.items()
                     if k.lstrip("-").isdigit())
    rest = sorted(u for k, u in eps.items() if not k.lstrip("-").isdigit())
    members: Dict[str, Dict[str, Any]] = {}
    slot = 0
    for _, u in indexed:
        members.setdefault(u, {"slot": slot, "joined_at": 0.0,
                               "heartbeat_at": 0.0})
        slot += 1
    for u in rest:
        if u not in members:
            members[u] = {"slot": slot, "joined_at": 0.0,
                          "heartbeat_at": 0.0}
            slot += 1
    return Membership(version=0, members=members, pins={}, next_slot=slot)


def read_membership(path: str) -> Optional[Membership]:
    """Parse the membership record at ``path`` (None when the file is
    missing/empty).  Legacy announce-only files synthesize a version-0
    static membership, so ``ShardedBroker.from_membership`` works against
    federations that never ran a single ``--join``."""
    doc = jsonstore.load_json(path)
    if not isinstance(doc, dict):
        return None
    return _membership_from_file_doc(doc)


def _mirror_endpoints(doc: Dict[str, Any], m: Membership) -> None:
    """Keep the legacy ``endpoints``/``n`` keys in sync so pre-elastic
    readers (read_endpoints, static shard+file:// discovery) see the
    membership-managed federation."""
    doc["endpoints"] = {str(meta["slot"]): url
                        for url, meta in m.members.items()}
    doc["n"] = len(m.members)


def _update_membership(path: str, fn) -> Membership:
    """Locked read-modify-write of the membership section.  ``fn`` gets
    the parsed Membership (synthesized from a legacy announce file on
    first touch) and mutates it in place; returns True to bump version."""
    box: Dict[str, Membership] = {}

    def _apply(doc: Dict[str, Any]) -> None:
        m = _membership_from_file_doc(doc) or Membership()
        if fn(m):
            m.version += 1
        doc["membership"] = m.to_doc()
        _mirror_endpoints(doc, m)
        box["m"] = m

    # strict: a member that cannot register/deregister is invisible to the
    # federation — fail loudly rather than split-brain silently
    jsonstore.update_json(path, _apply, strict=True)
    return box["m"]


def join_membership(path: str, url: str,
                    now: Optional[float] = None) -> Membership:
    """Add (or refresh) ``url`` as a federation member; bumps the version
    when the member set actually changes.  Rejoin after leave/eviction
    allocates a FRESH slot — tags minted against the old incarnation stay
    fenced."""
    ts = time.time() if now is None else now

    def _fn(m: Membership) -> bool:
        if url in m.members:
            m.members[url]["heartbeat_at"] = ts
            return False
        m.members[url] = {"slot": m.next_slot, "joined_at": ts,
                          "heartbeat_at": ts}
        m.next_slot += 1
        return True

    return _update_membership(path, _fn)


def leave_membership(path: str, url: str) -> Membership:
    """Remove ``url`` from the federation (no-op when absent); drops any
    pins that targeted it."""
    def _fn(m: Membership) -> bool:
        if url not in m.members:
            return False
        del m.members[url]
        for q in [q for q, u in m.pins.items() if u == url]:
            del m.pins[q]
        return True

    return _update_membership(path, _fn)


def heartbeat_membership(path: str, url: str,
                         now: Optional[float] = None) -> Membership:
    """Refresh ``url``'s liveness timestamp.  NEVER bumps the version —
    heartbeats must not make every client rebuild its ring."""
    ts = time.time() if now is None else now

    def _fn(m: Membership) -> bool:
        if url in m.members:
            m.members[url]["heartbeat_at"] = ts
        return False

    return _update_membership(path, _fn)


def sweep_membership(path: str, ttl: float,
                     now: Optional[float] = None
                     ) -> Tuple[Membership, List[str]]:
    """Evict members whose heartbeat is older than ``ttl`` seconds (one
    version bump covers the whole sweep).  Members that never heartbeat
    (synthesized legacy entries, heartbeat_at == 0) are left alone —
    eviction is for members that were live and stopped."""
    ts = time.time() if now is None else now
    evicted: List[str] = []

    def _fn(m: Membership) -> bool:
        for url, meta in list(m.members.items()):
            hb = float(meta.get("heartbeat_at") or 0.0)
            if hb > 0.0 and ts - hb > ttl:
                del m.members[url]
                evicted.append(url)
        if evicted:
            for q in [q for q, u in m.pins.items() if u not in m.members]:
                del m.pins[q]
        return bool(evicted)

    return _update_membership(path, _fn), evicted


def pin_queue(path: str, queue: str, url: Optional[str]) -> Membership:
    """Set (url) or clear (None) a per-queue ownership override.  Pins
    win over the ring; a pin to a non-member is rejected."""
    def _fn(m: Membership) -> bool:
        if url is None:
            return m.pins.pop(queue, None) is not None
        if url not in m.members:
            raise ValueError(f"cannot pin {queue!r} to non-member {url!r}")
        if m.pins.get(queue) == url:
            return False
        m.pins[queue] = url
        return True

    return _update_membership(path, _fn)
