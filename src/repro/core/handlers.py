"""Pluggable execution handlers — HOW a step's work actually runs.

The DAG decides *what* runs *when*; an :class:`ExecutionHandler` decides
the mechanism.  The paper's Merlin spans three tiers and this module
mirrors them:

* :class:`FnStepHandler` (``handler: fn``) — in-process Python callables
  from the runtime's fn-registry.  ``inprocess=True`` marks these as
  fusable: the worker routes them through the shared
  :class:`~repro.core.engine.ExecutionEngine` micro-batcher, exactly as
  before this layer existed.
* :class:`SubprocessHandler` (``handler: subprocess``) — local shell
  command steps, one subprocess per bundle in the worker's own thread
  (N workers really do mean N concurrent simulations).
* :class:`SchedulerJobHandler` (``handler: scheduler``) — the
  flux/slurm batch tier: render the command to a job script, submit it
  to a :class:`Scheduler`, poll to completion.  :class:`MockScheduler`
  (the default) fakes the scheduler with a local process table so tests
  exercise the full submit→poll→collect path without a real batch
  system; swap in a real ``Scheduler`` via
  ``runtime.register_handler(SchedulerJobHandler(MyFluxScheduler()))``.

Steps pick a handler by name in the spec (``run: {handler: ...}``); the
default is ``fn`` for fn-steps and ``subprocess`` for cmd-steps, which
reproduces the old hard-coded split.  Workers never special-case fn vs
cmd anymore — they ask the runtime, and the runtime asks the handler
(``inprocess`` drives engine routing, ``execute`` does the work).
"""
from __future__ import annotations

import os
import subprocess
import threading
import time
import uuid
from typing import Any, Dict, Optional, Protocol, runtime_checkable

from .spec import Step, substitute


class HandlerError(RuntimeError):
    """A step's execution mechanism failed (bad handler, failed job...)."""


def render_script(step: Step, ctx) -> str:
    """Substitute ``$(NAME)`` tokens and write the step's shell script into
    the bundle workspace; returns the script path.  Shared by every
    command-based handler so env/layout conventions cannot drift."""
    env = {**ctx.variables, **ctx.combo,
           "SAMPLE_LO": ctx.lo, "SAMPLE_HI": ctx.hi,
           "WORKSPACE": ctx.workspace, "MERLIN_STUDY": ctx.study}
    cmd = substitute(step.cmd or "", env)
    script = os.path.join(ctx.workspace, f"{step.name}.sh")
    with open(script, "w") as f:
        f.write(cmd if cmd.endswith("\n") else cmd + "\n")
    return script


@runtime_checkable
class ExecutionHandler(Protocol):
    name: str
    inprocess: bool  # True → fusable through the shared ExecutionEngine

    def execute(self, runtime, step: Step, ctx) -> None:
        """Run one step for one bundle context; raise on failure."""
        ...


class FnStepHandler:
    """In-process callable from the runtime's fn-registry."""

    name = "fn"
    inprocess = True

    def execute(self, runtime, step: Step, ctx) -> None:
        if step.fn is None:
            raise HandlerError(f"step '{step.name}': handler 'fn' needs fn")
        try:
            fn = runtime.fns[step.fn]
        except KeyError:
            raise HandlerError(
                f"step '{step.name}': fn '{step.fn}' is not registered "
                f"(known: {', '.join(sorted(runtime.fns)) or 'none'})")
        fn(ctx)


class SubprocessHandler:
    """Local shell command, one subprocess per bundle."""

    name = "subprocess"
    inprocess = False

    def __init__(self, timeout: float = 600.0):
        self.timeout = timeout

    def execute(self, runtime, step: Step, ctx) -> None:
        if step.cmd is None:
            raise HandlerError(
                f"step '{step.name}': handler 'subprocess' needs cmd")
        script = render_script(step, ctx)
        # per-step `timeout:` overrides the handler default; subprocess.run
        # kills the child at the deadline (the wall-clock kill), and the
        # typed HandlerError routes into the normal retry/on_failure path
        timeout = step.timeout if step.timeout is not None else self.timeout
        try:
            res = subprocess.run([step.shell, script], cwd=ctx.workspace,
                                 capture_output=True, text=True,
                                 timeout=timeout)
        except subprocess.TimeoutExpired:
            raise HandlerError(
                f"step {step.name} timed out after {timeout}s (killed)")
        if res.returncode != 0:
            raise HandlerError(
                f"step {step.name} failed rc={res.returncode}: "
                f"{res.stderr[-500:]}")


# -- batch-scheduler tier ----------------------------------------------------

@runtime_checkable
class Scheduler(Protocol):
    """Minimal batch-scheduler surface (the flux/slurm adapter point)."""

    def submit(self, script: str, cwd: str,
               resources: Dict[str, Any]) -> str:
        """Submit a job script; returns an opaque job id."""
        ...

    def status(self, job_id: str) -> str:
        """One of PENDING / RUNNING / COMPLETED / FAILED."""
        ...

    def cancel(self, job_id: str) -> None: ...


class MockScheduler:
    """A fake batch scheduler backed by a local process table.

    Jobs run as real subprocesses but go through the full
    submit→PENDING→RUNNING→COMPLETED/FAILED lifecycle, so the handler's
    polling loop is exercised end-to-end in tests.  ``hold_s`` keeps a
    job PENDING for a while — useful for asserting the polling path."""

    def __init__(self, shell: str = "/bin/bash", hold_s: float = 0.0):
        self.shell = shell
        self.hold_s = hold_s
        self.jobs: Dict[str, Dict[str, Any]] = {}
        self.submitted = 0
        self._lock = threading.Lock()

    def submit(self, script: str, cwd: str,
               resources: Dict[str, Any]) -> str:
        job_id = f"mock-{uuid.uuid4().hex[:8]}"
        with self._lock:
            self.submitted += 1
            self.jobs[job_id] = {"script": script, "cwd": cwd,
                                 "resources": dict(resources),
                                 "t0": time.monotonic(), "proc": None}
        return job_id

    def _maybe_start(self, job: Dict[str, Any]) -> None:
        if job["proc"] is None and \
                time.monotonic() - job["t0"] >= self.hold_s:
            job["proc"] = subprocess.Popen(
                [self.shell, job["script"]], cwd=job["cwd"],
                stdout=subprocess.DEVNULL, stderr=subprocess.PIPE)

    def status(self, job_id: str) -> str:
        with self._lock:
            job = self.jobs.get(job_id)
            if job is None:
                raise HandlerError(f"unknown job id {job_id}")
            self._maybe_start(job)
            proc = job["proc"]
            if proc is None:
                return "PENDING"
            rc = proc.poll()
            if rc is None:
                return "RUNNING"
            if "stderr" not in job:  # drain + close the pipe exactly once
                job["stderr"] = proc.stderr.read().decode(
                    "utf-8", "replace") if proc.stderr else ""
                if proc.stderr:
                    proc.stderr.close()
            return "COMPLETED" if rc == 0 else "FAILED"

    def cancel(self, job_id: str) -> None:
        with self._lock:
            job = self.jobs.get(job_id)
            if job and job["proc"] is not None and \
                    job["proc"].poll() is None:
                job["proc"].kill()


class SchedulerJobHandler:
    """Run a cmd-step as a batch-scheduler job: render script, submit with
    the step's ``resources`` annotation, poll until terminal."""

    name = "scheduler"
    inprocess = False

    def __init__(self, scheduler: Optional[Scheduler] = None,
                 poll_s: float = 0.02, timeout: float = 600.0):
        self.scheduler = scheduler or MockScheduler()
        self.poll_s = poll_s
        self.timeout = timeout

    def execute(self, runtime, step: Step, ctx) -> None:
        if step.cmd is None:
            raise HandlerError(
                f"step '{step.name}': handler 'scheduler' needs cmd")
        script = render_script(step, ctx)
        job_id = self.scheduler.submit(script, ctx.workspace,
                                       step.resources)
        timeout = step.timeout if step.timeout is not None else self.timeout
        deadline = time.monotonic() + timeout
        while True:
            st = self.scheduler.status(job_id)
            if st == "COMPLETED":
                return
            if st == "FAILED":
                raise HandlerError(
                    f"step {step.name}: scheduler job {job_id} FAILED")
            if time.monotonic() > deadline:
                self.scheduler.cancel(job_id)
                raise HandlerError(
                    f"step {step.name}: scheduler job {job_id} timed out")
            time.sleep(self.poll_s)


def default_handlers() -> Dict[str, ExecutionHandler]:
    """The registry every runtime starts with."""
    return {h.name: h for h in
            (FnStepHandler(), SubprocessHandler(), SchedulerJobHandler())}
