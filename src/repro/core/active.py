"""The iterative surrogate-optimization archetype (paper Sec. 3.2).

Loop per iteration, exactly the HYDRA capsule-robustness workflow:
  simulate batch -> post-process -> collect features -> train ML surrogate
  -> constrained acquisition (maximize expected objective under constraints,
  with robustness samples around candidates) -> choose next batch
  (1/3 around best observed, 1/3 at predicted optimum, 1/3 on the line
  between them — the paper's 128/128/128 split) -> re-enqueue via a worker
  call back into ``merlin run`` (dynamic workflow).

The surrogate is a small JAX MLP ensemble (deep ensembles for cheap
uncertainty); the simulator is any vmappable f(u, rng)->dict (JAG here).

Hot-path layout (the AI half of the AI–HPC coupling):

* ``train_surrogate`` is ONE jitted ``lax.scan`` over optimizer steps,
  ``vmap``-ed over ensemble members — a single compile and a single device
  loop instead of n_members × steps eager dispatches.  Training rows are
  padded to power-of-two buckets (core/ensemble.bucket_for) with a masked
  loss, so the growing per-iteration archive re-uses compiled programs
  instead of re-tracing at every new dataset size.
* ``Surrogate.predict`` is one jitted batched apply over the stacked member
  pytree (row-padded the same way), shared process-wide across instances.
* ``OptimizationLoop`` keeps one executor per iteration (all sharing the
  process-wide simulator compile cache) and one Bundler whose cached
  ``load_all`` re-reads only bundles that appeared since the last funnel.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import threading
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bundler import Bundler
from repro.core.ensemble import EnsembleExecutor, bucket_for, pad_rows
from repro.core.runtime import MerlinRuntime
from repro.core.spec import Step, StudySpec


# ---------------------------------------------------------------------------
# MLP surrogate (deep ensemble)
# ---------------------------------------------------------------------------

def _mlp_init(rng, dims):
    params = []
    for i in range(len(dims) - 1):
        rng, k = jax.random.split(rng)
        w = jax.random.normal(k, (dims[i], dims[i + 1])) * (2.0 / dims[i]) ** 0.5
        params.append({"w": w, "b": jnp.zeros(dims[i + 1])})
    return params


def _mlp_apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.gelu(x)
    return x[..., 0]


@jax.jit
def _ensemble_apply(stacked, X):
    """Batched deep-ensemble forward: member axis leads the stacked pytree."""
    preds = jax.vmap(_mlp_apply, in_axes=(0, None))(stacked, X)
    return preds.mean(0), preds.std(0)


@dataclasses.dataclass
class Surrogate:
    params_list: List

    @property
    def stacked(self):
        """Members stacked on a leading axis (computed once, cached)."""
        s = getattr(self, "_stacked", None)
        if s is None:
            s = jax.tree.map(lambda *ls: jnp.stack(ls), *self.params_list)
            object.__setattr__(self, "_stacked", s)
        return s

    @classmethod
    def from_stacked(cls, stacked, n_members: int) -> "Surrogate":
        members = [jax.tree.map(lambda a: a[m], stacked)
                   for m in range(n_members)]
        sur = cls(members)
        object.__setattr__(sur, "_stacked", stacked)
        return sur

    def predict(self, X) -> Tuple[np.ndarray, np.ndarray]:
        """One jitted device launch; rows padded to a bucket so repeated
        calls at drifting batch sizes hit the compile cache."""
        X = np.asarray(X, np.float32)
        n = len(X)
        mu, sd = _ensemble_apply(self.stacked,
                                 jnp.asarray(pad_rows(X, bucket_for(n))))
        return np.asarray(mu[:n]), np.asarray(sd[:n])


@functools.partial(jax.jit, static_argnames=("steps", "lr"))
def _fit_members(params0, X, y, w, steps: int, lr: float):
    """Deep-ensemble Adam fit: ``lax.scan`` over steps, members vmapped.

    ``w`` masks padded rows out of the loss (sum(w·err²)/sum(w) equals the
    unpadded mean exactly); the update rule reproduces the seed's simple
    Adam (no bias correction) so results match the eager per-member loop.
    """
    def member_loss(p):
        err = _mlp_apply(p, X) - y
        return jnp.sum(w * err ** 2) / jnp.sum(w)

    zeros = jax.tree.map(jnp.zeros_like, params0)

    def body(carry, _):
        p, mom, vel = carry
        g = jax.vmap(jax.grad(member_loss))(p)
        mom = jax.tree.map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, mom, g)
        vel = jax.tree.map(lambda v_, g_: 0.999 * v_ + 0.001 * g_ ** 2, vel, g)
        p = jax.tree.map(
            lambda p_, m_, v_: p_ - lr * m_ / (jnp.sqrt(v_) + 1e-8),
            p, mom, vel)
        return (p, mom, vel), None

    (params, _, _), _ = jax.lax.scan(body, (params0, zeros, zeros), None,
                                     length=steps)
    return params


def train_surrogate(X: np.ndarray, y: np.ndarray, n_members: int = 3,
                    hidden: int = 64, steps: int = 300, lr: float = 3e-3,
                    seed: int = 0, pad: bool = True) -> Surrogate:
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.float32)
    n = len(X)
    cap = bucket_for(n) if pad else n
    w = np.zeros(cap, np.float32)
    w[:n] = 1.0
    rngs = jnp.stack([jax.random.PRNGKey(seed * 131 + m)
                      for m in range(n_members)])
    dims = (X.shape[1], hidden, hidden, 1)
    params0 = jax.vmap(lambda r: _mlp_init(r, dims))(rngs)
    params = _fit_members(params0, jnp.asarray(pad_rows(X, cap)),
                          jnp.asarray(pad_rows(y, cap)), jnp.asarray(w),
                          steps, lr)
    return Surrogate.from_stacked(params, n_members)


# ---------------------------------------------------------------------------
# serving snapshot
# ---------------------------------------------------------------------------

class SurrogateSnapshot:
    """A resident, reloadable serving view of a study's surrogate ensemble.

    The gateway tier (``repro.serve.gateway``) answers predict/calibrate/
    what-if requests against this object: it holds the trained
    :class:`Surrogate` in memory (stacked member pytree, jitted batched
    apply) and tracks the study's bundle archive through
    ``Bundler.load_since`` deltas — ``refresh()`` reads only bundles that
    appeared since the last call, appends their rows, and retrains,
    bumping ``version``.  Serving and refreshing are concurrent-safe: the
    retrain happens under the snapshot lock and the new model swaps in
    with a single attribute assignment, so in-flight ``predict`` calls
    finish on the old ensemble and the next batch picks up the new one
    (no request ever observes a half-trained model).

    ``min_new_rows`` batches refresh work: deltas accumulate until at
    least that many new rows arrived, then one retrain covers them all
    (retrains are the expensive part; padded bucket sizes keep them on
    cached compiles).
    """

    def __init__(self, root: str, objective_key: str = "yield",
                 input_key: str = "inputs", n_members: int = 3,
                 hidden: int = 64, steps: int = 300, lr: float = 3e-3,
                 seed: int = 0, min_new_rows: int = 1):
        self.bundler = Bundler(root)
        self.objective_key = objective_key
        self.input_key = input_key
        self.n_members, self.hidden = int(n_members), int(hidden)
        self.steps, self.lr, self.seed = int(steps), float(lr), int(seed)
        self.min_new_rows = max(1, int(min_new_rows))
        self._lock = threading.Lock()
        self._cursor = None
        self._X: Optional[np.ndarray] = None
        self._y: Optional[np.ndarray] = None
        self._pending_rows = 0
        self._sur: Optional[Surrogate] = None
        self.version = 0
        self.refresh()
        if self._sur is None:
            raise ValueError(
                f"no training rows under {root!r}: bundles must carry "
                f"'{input_key}' and '{objective_key}' arrays")

    @property
    def rows(self) -> int:
        X = self._X
        return 0 if X is None else len(X)

    @property
    def dims(self) -> int:
        X = self._X
        return 0 if X is None else X.shape[1]

    def refresh(self) -> bool:
        """Pull new bundles since the last refresh and retrain if at least
        ``min_new_rows`` accumulated; returns True when the served model
        changed (``version`` bumped)."""
        with self._lock:
            data, self._cursor = self.bundler.load_since(self._cursor)
            X_new = data.get(self.input_key)
            y_new = data.get(self.objective_key)
            if X_new is not None and y_new is not None and len(X_new):
                X_new = np.asarray(X_new, np.float32)
                y_new = np.asarray(y_new, np.float32).reshape(len(X_new))
                if X_new.ndim == 1:
                    X_new = X_new[:, None]
                if self._X is None:
                    self._X, self._y = X_new, y_new
                else:
                    self._X = np.concatenate([self._X, X_new])
                    self._y = np.concatenate([self._y, y_new])
                self._pending_rows += len(X_new)
            if self._X is None or not len(self._X):
                return False
            if self._sur is not None and self._pending_rows < self.min_new_rows:
                return False
            self._sur = train_surrogate(
                self._X, self._y, n_members=self.n_members,
                hidden=self.hidden, steps=self.steps, lr=self.lr,
                seed=self.seed)
            self._pending_rows = 0
            self.version += 1
            return True

    def predict(self, X) -> Tuple[np.ndarray, np.ndarray]:
        """(mu, sd) over rows — lock-free: the model reference is read
        once, so a concurrent refresh never tears a batch."""
        sur = self._sur
        if sur is None:
            raise RuntimeError("snapshot has no trained model yet")
        return sur.predict(X)


# ---------------------------------------------------------------------------
# acquisition
# ---------------------------------------------------------------------------

def robust_objective(sur: Surrogate, X: np.ndarray, n_perturb: int = 16,
                     radius: float = 0.02, seed: int = 0) -> np.ndarray:
    """Expected objective under manufacturing-tolerance perturbations
    (the paper's 'expected yield under random draws about a design')."""
    rng = np.random.default_rng(seed)
    Xp = X[:, None, :] + rng.normal(0, radius, (len(X), n_perturb, X.shape[1]))
    mu, _ = sur.predict(np.clip(Xp, 0, 1).reshape(-1, X.shape[1]))
    return mu.reshape(len(X), n_perturb).mean(1)


def propose_batch(sur_obj: Surrogate, sur_con: Optional[Surrogate],
                  X_seen: np.ndarray, y_seen: np.ndarray, n: int,
                  dims: int, con_max: float = np.inf, seed: int = 0
                  ) -> np.ndarray:
    """The paper's 3-way split: around best / at predicted opt / connecting."""
    rng = np.random.default_rng(seed)
    best = X_seen[int(np.argmax(y_seen))]
    # predicted constrained optimum via random search on the surrogate
    cand = rng.uniform(0, 1, (4096, dims)).astype(np.float32)
    obj = robust_objective(sur_obj, cand, seed=seed)
    if sur_con is not None:
        cmu, _ = sur_con.predict(cand)
        obj = np.where(cmu <= con_max, obj, -np.inf)
    pred_opt = cand[int(np.argmax(obj))]
    k = n // 3
    around_best = np.clip(best + rng.normal(0, 0.04, (k, dims)), 0, 1)
    around_opt = np.clip(pred_opt + rng.normal(0, 0.04, (k, dims)), 0, 1)
    t = rng.uniform(0, 1, (n - 2 * k, 1))
    line = np.clip(best * (1 - t) + pred_opt * t
                   + rng.normal(0, 0.02, (n - 2 * k, dims)), 0, 1)
    return np.concatenate([around_best, around_opt, line]).astype(np.float32)


# ---------------------------------------------------------------------------
# the full loop as a dynamic Merlin study
# ---------------------------------------------------------------------------

class OptimizationLoop:
    """Self-re-enqueueing optimization chain (Fig. 8)."""

    def __init__(self, runtime: MerlinRuntime, simulator: Callable,
                 objective_key: str = "yield", constraint_key: str = "velocity",
                 constraint_max: float = 360.0, dims: int = 5,
                 batch_per_iter: int = 48, max_iters: int = 3, seed: int = 0):
        self.rt = runtime
        self.dims = dims
        self.batch = batch_per_iter
        self.max_iters = max_iters
        self.obj_key = objective_key
        self.con_key = constraint_key
        self.con_max = constraint_max
        self.seed = seed
        self.history: List[Dict] = []
        self.simulator = simulator
        self.root = os.path.join(runtime.workspace, "opt_results")
        # all-iteration view (load_all/crawl walk recursively); its per-file
        # cache makes each funnel's load incremental over the archive
        self.bundler = Bundler(self.root)
        # per-iteration executors live for the whole loop: jit cache and
        # bundler handles are reused across every task of an iteration (and
        # the compiled simulator is shared process-wide across iterations)
        self._executors: Dict[int, EnsembleExecutor] = {}
        self._exec_lock = threading.Lock()
        runtime.register("opt_simulate", self._sim_step)
        runtime.register("opt_analyze", self._analyze_step)

    def _executor(self, iteration: int) -> EnsembleExecutor:
        with self._exec_lock:
            ex = self._executors.get(iteration)
            if ex is None:
                # one bundler sub-tree per iteration: sample ids restart at
                # 0 each iteration, so results must not collide across them
                b = Bundler(os.path.join(self.root, f"iter{iteration:03d}"))
                ex = EnsembleExecutor(self.simulator, b)
                self._executors[iteration] = ex
            return ex

    def _sim_step(self, ctx) -> None:
        it = int(ctx.variables["ITER"])
        self._executor(it).run_bundle(ctx.lo, ctx.hi, ctx.sample_block,
                                      sub_ranges=ctx.sub_ranges)

    def _spec(self, iteration: int) -> StudySpec:
        return StudySpec(
            name=f"opt-iter{iteration}",
            steps=[
                Step(name="simulate", fn="opt_simulate"),
                Step(name="analyze", fn="opt_analyze",
                     depends=("simulate_*",), over_samples=False),
            ],
            variables={"ITER": iteration})

    def start(self, rng: Optional[np.random.Generator] = None) -> str:
        rng = rng or np.random.default_rng(self.seed)
        X0 = rng.uniform(0, 1, (self.batch, self.dims)).astype(np.float32)
        return self.rt.run(self._spec(0), X0)

    def _analyze_step(self, ctx) -> None:
        """Funnel: train surrogates, log progress, launch the next iteration
        from inside a worker task (the dynamic re-enqueue of Sec. 3.2)."""
        it = int(ctx.variables["ITER"])
        data = self.bundler.load_all()
        ok = np.isfinite(data[self.obj_key])
        X = data["inputs"][ok]
        y = np.log10(np.maximum(data[self.obj_key][ok], 1e10))
        y = (y - y.min()) / max(y.max() - y.min(), 1e-9)
        c = data[self.con_key][ok]
        sur = train_surrogate(X, y, seed=self.seed + it)
        sur_c = train_surrogate(X, c / max(abs(c).max(), 1e-9),
                                seed=self.seed + 71 + it)
        self.history.append({
            "iter": it, "n": int(ok.sum()),
            "best": float(np.nanmax(data[self.obj_key]))})
        if it + 1 < self.max_iters:
            Xn = propose_batch(sur, sur_c, X, y, self.batch, self.dims,
                               con_max=self.con_max / max(abs(c).max(), 1e-9),
                               seed=self.seed + it)
            ctx.runtime.run(self._spec(it + 1), Xn)
