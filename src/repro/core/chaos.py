"""Seeded fault injection for the broker/worker tier (the chaos harness).

Robustness claims — retry budgets, dead-lettering, lease redelivery,
exactly-once completion — are only as good as the failure paths they have
actually been driven through.  This module makes those paths cheap to
exercise deterministically:

* :class:`ChaosBroker` wraps any :class:`~repro.core.queue.Broker` and
  injects faults on the data-plane operations (put/get/ack/nack and
  their batch variants) from a seeded RNG:

  - ``p_error``  — raise :class:`BrokerUnavailable` instead of the op
    (the transient-outage path: worker backoff, netbroker retry).
  - ``p_delay`` / ``max_delay_s`` — sleep before the op (slow broker;
    stretches lease windows and ack flushes).
  - ``p_drop_ack`` — perform *nothing* but report ack success (a lost
    ack: the lease expires and the task is redelivered, so completion
    must be idempotent under re-execution).
  - ``p_lose_lease`` — claim a lease from the inner broker but withhold
    it from the caller (a worker that died mid-lease: the task comes
    back after the visibility timeout).

  ``partition(seconds)`` opens a window during which every data-plane
  op raises :class:`BrokerUnavailable` (a network partition); ``heal()``
  closes it early.  Control-plane reads (qsize, queue_names, idle,
  stats, ...) pass through untouched so drain loops and assertions stay
  usable mid-chaos.

* :class:`FlakyFn` wraps a registered step fn with seeded, *bounded*
  failures per bundle key — each (study, lo, hi) fails at most
  ``max_failures`` times, so any retry budget >= ``max_failures``
  eventually succeeds and the test can still assert full completion.

Every injected fault is counted in :attr:`ChaosBroker.faults`; tests
assert the run actually suffered (non-zero injections) before claiming
the audit means anything.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.queue import Broker, BrokerUnavailable, Lease, Task


class ChaosBroker:
    """A fault-injecting proxy around any Broker (seeded, thread-safe)."""

    def __init__(self, inner: Broker, seed: int = 0,
                 p_error: float = 0.0, p_delay: float = 0.0,
                 max_delay_s: float = 0.05, p_drop_ack: float = 0.0,
                 p_lose_lease: float = 0.0):
        self.inner = inner
        self.rng = random.Random(seed)
        self.p_error = p_error
        self.p_delay = p_delay
        self.max_delay_s = max_delay_s
        self.p_drop_ack = p_drop_ack
        self.p_lose_lease = p_lose_lease
        self._lock = threading.Lock()
        self._partition_until = 0.0
        self.faults: Dict[str, int] = {
            "errors": 0, "delays": 0, "dropped_acks": 0,
            "lost_leases": 0, "partition_rejections": 0,
        }

    # -- fault controls ------------------------------------------------------
    def partition(self, seconds: float) -> None:
        """Open a partition window: all data-plane ops fail for its span."""
        with self._lock:
            self._partition_until = max(self._partition_until,
                                        time.monotonic() + seconds)

    def heal(self) -> None:
        with self._lock:
            self._partition_until = 0.0

    def _roll(self, p: float) -> bool:
        return p > 0 and self.rng.random() < p

    def _preamble(self, op: str) -> None:
        """Partition check + error/delay rolls shared by every data op."""
        with self._lock:
            if time.monotonic() < self._partition_until:
                self.faults["partition_rejections"] += 1
                raise BrokerUnavailable(
                    f"chaos: partitioned (op={op})")
            if self._roll(self.p_error):
                self.faults["errors"] += 1
                raise BrokerUnavailable(f"chaos: injected error (op={op})")
            delay = (self.rng.random() * self.max_delay_s
                     if self._roll(self.p_delay) else 0.0)
            if delay > 0:
                self.faults["delays"] += 1
        if delay > 0:
            time.sleep(delay)

    # -- data plane (faults injected) ----------------------------------------
    def put(self, task: Task) -> None:
        self._preamble("put")
        self.inner.put(task)

    def put_many(self, tasks: List[Task]) -> None:
        self._preamble("put_many")
        self.inner.put_many(tasks)

    def get(self, timeout: Optional[float] = 0.0,
            queues: Optional[Sequence[str]] = None) -> Optional[Lease]:
        self._preamble("get")
        lease = self.inner.get(timeout, queues)
        if lease is not None:
            with self._lock:
                if self._roll(self.p_lose_lease):
                    self.faults["lost_leases"] += 1
                    return None  # leased but never delivered -> vt redelivery
        return lease

    def get_many(self, n: int, timeout: Optional[float] = 0.0,
                 queues: Optional[Sequence[str]] = None) -> List[Lease]:
        self._preamble("get_many")
        leases = self.inner.get_many(n, timeout, queues)
        if leases:
            with self._lock:
                kept = []
                for lease in leases:
                    if self._roll(self.p_lose_lease):
                        self.faults["lost_leases"] += 1
                    else:
                        kept.append(lease)
            return kept
        return leases

    def ack(self, tag: str) -> None:
        self._preamble("ack")
        with self._lock:
            if self._roll(self.p_drop_ack):
                self.faults["dropped_acks"] += 1
                return  # pretend success; lease expires -> redelivery
        self.inner.ack(tag)

    def ack_many(self, tags: Iterable[str]) -> None:
        self._preamble("ack_many")
        tags = list(tags)
        with self._lock:
            kept = []
            for t in tags:
                if self._roll(self.p_drop_ack):
                    self.faults["dropped_acks"] += 1
                else:
                    kept.append(t)
        if kept:
            self.inner.ack_many(kept)

    def nack(self, tag: str) -> None:
        self._preamble("nack")
        self.inner.nack(tag)

    # -- control plane (clean passthrough) -----------------------------------
    def qsize(self, queues: Optional[Sequence[str]] = None) -> int:
        return self.inner.qsize(queues)

    def queue_names(self) -> List[str]:
        return self.inner.queue_names()

    def inflight(self) -> int:
        return self.inner.inflight()

    def inflight_tasks(self) -> List[Tuple[Task, float]]:
        return self.inner.inflight_tasks()

    def idle(self) -> bool:
        return self.inner.idle()

    def set_visibility_timeout(self, queue: str, timeout: float) -> None:
        self.inner.set_visibility_timeout(queue, timeout)

    def set_max_queue_depth(self, queue: str, depth: Optional[int]) -> None:
        self.inner.set_max_queue_depth(queue, depth)

    def heartbeat(self, consumer_id: str,
                  queues: Optional[Sequence[str]] = None) -> None:
        self.inner.heartbeat(consumer_id, queues)

    # migration protocol ops are control-plane: chaos must not break the
    # handoff itself, only the data traffic flowing around it
    def migrate_queue(self, queue: str, target: Optional[str]) -> None:
        self.inner.migrate_queue(queue, target)

    def export_queue(self, queue: str, max_n: int = 256) -> List[Dict[str, Any]]:
        return self.inner.export_queue(queue, max_n)

    def import_tasks(self, tasks: List[Dict[str, Any]]) -> None:
        self.inner.import_tasks(tasks)

    @property
    def stats(self) -> Dict[str, Any]:
        s = dict(self.inner.stats)
        with self._lock:
            s["chaos"] = dict(self.faults)
        return s

    def close(self) -> None:
        close = getattr(self.inner, "close", None)
        if close is not None:
            close()


class FlakyFn:
    """Wrap a step fn with seeded, bounded failures per bundle.

    Each (study, lo, hi) key fails at most ``max_failures`` times before
    the underlying fn runs, so a retry budget >= ``max_failures``
    guarantees eventual completion — the chaos suite can assert both
    "failures happened" and "everything still finished".
    """

    def __init__(self, fn, p_fail: float = 0.3, max_failures: int = 2,
                 seed: int = 0):
        self.fn = fn
        self.p_fail = p_fail
        self.max_failures = max_failures
        self.rng = random.Random(seed)
        self.failed: Dict[Tuple[str, int, int], int] = {}
        self.injected = 0
        self._lock = threading.Lock()

    def __call__(self, ctx) -> None:
        key = (ctx.study, int(ctx.lo), int(ctx.hi))
        with self._lock:
            n = self.failed.get(key, 0)
            fail = (n < self.max_failures
                    and self.rng.random() < self.p_fail)
            if fail:
                self.failed[key] = n + 1
                self.injected += 1
        if fail:
            raise RuntimeError(
                f"chaos: injected fn failure #{n + 1} for {key}")
        self.fn(ctx)
