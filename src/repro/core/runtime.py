"""The Merlin producer-consumer runtime, DAG edition.

``MerlinRuntime.run(spec, samples)`` is ``merlin run``: it compiles the
spec into a :class:`~repro.core.dag.TaskDag` (arbitrary fan-in/fan-out,
chain-fused sample-parallel nodes), persists the study + initial DAG
state, and enqueues ONE root task per source node instance — the
near-non-blocking producer of Sec. 2.3.  Workers (core/worker.py) expand
the hierarchy and execute sample bundles through pluggable
:mod:`~repro.core.handlers`; and — Celery-chord-like, fully
decentralized — whichever worker completes a node instance's LAST bundle
walks that instance's out-edges and unlocks exactly the children whose
fan-in is now satisfied.  All coordination lives in crash-safe file
counters / once-markers (flock / O_EXCL), so workers in different
processes / "batch allocations" agree without a central orchestrator;
the persisted ``<study>.dag.json`` (via :mod:`~repro.core.jsonstore`) is
the human/status-tool view of the same progress, and
``attach(study, resume=True)`` re-arms an interrupted study mid-graph.

Dynamic data flow between nodes rides on *named sample sets*: a step may
call ``ctx.publish_samples("posterior", arr)`` and a downstream step
with ``sample_set: posterior`` iterates exactly that array — how the
COVID cascade's phase 2 became an ordinary graph edge instead of a
nested ``runtime.run()`` call from inside a worker.
"""
from __future__ import annotations

import fcntl
import json
import os
import threading
import time
import uuid
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import hierarchy as H
from repro.core import jsonstore
from repro.core.dag import DagNode, TaskDag, compile_dag
from repro.core.handlers import ExecutionHandler, default_handlers
from repro.core.queue import (PRIORITY_GEN, PRIORITY_REAL, BrokerError,
                              InMemoryBroker, Lease, Task, new_task)
from repro.core.resilience import BackoffPolicy
from repro.core.spec import Step, StudySpec, expand_parameters, substitute


# ---------------------------------------------------------------------------
# crash-safe counters / once-markers / journal
# ---------------------------------------------------------------------------

class FileCounter:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key.replace("/", "_") + ".cnt")

    def incr(self, key: str, by: int = 1) -> int:
        path = self._path(key)
        fd = os.open(path, os.O_RDWR | os.O_CREAT)
        with os.fdopen(fd, "r+") as f:
            fcntl.flock(f, fcntl.LOCK_EX)
            raw = f.read().strip()
            val = (int(raw) if raw else 0) + by
            f.seek(0)
            f.truncate()
            f.write(str(val))
            f.flush()
            return val

    def get(self, key: str) -> int:
        try:
            with open(self._path(key)) as f:
                raw = f.read().strip()
                return int(raw) if raw else 0
        except FileNotFoundError:
            return 0

    def once(self, key: str) -> bool:
        """True exactly once per key across all processes (O_EXCL)."""
        path = os.path.join(self.root, key.replace("/", "_") + ".once")
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            os.close(fd)
            return True
        except FileExistsError:
            return False

    def once_exists(self, key: str) -> bool:
        return os.path.exists(
            os.path.join(self.root, key.replace("/", "_") + ".once"))


class Journal:
    """Append-only jsonl event log (provenance + restart/crawl substrate)."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path), exist_ok=True)
        self._lock = threading.Lock()

    def append(self, event: Dict[str, Any]) -> None:
        event = {"t": time.time(), **event}
        # leading newline isolates this record from any torn write a crashed
        # worker left behind; replay skips the blank lines it creates
        line = "\n" + json.dumps(event) + "\n"
        with self._lock, open(self.path, "a") as f:
            fcntl.flock(f, fcntl.LOCK_EX)
            f.write(line)

    def replay(self) -> List[Dict[str, Any]]:
        if not os.path.exists(self.path):
            return []
        out = []
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if line:
                    try:
                        out.append(json.loads(line))
                    except json.JSONDecodeError:
                        pass  # torn write from a crashed worker
        return out

    def done_bundles(self, study: str) -> set:
        done = set()
        for ev in self.replay():
            if ev.get("ev") == "bundle_done" and ev.get("study") == study:
                done.add((ev["stage"], ev["combo"], ev["lo"], ev["hi"]))
        return done


# ---------------------------------------------------------------------------
# runtime
# ---------------------------------------------------------------------------

class Context:
    """Execution context handed to fn-steps.

    ``sub_ranges`` is the coalescing contract: when a worker fuses several
    contiguous leaf tasks into one execution (``execute_real_many``), the
    step sees ONE context spanning the union [lo, hi) plus the original
    per-task [slo, shi) spans.  Steps that write per-range artifacts (the
    ensemble executor's bundle files) iterate ``sub_ranges`` so the on-disk
    layout is identical to per-task execution; steps that ignore it simply
    process the whole block at once.

    ``publish_samples`` feeds downstream DAG nodes: the array becomes a
    named sample set scoped to this context's parameter combo, and any
    node with a matching ``sample_set`` iterates it.
    """

    def __init__(self, runtime: "MerlinRuntime", study: str, combo: Dict,
                 samples: Optional[np.ndarray], lo: int, hi: int,
                 workspace: str, variables: Dict,
                 sub_ranges: Optional[Sequence[tuple]] = None,
                 deferred: bool = False):
        self.runtime = runtime
        self.study = study
        self.combo = combo
        self.samples = samples
        self.lo, self.hi = lo, hi
        self.workspace = workspace
        self.variables = variables
        self.sub_ranges = list(sub_ranges) if sub_ranges else [(lo, hi)]
        self._deferred: Optional[list] = [] if deferred else None

    @property
    def sample_block(self) -> Optional[np.ndarray]:
        return None if self.samples is None else self.samples[self.lo:self.hi]

    @property
    def deferrable(self) -> bool:
        """True under deferred execution: completion work registered with
        ``defer`` runs on the engine's writer thread, overlapping the next
        batch's dispatch, instead of blocking the step."""
        return self._deferred is not None

    def defer(self, fn: Callable[[], None]) -> None:
        """Register completion work (host sync + artifact writes).  Under
        deferred execution it runs later, before this context's tasks get
        their once-markers; otherwise it runs immediately — steps may call
        this unconditionally."""
        if self._deferred is None:
            fn()
        else:
            self._deferred.append(fn)

    def run_deferred(self) -> None:
        """Run (and clear) the registered completion work, in order."""
        if self._deferred:
            fns, self._deferred = self._deferred, []
            for fn in fns:
                fn()

    def publish_samples(self, name: str, arr) -> None:
        """Publish ``arr`` as sample set ``name`` scoped to this combo, for
        downstream nodes declaring ``sample_set: name``."""
        self.runtime.publish_samples(self.study, name, arr, scope=self.combo)


class MerlinRuntime:
    def __init__(self, broker=None, workspace: str = "/tmp/merlin",
                 fns: Optional[Dict[str, Callable]] = None,
                 hierarchy: H.HierarchyCfg = H.HierarchyCfg(),
                 real_queue: str = "real", gen_queue: str = "gen",
                 handlers: Optional[Dict[str, ExecutionHandler]] = None):
        # broker may be a Broker instance or a URL: "tcp://host:port"
        # connects to a remote BrokerServer (no shared filesystem for the
        # queue — the paper's cross-allocation RabbitMQ model), "file://dir"
        # a shared-directory FileBroker, "mem://" a private InMemoryBroker,
        # "shard://h1:p1,h2:p2" (or a list of tcp:// endpoints) a
        # ShardedBroker federating several BrokerServers by queue name.
        if isinstance(broker, (str, list, tuple)):
            from repro.core.netbroker import make_broker
            broker = make_broker(broker)
        self.broker = broker if broker is not None else InMemoryBroker()
        self.workspace = workspace
        os.makedirs(workspace, exist_ok=True)
        self.fns = dict(fns or {})
        self.hcfg = hierarchy
        # Sec. 2.2 routing: simulation (real) tasks and task-generation
        # tasks live on separate named queues so workers can subscribe to
        # either stream; priority still drains real before gen globally.
        # A node's spec-level `queue:` annotation overrides real_queue for
        # that node's leaf tasks.
        self.real_queue = real_queue
        self.gen_queue = gen_queue
        self.counters = FileCounter(os.path.join(workspace, "_counters"))
        self.journal = Journal(os.path.join(workspace, "_journal.jsonl"))
        self.handlers: Dict[str, ExecutionHandler] = \
            dict(handlers) if handlers is not None else default_handlers()
        # one micro-batching ExecutionEngine per runtime (lazily created):
        # every WorkerPool attached to this runtime feeds the same
        # scheduler, so fused launches span pools as well as workers
        self._engine = None
        self._engine_lock = threading.Lock()
        self._specs: Dict[str, StudySpec] = {}
        self._dags: Dict[str, TaskDag] = {}
        self._samples: Dict[str, Optional[np.ndarray]] = {}  # "default" set
        self._meta_n: Dict[str, int] = {}
        self._pub_cache: Dict[str, np.ndarray] = {}  # published .npy files

    def register(self, name: str, fn: Callable) -> None:
        self.fns[name] = fn

    def register_handler(self, handler: ExecutionHandler) -> None:
        """Install (or replace) an execution handler under ``handler.name``;
        specs select it per step via ``run: {handler: <name>}``."""
        self.handlers[handler.name] = handler

    def shared_engine(self, **cfg):
        """This runtime's shared :class:`~repro.core.engine.ExecutionEngine`
        (created on first use, re-created after the last pool closed it).

        Returns the engine with one reference attached — callers pair this
        with ``engine.detach()`` (WorkerPool does both automatically).
        ``cfg`` (``max_batch``, ``max_wait_ms``) only applies when this
        call creates the engine; later callers share the first
        configuration.
        """
        from repro.core.engine import EngineClosed, ExecutionEngine
        with self._engine_lock:
            while True:
                if self._engine is None or self._engine.closed:
                    self._engine = ExecutionEngine(self, **cfg)
                try:
                    return self._engine.attach()
                except EngineClosed:
                    # lost a race with the last pool's detach-close:
                    # build a fresh engine on the next spin
                    self._engine = None

    # -- study registration --------------------------------------------------
    def register_study(self, spec: StudySpec,
                       study_id: Optional[str] = None,
                       samples: Optional[np.ndarray] = None) -> str:
        """Compile ``spec`` and make the study executable by THIS runtime
        (fills the dag/spec/sample tables workers consult).  ``run()`` and
        ``attach()`` both route through here; tests and benchmarks that
        enqueue hand-built tasks use it directly instead of poking at
        private tables."""
        dag = compile_dag(spec)
        for node in dag.nodes:  # fail fast, not at worker-execute time
            self._handler_for(node)
        study = study_id or f"{spec.name}-{uuid.uuid4().hex[:8]}"
        self._specs[study] = spec
        self._dags[study] = dag
        self._samples[study] = samples
        self._meta_n[study] = (len(samples) if samples is not None
                               else self.hcfg.bundle)
        return study

    def dag(self, study: str) -> TaskDag:
        return self._dags[study]

    # -- producer ("merlin run") -------------------------------------------
    def run(self, spec: StudySpec, samples: Optional[np.ndarray] = None,
            study_id: Optional[str] = None) -> str:
        study = self.register_study(spec, study_id, samples)
        dag = self._dags[study]
        n = self._meta_n[study]
        # persist study metadata so cross-process workers can reconstruct it
        meta = {"study": study, "n_samples": n,
                "spec": _spec_to_dict(spec)}
        mpath = os.path.join(self.workspace, f"{study}.study.json")
        # samples first, then meta, both via atomic rename: attach() treats
        # the meta file as the commit point, so a crash mid-persist must
        # never leave valid meta next to a missing/torn samples file
        if samples is not None:
            spath = os.path.join(self.workspace, f"{study}.samples.npy")
            with open(spath + ".tmp", "wb") as f:
                np.save(f, samples)
            os.rename(spath + ".tmp", spath)
        with open(mpath + ".tmp", "w") as f:
            json.dump(meta, f)
        os.rename(mpath + ".tmp", mpath)
        self._state_init(study, dag)
        self.journal.append({"ev": "study_start", "study": study, "n": n})
        for nidx, iidx in dag.roots():
            # claim the enqueue marker so a later (buggy or racing) unlock
            # cannot double-enqueue a root
            self.counters.once(f"{study}/s{nidx}/c{iidx}/enqueue")
            self._enqueue_node(study, nidx, iidx)
        return study

    def _put_resilient(self, task: Task, attempts: int = 8) -> None:
        """Enqueue with bounded backoff retry.  ``_enqueue_node`` runs
        behind an already-consumed once(enqueue) marker — a transient
        broker error here is the study's ONLY chance to enqueue that
        instance, so it must ride out short outages instead of wedging
        the graph."""
        backoff = BackoffPolicy(base=0.05, cap=1.0)
        for attempt in range(attempts):
            try:
                self.broker.put(task)
                return
            except BrokerError:
                if attempt == attempts - 1:
                    raise
                time.sleep(backoff.delay(attempt))

    def _enqueue_node(self, study: str, nidx: int, iidx: int) -> None:
        """Put the root task for one node instance on the broker."""
        if self.study_halted(study):
            return  # halted studies grow no new work
        dag = self._dags[study]
        node = dag.nodes[nidx]
        extra = {"study": study, "stage": nidx, "combo": iidx,
                 "real_queue": node.queue or self.real_queue,
                 "gen_queue": self.gen_queue}
        if node.kind == "single":
            extra["n_samples"] = 1
            self._put_resilient(new_task("real",
                                         {**extra, "samples": [0, 1],
                                          "fanout": self.hcfg.max_fanout,
                                          "bundle": 1},
                                         priority=PRIORITY_REAL,
                                         queue=extra["real_queue"]))
        else:
            _, n = self._resolve_samples(study, node, node.instances[iidx])
            extra["n_samples"] = n
            self._put_resilient(H.root_task(study, str(nidx), n, self.hcfg,
                                            extra=extra))
        self._state_set(study, nidx, iidx, "running")
        self.journal.append({"ev": "stage_start", "study": study,
                             "stage": nidx, "combo": iidx})

    def attach(self, study: str, resume: bool = False) -> str:
        """Load a study persisted by another runtime instance's ``run()``.

        Reconstructs the spec/dag/samples from the workspace's
        ``<study>.study.json`` + ``<study>.samples.npy`` so workers in a
        fresh process (a new "batch allocation", or a restart after a
        crash) can execute and advance a study they did not start.  Node
        counters and once-markers live on disk, so progress made before
        the crash is preserved mid-graph.  ``resume=True`` additionally
        re-enqueues every ready-but-incomplete node instance (see
        :meth:`resume`) so the study completes even if the queued tasks
        died with the previous broker/process.
        """
        mpath = os.path.join(self.workspace, f"{study}.study.json")
        with open(mpath) as f:
            meta = json.load(f)
        spec = _spec_from_dict(meta["spec"])
        spath = os.path.join(self.workspace, f"{study}.samples.npy")
        samples = np.load(spath) if os.path.exists(spath) else None
        self.register_study(spec, study_id=study, samples=samples)
        self._meta_n[study] = int(meta.get("n_samples",
                                           self._meta_n[study]))
        if resume:
            self.resume(study)
        return study

    def resume(self, study: str) -> List[Tuple[int, int]]:
        """Re-enqueue every node instance that is ready (all parents done)
        but not itself complete.  Safe against duplicates: execution is
        idempotent (done-markers), completed bundles of a half-finished
        instance no-op, and the advance/enqueue once-markers keep the
        unlock accounting exactly-once.  Returns the re-armed (node,
        instance) pairs."""
        dag = self._dags[study]
        requeued: List[Tuple[int, int]] = []
        for nidx, iidx in dag.all_instances():
            if self.counters.once_exists(f"{study}/s{nidx}/c{iidx}/advance"):
                continue  # already complete
            parents = dag.instance_parents(nidx, iidx)
            if not all(self.counters.once_exists(f"{study}/s{p}/c{q}/advance")
                       for p, q in parents):
                continue  # not unlocked yet: its parent's completion will do it
            self.counters.once(f"{study}/s{nidx}/c{iidx}/enqueue")
            self._enqueue_node(study, nidx, iidx)
            requeued.append((nidx, iidx))
        self.journal.append({"ev": "study_resume", "study": study,
                             "requeued": len(requeued)})
        return requeued

    # -- persisted DAG state (the status view; counters are the truth) ------
    def _state_path(self, study: str) -> str:
        return os.path.join(self.workspace, f"{study}.dag.json")

    def _state_init(self, study: str, dag: TaskDag) -> None:
        doc = dag.to_doc()
        doc["state"] = {f"s{n}/c{i}": {"status": "pending"}
                        for n, i in dag.all_instances()}
        jsonstore.save_json(self._state_path(study), doc)

    def _state_set(self, study: str, nidx: int, iidx: int, status: str,
                   epoch: Optional[int] = None) -> None:
        def upd(doc: Dict[str, Any]) -> None:
            ent = doc.setdefault("state", {}).setdefault(
                f"s{nidx}/c{iidx}", {})
            # never regress a terminal status: a resume's "running" update
            # racing a completer's "done" must lose
            if ent.get("status") == "done" and status != "done":
                return
            ent["status"] = status
            if epoch is not None:
                ent["epoch"] = epoch
        jsonstore.update_json(self._state_path(study), upd)

    def dag_state(self, study: str) -> Dict[str, Any]:
        """The persisted per-node status/epoch view (for status tooling)."""
        return jsonstore.load_json(self._state_path(study), default={})

    def note_failure(self, task: Task) -> None:
        """Mark a node instance failed in the persisted state (called when
        the retry policy gives a task up as poison).  Advisory: the
        counters still hold, and a later successful retry/crawl flips the
        instance back to done."""
        p = task.payload
        try:
            study, nidx, iidx = p["study"], p["stage"], p["combo"]
        except (KeyError, TypeError):
            return
        if study in self._dags:
            self._state_set(study, nidx, iidx, "failed")

    # -- per-step failure policy (ISSUE 7 tentpole) -------------------------
    def node_for(self, task: Task) -> Optional[DagNode]:
        """The DAG node a task belongs to, or None when this runtime does
        not know the study (a foreign task: fall back to worker defaults)."""
        try:
            p = task.payload
            return self._dags[p["study"]].nodes[p["stage"]]
        except (KeyError, IndexError, TypeError):
            return None

    def failure_policy(self, task: Task) -> Optional[Tuple[str, int]]:
        """``(on_failure, max_retries)`` for a task's node — the per-step
        policy the worker enforces at retry exhaustion.  None for tasks of
        unknown studies (the worker's own RetryPolicy applies)."""
        node = self.node_for(task)
        if node is None:
            return None
        return node.on_failure, node.max_retries

    def complete_skipped(self, task: Task) -> None:
        """``on_failure: skip``: record the bundle as complete WITHOUT
        executing it, so the node's counter advances and children unlock.
        The once-marker keeps this idempotent against redelivered copies
        racing a real completion."""
        if self.counters.once(self._done_key(task)):
            p = task.payload
            self.journal.append({"ev": "task_skipped", "study": p["study"],
                                 "stage": p["stage"], "combo": p["combo"],
                                 "lo": p["samples"][0],
                                 "hi": p["samples"][1]})
            self._bundle_done(task)

    def halt_study(self, study: str, reason: str = "") -> bool:
        """``on_failure: halt_study``: stop the whole study.  The halt is a
        crash-safe once-marker every process sees; workers drain the
        study's remaining tasks by acking them unexecuted, and no new node
        instance is enqueued or unlocked.  Returns True for the caller
        that actually performed the halt."""
        if not self.counters.once(f"{study}/halt"):
            return False
        self.journal.append({"ev": "study_halt", "study": study,
                             "reason": reason})

        def upd(doc: Dict[str, Any]) -> None:
            for ent in doc.get("state", {}).values():
                if ent.get("status") != "done":
                    ent["status"] = "halted"
        jsonstore.update_json(self._state_path(study), upd)
        return True

    def study_halted(self, study: str) -> bool:
        return self.counters.once_exists(f"{study}/halt")

    def task_halted(self, task: Task) -> bool:
        """True when this task belongs to a halted study (workers ack-drop
        such tasks instead of executing them — the passive drain)."""
        try:
            study = task.payload["study"]
        except (KeyError, TypeError):
            return False
        return isinstance(study, str) and self.study_halted(study)

    # -- named sample sets ---------------------------------------------------
    def publish_samples(self, study: str, name: str, arr,
                        scope: Optional[Dict[str, Any]] = None) -> None:
        """Persist ``arr`` as sample set ``name`` scoped to parameter values
        ``scope``; downstream nodes with ``sample_set: name`` whose combo
        matches the scope iterate it.  Crash-safe: the .npy commits via
        atomic rename before the locked index update, and re-publishing
        the same scope (a retried producer) replaces the entry."""
        arr = np.asarray(arr)
        scope = dict(scope or {})
        fname = f"{study}.samples.{name}.{uuid.uuid4().hex[:8]}.npy"
        path = os.path.join(self.workspace, fname)
        with open(path + ".tmp", "wb") as f:
            np.save(f, arr)
        os.rename(path + ".tmp", path)
        idx_path = os.path.join(self.workspace,
                                f"{study}.samples_index.json")

        def upd(doc: Dict[str, Any]) -> None:
            ents = doc.setdefault(name, [])
            ents[:] = [e for e in ents if e.get("combo") != scope]
            ents.append({"combo": scope, "n": int(len(arr)), "file": fname})
        jsonstore.update_json(idx_path, upd)
        self.journal.append({"ev": "samples_published", "study": study,
                             "set": name, "n": int(len(arr)),
                             "scope": scope})

    def _resolve_samples(self, study: str, node: DagNode,
                         inst: Dict[str, Any]):
        """The (array, count) a node instance iterates.  ``default`` is the
        study-level array passed to ``run()``; anything else must have
        been published (by an upstream step, before it completed) with a
        scope matching this instance — most-specific scope wins."""
        if node.sample_set == "default":
            arr = self._samples.get(study)
            n = len(arr) if arr is not None \
                else self._meta_n.get(study, self.hcfg.bundle)
            return arr, n
        idx_path = os.path.join(self.workspace,
                                f"{study}.samples_index.json")
        ents = jsonstore.load_json(idx_path, default={}).get(
            node.sample_set, [])
        best = None
        for e in ents:
            sc = e.get("combo", {})
            if all(k in inst and inst[k] == v for k, v in sc.items()):
                if best is None or len(sc) > len(best.get("combo", {})):
                    best = e
        if best is None:
            raise RuntimeError(
                f"study {study}: no published sample set "
                f"'{node.sample_set}' matches node '{node.name}' instance "
                f"{inst!r} — the producing step must call "
                f"ctx.publish_samples(...) before it completes")
        fname = best["file"]
        if fname not in self._pub_cache:
            self._pub_cache[fname] = np.load(
                os.path.join(self.workspace, fname))
        return self._pub_cache[fname], int(best["n"])

    # -- node bookkeeping (called by workers at bundle completion) ----------
    def _bundle_done(self, task: Task) -> None:
        p = task.payload
        study, nidx, iidx = p["study"], p["stage"], p["combo"]
        node = self._dags[study].nodes[nidx]
        if node.kind == "single":
            expected = 1
        else:
            # bundle size from the task payload, not this process's hcfg: a
            # runtime that attach()ed with a different config must still
            # agree with the producer on how many bundles complete a node
            n = p["n_samples"]
            expected = -(-n // p.get("bundle", self.hcfg.bundle))
        key = f"{study}/s{nidx}/c{iidx}"
        done = self.counters.incr(key)
        self.journal.append({"ev": "bundle_done", "study": study,
                             "stage": nidx, "combo": iidx,
                             "lo": p["samples"][0], "hi": p["samples"][1]})
        if done >= expected and self.counters.once(key + "/advance"):
            self.journal.append({"ev": "stage_done", "study": study,
                                 "stage": nidx, "combo": iidx})
            # completion epoch: a per-study monotonic clock shared by every
            # process via the flock'd counter — orders node completions for
            # the persisted state and the resume audit
            epoch = self.counters.incr(f"{study}/epoch")
            self._state_set(study, nidx, iidx, "done", epoch=epoch)
            self._unlock_children(study, nidx, iidx)
            if self.study_done(study) and self.counters.once(f"{study}/done"):
                self.journal.append({"ev": "study_done", "study": study})

    def _unlock_children(self, study: str, nidx: int, iidx: int) -> None:
        """The generalized chord: walk this instance's out-edges; each child
        instance counts satisfied parents in a crash-safe counter and the
        worker that supplies the LAST one enqueues it (exactly once, via
        the enqueue marker)."""
        if self.study_halted(study):
            return
        dag = self._dags[study]
        for m, j in dag.instance_children(nidx, iidx):
            need = dag.indegree(m, j)
            got = self.counters.incr(f"{study}/unlock/s{m}/c{j}")
            if got >= need and self.counters.once(f"{study}/s{m}/c{j}/enqueue"):
                self._enqueue_node(study, m, j)

    # -- execution of a real task -------------------------------------------
    def _node_fusable(self, node: DagNode) -> bool:
        """THE fusion predicate — the single definition both the worker's
        engine-routing decision (``coalescable``) and the grouping in
        ``execute_real_many`` consult, so they can never disagree about
        what fuses: sample-parallel nodes whose handler runs in-process."""
        h = self.handlers.get(node.handler)
        return node.kind == "parallel" and h is not None and h.inprocess

    def _handler_for(self, node: DagNode) -> ExecutionHandler:
        try:
            return self.handlers[node.handler]
        except KeyError:
            raise RuntimeError(
                f"node '{node.name}' wants handler '{node.handler}' but only "
                f"{sorted(self.handlers)} are registered "
                f"(runtime.register_handler adds more)")

    def coalescable(self, task: Task) -> bool:
        """True when this real task can profit from fused execution: its
        node is sample-parallel with an in-process handler (the only thing
        ``execute_real_many`` fuses).  Subprocess/scheduler and funnel-node
        tasks — and tasks for studies this runtime does not know — return
        False: workers run those in their own threads, where N workers
        really do mean N concurrent subprocesses, instead of serializing
        them behind the engine's single dispatcher."""
        try:
            p = task.payload
            node = self._dags[p["study"]].nodes[p["stage"]]
        except (KeyError, IndexError, TypeError):
            return False
        return self._node_fusable(node)

    def affinity_key(self, task: Task):
        """The engine's coalescing-bucket key: ``(study, simulator)``.

        Tasks only micro-batch with key-mates, so one fused dispatch never
        interleaves two studies' (or two simulators') bundles — a mixed
        buffer would shred ``execute_real_many``'s contiguity grouping
        into per-study fragments of a half-empty batch.  The simulator
        identity is the node's step fn/cmd tuple; tasks for studies this
        runtime does not know share the ``None`` bucket (they are not
        coalescable anyway)."""
        try:
            p = task.payload
            study = p["study"]
            node = self._dags[study].nodes[p["stage"]]
        except (KeyError, IndexError, TypeError):
            return None
        return (study, tuple(s.fn or s.cmd for s in node.steps))

    @staticmethod
    def _done_key(task: Task) -> str:
        p = task.payload
        lo, hi = p["samples"]
        return f"{p['study']}/exec/s{p['stage']}/c{p['combo']}/{lo}_{hi}"

    def execute_real(self, task: Task) -> None:
        p = task.payload
        study, nidx, iidx = p["study"], p["stage"], p["combo"]
        lo, hi = p["samples"]
        done_key = self._done_key(task)
        # idempotency: if a previous attempt *completed*, redelivered or
        # speculatively-duplicated copies no-op.  Failed attempts leave no
        # marker, so retries re-execute.
        if self.counters.once_exists(done_key):
            return
        spec = self._specs[study]
        node = self._dags[study].nodes[nidx]
        inst = node.instances[iidx]
        samples, _ = self._resolve_samples(study, node, inst)
        wdir = os.path.join(self.workspace, study, f"s{nidx}",
                            f"c{iidx}", f"b{lo:09d}_{hi:09d}")
        os.makedirs(wdir, exist_ok=True)
        ctx = Context(self, study, inst, samples, lo, hi, wdir,
                      spec.variables)
        handler = self._handler_for(node)
        for step in node.steps:
            handler.execute(self, step, ctx)
        # first completer wins; concurrent duplicates are safe (atomic writes)
        if self.counters.once(done_key):
            self._bundle_done(task)

    # -- coalesced execution of a lease batch --------------------------------
    def execute_real_many(self, tasks: Sequence[Task]) -> None:
        """Execute a batch of real tasks, fusing contiguous sample ranges.

        Coalescing policy: tasks from the same (study, node, instance)
        whose [lo, hi) ranges are contiguous — the common case when one
        ``get_many`` drains a generator's leaf burst — execute as ONE step
        invocation over the union range (one fused vmap launch for ensemble
        steps) with ``ctx.sub_ranges`` carrying the original spans.  Only
        sample-parallel nodes with in-process handlers coalesce; subprocess
        / scheduler steps and funnel nodes keep per-task execution (their
        workspace layout is per-task).  Idempotency is unchanged: every
        original task still gets its own once-marker and ``_bundle_done``
        accounting, and already-done tasks are skipped before grouping.
        If a fused execution fails, the whole group falls back to per-task
        ``execute_real`` so one poison task cannot take down its
        batch-mates' progress or retry accounting.
        """
        self._execute_many(tasks, deferred=False)

    def execute_real_many_deferred(
            self, tasks: Sequence[Task]) -> Callable[[], None]:
        """Pipelined variant of :meth:`execute_real_many` for the engine's
        writer thread: device compute for every fused run is dispatched
        *now* (asynchronously), while the host-side completion — the
        ``block_until_ready`` sync, bundle writes, and once-markers — is
        packaged into the returned ``finalize()`` callable.  The engine
        runs finalize on its single writer thread, so the dispatch of
        batch N+1 overlaps the write of batch N.  Exceptions inside
        finalize propagate; the engine then re-runs the batch per-task
        (completed runs no-op on their once-markers)."""
        return self._execute_many(tasks, deferred=True)

    def _execute_many(self, tasks: Sequence[Task],
                      deferred: bool) -> Optional[Callable[[], None]]:
        groups: Dict[tuple, List[Task]] = {}
        singles: List[Task] = []
        for t in tasks:
            if self.counters.once_exists(self._done_key(t)):
                continue  # a previous attempt completed: no-op, no re-count
            p = t.payload
            node = self._dags[p["study"]].nodes[p["stage"]]
            if self._node_fusable(node):
                groups.setdefault((p["study"], p["stage"], p["combo"]),
                                  []).append(t)
            else:
                singles.append(t)
        for t in singles:
            self.execute_real(t)
        if deferred:
            fins = []
            for run in self._contiguous_runs(groups):
                try:
                    fins.append(self._execute_coalesced(run, deferred=True))
                except Exception:
                    # poison run: its compute failure must not discard the
                    # sibling runs already dispatched (their once-markers
                    # live in finalize) — package this run's per-task
                    # retry into the finalize stage instead, mirroring the
                    # sync path's per-run isolation
                    fins.append(lambda run=run: [self.execute_real(t)
                                                 for t in run])

            def finalize() -> None:
                for fin in fins:
                    fin()
            return finalize
        for run in self._contiguous_runs(groups):
            if len(run) == 1:
                self.execute_real(run[0])
                continue
            try:
                self._execute_coalesced(run)
            except Exception:
                for t in run:  # isolate the failure: per-task retry semantics
                    self.execute_real(t)
        return None

    @staticmethod
    def _contiguous_runs(groups: Dict[tuple, List[Task]]) -> List[List[Task]]:
        runs: List[List[Task]] = []
        for ts in groups.values():
            ts.sort(key=lambda t: t.payload["samples"][0])
            cur = [ts[0]]
            for t in ts[1:]:
                if t.payload["samples"][0] == cur[-1].payload["samples"][1]:
                    cur.append(t)
                else:
                    runs.append(cur)
                    cur = [t]
            runs.append(cur)
        return runs

    def _execute_coalesced(self, run: List[Task],
                           deferred: bool = False
                           ) -> Optional[Callable[[], None]]:
        """One fused execution covering a contiguous run of leaf tasks.

        With ``deferred=True`` the steps run now (device compute
        dispatches asynchronously; steps park their host sync + artifact
        writes on ``ctx.defer``) and the returned closure performs the
        deferred completion work *then* sets the once-markers — durable
        write strictly before the marker that suppresses re-execution."""
        p = run[0].payload
        study, nidx, iidx = p["study"], p["stage"], p["combo"]
        lo = p["samples"][0]
        hi = run[-1].payload["samples"][1]
        spec = self._specs[study]
        node = self._dags[study].nodes[nidx]
        inst = node.instances[iidx]
        samples, _ = self._resolve_samples(study, node, inst)
        wdir = os.path.join(self.workspace, study, f"s{nidx}",
                            f"c{iidx}", f"b{lo:09d}_{hi:09d}")
        os.makedirs(wdir, exist_ok=True)
        ctx = Context(self, study, inst, samples, lo, hi, wdir,
                      spec.variables,
                      sub_ranges=[tuple(t.payload["samples"]) for t in run],
                      deferred=deferred)
        handler = self._handler_for(node)
        for step in node.steps:
            handler.execute(self, step, ctx)

        def finalize() -> None:
            ctx.run_deferred()
            for t in run:  # per-sub-bundle markers + node accounting
                if self.counters.once(self._done_key(t)):
                    self._bundle_done(t)
        if deferred:
            return finalize
        finalize()
        return None

    # -- completion ----------------------------------------------------------
    def study_done(self, study: str) -> bool:
        dag = self._dags[study]
        return all(
            self.counters.once_exists(f"{study}/s{n}/c{i}/advance")
            for n, i in dag.all_instances())

    def wait(self, study: str, timeout: float = 120.0, poll: float = 0.02) -> bool:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.study_done(study):
                return True
            if self.study_halted(study):
                return False  # halt is terminal: don't poll out the timeout
            time.sleep(poll)
        return False


def _spec_to_dict(spec: StudySpec) -> Dict:
    import dataclasses as dc
    return {"name": spec.name, "parameters": spec.parameters,
            "variables": spec.variables,
            "steps": [dc.asdict(s) for s in spec.steps]}


def _spec_from_dict(d: Dict) -> StudySpec:
    steps = []
    for s in d["steps"]:
        kw = dict(s)
        kw["depends"] = tuple(kw.get("depends", ()))
        if kw.get("params") is not None:
            kw["params"] = tuple(kw["params"])
        steps.append(Step(**kw))
    return StudySpec(name=d["name"], steps=steps,
                     parameters=d.get("parameters", {}),
                     variables=d.get("variables", {}))
