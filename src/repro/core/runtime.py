"""The Merlin producer-consumer runtime.

``MerlinRuntime.run(spec, samples)`` is ``merlin run``: it expands the DAG
parameters, splits the steps into *stages* (maximal chains of sample-
parallel steps, separated by funnel steps), and enqueues ONE root
generation task per (parameter-combo × first stage) — the near-non-blocking
producer of Sec. 2.3.  Workers (core/worker.py) expand the hierarchy,
execute sample bundles, and — Celery-chord-like, fully decentralized —
whichever worker completes a stage's last bundle enqueues the next stage.
Stage completion is tracked through crash-safe file counters (flock), so
workers in different processes / "batch allocations" coordinate without a
central orchestrator, and a restarted run resumes from the journal.

Steps may call ``ctx.runtime.run(...)`` — dynamic workflow creation from
inside a step, which is how the COVID cascade launches its second phase.
"""
from __future__ import annotations

import fcntl
import json
import os
import subprocess
import threading
import time
import uuid
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import hierarchy as H
from repro.core.queue import (PRIORITY_GEN, PRIORITY_REAL, InMemoryBroker,
                              Lease, Task, new_task)
from repro.core.spec import Step, StudySpec, expand_parameters, substitute, topo_order


# ---------------------------------------------------------------------------
# crash-safe counters / once-markers / journal
# ---------------------------------------------------------------------------

class FileCounter:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key.replace("/", "_") + ".cnt")

    def incr(self, key: str, by: int = 1) -> int:
        path = self._path(key)
        fd = os.open(path, os.O_RDWR | os.O_CREAT)
        with os.fdopen(fd, "r+") as f:
            fcntl.flock(f, fcntl.LOCK_EX)
            raw = f.read().strip()
            val = (int(raw) if raw else 0) + by
            f.seek(0)
            f.truncate()
            f.write(str(val))
            f.flush()
            return val

    def get(self, key: str) -> int:
        try:
            with open(self._path(key)) as f:
                raw = f.read().strip()
                return int(raw) if raw else 0
        except FileNotFoundError:
            return 0

    def once(self, key: str) -> bool:
        """True exactly once per key across all processes (O_EXCL)."""
        path = os.path.join(self.root, key.replace("/", "_") + ".once")
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            os.close(fd)
            return True
        except FileExistsError:
            return False

    def once_exists(self, key: str) -> bool:
        return os.path.exists(
            os.path.join(self.root, key.replace("/", "_") + ".once"))


class Journal:
    """Append-only jsonl event log (provenance + restart/crawl substrate)."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path), exist_ok=True)
        self._lock = threading.Lock()

    def append(self, event: Dict[str, Any]) -> None:
        event = {"t": time.time(), **event}
        # leading newline isolates this record from any torn write a crashed
        # worker left behind; replay skips the blank lines it creates
        line = "\n" + json.dumps(event) + "\n"
        with self._lock, open(self.path, "a") as f:
            fcntl.flock(f, fcntl.LOCK_EX)
            f.write(line)

    def replay(self) -> List[Dict[str, Any]]:
        if not os.path.exists(self.path):
            return []
        out = []
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if line:
                    try:
                        out.append(json.loads(line))
                    except json.JSONDecodeError:
                        pass  # torn write from a crashed worker
        return out

    def done_bundles(self, study: str) -> set:
        done = set()
        for ev in self.replay():
            if ev.get("ev") == "bundle_done" and ev.get("study") == study:
                done.add((ev["stage"], ev["combo"], ev["lo"], ev["hi"]))
        return done


# ---------------------------------------------------------------------------
# stage planning
# ---------------------------------------------------------------------------

def plan_stages(spec: StudySpec) -> List[Dict[str, Any]]:
    """Split topologically-ordered steps into stages.

    A run of consecutive ``over_samples`` steps forms one parallel stage
    (executed as a chain inside each sample-bundle task); each funnel step
    (over_samples=False or a ``_*`` dependency) is its own single stage.
    """
    stages: List[Dict[str, Any]] = []
    chain: List[Step] = []
    for s in topo_order(spec):
        funnel = (not s.over_samples) or any(d.endswith("_*") for d in s.depends)
        if funnel:
            if chain:
                stages.append({"kind": "parallel", "steps": chain})
                chain = []
            stages.append({"kind": "single", "steps": [s]})
        else:
            chain.append(s)
    if chain:
        stages.append({"kind": "parallel", "steps": chain})
    return stages


# ---------------------------------------------------------------------------
# runtime
# ---------------------------------------------------------------------------

class Context:
    """Execution context handed to fn-steps.

    ``sub_ranges`` is the coalescing contract: when a worker fuses several
    contiguous leaf tasks into one execution (``execute_real_many``), the
    step sees ONE context spanning the union [lo, hi) plus the original
    per-task [slo, shi) spans.  Steps that write per-range artifacts (the
    ensemble executor's bundle files) iterate ``sub_ranges`` so the on-disk
    layout is identical to per-task execution; steps that ignore it simply
    process the whole block at once.
    """

    def __init__(self, runtime: "MerlinRuntime", study: str, combo: Dict,
                 samples: Optional[np.ndarray], lo: int, hi: int,
                 workspace: str, variables: Dict,
                 sub_ranges: Optional[Sequence[tuple]] = None):
        self.runtime = runtime
        self.study = study
        self.combo = combo
        self.samples = samples
        self.lo, self.hi = lo, hi
        self.workspace = workspace
        self.variables = variables
        self.sub_ranges = list(sub_ranges) if sub_ranges else [(lo, hi)]

    @property
    def sample_block(self) -> Optional[np.ndarray]:
        return None if self.samples is None else self.samples[self.lo:self.hi]


class MerlinRuntime:
    def __init__(self, broker=None, workspace: str = "/tmp/merlin",
                 fns: Optional[Dict[str, Callable]] = None,
                 hierarchy: H.HierarchyCfg = H.HierarchyCfg(),
                 real_queue: str = "real", gen_queue: str = "gen"):
        # broker may be a Broker instance or a URL: "tcp://host:port"
        # connects to a remote BrokerServer (no shared filesystem for the
        # queue — the paper's cross-allocation RabbitMQ model), "file://dir"
        # a shared-directory FileBroker, "mem://" a private InMemoryBroker,
        # "shard://h1:p1,h2:p2" (or a list of tcp:// endpoints) a
        # ShardedBroker federating several BrokerServers by queue name.
        if isinstance(broker, (str, list, tuple)):
            from repro.core.netbroker import make_broker
            broker = make_broker(broker)
        self.broker = broker if broker is not None else InMemoryBroker()
        self.workspace = workspace
        os.makedirs(workspace, exist_ok=True)
        self.fns = dict(fns or {})
        self.hcfg = hierarchy
        # Sec. 2.2 routing: simulation (real) tasks and task-generation
        # tasks live on separate named queues so workers can subscribe to
        # either stream; priority still drains real before gen globally.
        self.real_queue = real_queue
        self.gen_queue = gen_queue
        self.counters = FileCounter(os.path.join(workspace, "_counters"))
        self.journal = Journal(os.path.join(workspace, "_journal.jsonl"))
        # one micro-batching ExecutionEngine per runtime (lazily created):
        # every WorkerPool attached to this runtime feeds the same
        # scheduler, so fused launches span pools as well as workers
        self._engine = None
        self._engine_lock = threading.Lock()
        self._specs: Dict[str, StudySpec] = {}
        self._stages: Dict[str, List[Dict]] = {}
        self._samples: Dict[str, Optional[np.ndarray]] = {}
        self._combos: Dict[str, List[Dict]] = {}

    def register(self, name: str, fn: Callable) -> None:
        self.fns[name] = fn

    def shared_engine(self, **cfg):
        """This runtime's shared :class:`~repro.core.engine.ExecutionEngine`
        (created on first use, re-created after the last pool closed it).

        Returns the engine with one reference attached — callers pair this
        with ``engine.detach()`` (WorkerPool does both automatically).
        ``cfg`` (``max_batch``, ``max_wait_ms``) only applies when this
        call creates the engine; later callers share the first
        configuration.
        """
        from repro.core.engine import EngineClosed, ExecutionEngine
        with self._engine_lock:
            while True:
                if self._engine is None or self._engine.closed:
                    self._engine = ExecutionEngine(self, **cfg)
                try:
                    return self._engine.attach()
                except EngineClosed:
                    # lost a race with the last pool's detach-close:
                    # build a fresh engine on the next spin
                    self._engine = None

    # -- producer ("merlin run") -------------------------------------------
    def run(self, spec: StudySpec, samples: Optional[np.ndarray] = None,
            study_id: Optional[str] = None) -> str:
        spec.validate()
        study = study_id or f"{spec.name}-{uuid.uuid4().hex[:8]}"
        self._specs[study] = spec
        self._stages[study] = plan_stages(spec)
        self._samples[study] = samples
        self._combos[study] = expand_parameters(spec)
        n = len(samples) if samples is not None else self.hcfg.bundle
        # persist study metadata so cross-process workers can reconstruct it
        meta = {"study": study, "n_samples": n,
                "spec": _spec_to_dict(spec)}
        mpath = os.path.join(self.workspace, f"{study}.study.json")
        # samples first, then meta, both via atomic rename: attach() treats
        # the meta file as the commit point, so a crash mid-persist must
        # never leave valid meta next to a missing/torn samples file
        if samples is not None:
            spath = os.path.join(self.workspace, f"{study}.samples.npy")
            with open(spath + ".tmp", "wb") as f:
                np.save(f, samples)
            os.rename(spath + ".tmp", spath)
        with open(mpath + ".tmp", "w") as f:
            json.dump(meta, f)
        os.rename(mpath + ".tmp", mpath)
        self.journal.append({"ev": "study_start", "study": study, "n": n})
        for ci in range(len(self._combos[study])):
            self._enqueue_stage(study, 0, ci, n)
        return study

    def _enqueue_stage(self, study: str, stage_idx: int, combo_idx: int,
                       n_samples: int) -> None:
        stages = self._stages[study]
        if stage_idx >= len(stages):
            if self.counters.once(f"{study}/done/{combo_idx}"):
                self.journal.append({"ev": "combo_done", "study": study,
                                     "combo": combo_idx})
            return
        st = stages[stage_idx]
        extra = {"study": study, "stage": stage_idx, "combo": combo_idx,
                 "n_samples": n_samples,
                 "real_queue": self.real_queue, "gen_queue": self.gen_queue}
        if st["kind"] == "single":
            self.broker.put(new_task("real", {**extra, "samples": [0, 1],
                                              "fanout": self.hcfg.max_fanout,
                                              "bundle": 1},
                                     priority=PRIORITY_REAL,
                                     queue=self.real_queue))
        else:
            self.broker.put(H.root_task(study, str(stage_idx), n_samples,
                                        self.hcfg, extra=extra))
        self.journal.append({"ev": "stage_start", "study": study,
                             "stage": stage_idx, "combo": combo_idx})

    def attach(self, study: str) -> str:
        """Load a study persisted by another runtime instance's ``run()``.

        Reconstructs the spec/stages/combos/samples from the workspace's
        ``<study>.study.json`` + ``<study>.samples.npy`` so workers in a
        fresh process (a new "batch allocation", or a restart after a
        crash) can execute and advance a study they did not start.  Stage
        counters and once-markers live on disk, so progress made before the
        crash is preserved.
        """
        mpath = os.path.join(self.workspace, f"{study}.study.json")
        with open(mpath) as f:
            meta = json.load(f)
        spec = _spec_from_dict(meta["spec"])
        spec.validate()
        self._specs[study] = spec
        self._stages[study] = plan_stages(spec)
        self._combos[study] = expand_parameters(spec)
        spath = os.path.join(self.workspace, f"{study}.samples.npy")
        self._samples[study] = np.load(spath) if os.path.exists(spath) else None
        return study

    # -- stage bookkeeping (called by workers at bundle completion) ---------
    def _bundle_done(self, task: Task) -> None:
        p = task.payload
        study, stage, combo = p["study"], p["stage"], p["combo"]
        n = p["n_samples"]
        st = self._stages[study][stage]
        if st["kind"] == "single":
            expected = 1
        else:
            # bundle size from the task payload, not this process's hcfg: a
            # runtime that attach()ed with a different config must still
            # agree with the producer on how many bundles complete a stage
            expected = -(-n // p.get("bundle", self.hcfg.bundle))
        key = f"{study}/s{stage}/c{combo}"
        done = self.counters.incr(key)
        self.journal.append({"ev": "bundle_done", "study": study,
                             "stage": stage, "combo": combo,
                             "lo": p["samples"][0], "hi": p["samples"][1]})
        if done >= expected and self.counters.once(key + "/advance"):
            self.journal.append({"ev": "stage_done", "study": study,
                                 "stage": stage, "combo": combo})
            self._enqueue_stage(study, stage + 1, combo, n)

    # -- execution of a real task -------------------------------------------
    @staticmethod
    def _stage_fusable(stage: Dict[str, Any]) -> bool:
        """THE fusion predicate — the single definition both the worker's
        engine-routing decision (``coalescable``) and the grouping in
        ``execute_real_many`` consult, so they can never disagree about
        what fuses."""
        return stage["kind"] == "parallel" and \
            all(s.fn is not None for s in stage["steps"])

    def coalescable(self, task: Task) -> bool:
        """True when this real task can profit from fused execution: its
        stage is a parallel run of fn-steps (the only thing
        ``execute_real_many`` fuses).  Cmd-step and funnel-stage tasks —
        and tasks for studies this runtime does not know — return False:
        workers run those in their own threads, where N workers really do
        mean N concurrent subprocesses, instead of serializing them behind
        the engine's single dispatcher."""
        try:
            p = task.payload
            stage = self._stages[p["study"]][p["stage"]]
        except (KeyError, IndexError, TypeError):
            return False
        return self._stage_fusable(stage)

    @staticmethod
    def _done_key(task: Task) -> str:
        p = task.payload
        lo, hi = p["samples"]
        return f"{p['study']}/exec/s{p['stage']}/c{p['combo']}/{lo}_{hi}"

    def execute_real(self, task: Task) -> None:
        p = task.payload
        study, stage_idx, combo_idx = p["study"], p["stage"], p["combo"]
        lo, hi = p["samples"]
        done_key = self._done_key(task)
        # idempotency: if a previous attempt *completed*, redelivered or
        # speculatively-duplicated copies no-op.  Failed attempts leave no
        # marker, so retries re-execute.
        if self.counters.once_exists(done_key):
            return
        spec = self._specs[study]
        stage = self._stages[study][stage_idx]
        combo = self._combos[study][combo_idx]
        samples = self._samples.get(study)
        wdir = os.path.join(self.workspace, study, f"s{stage_idx}",
                            f"c{combo_idx}", f"b{lo:09d}_{hi:09d}")
        os.makedirs(wdir, exist_ok=True)
        ctx = Context(self, study, combo, samples, lo, hi, wdir, spec.variables)
        for step in stage["steps"]:
            self._run_step(step, ctx)
        # first completer wins; concurrent duplicates are safe (atomic writes)
        if self.counters.once(done_key):
            self._bundle_done(task)

    # -- coalesced execution of a lease batch --------------------------------
    def execute_real_many(self, tasks: Sequence[Task]) -> None:
        """Execute a batch of real tasks, fusing contiguous sample ranges.

        Coalescing policy: tasks from the same (study, stage, combo) whose
        [lo, hi) ranges are contiguous — the common case when one
        ``get_many`` drains a generator's leaf burst — execute as ONE step
        invocation over the union range (one fused vmap launch for ensemble
        steps) with ``ctx.sub_ranges`` carrying the original spans.  Only
        parallel stages made of fn-steps coalesce; cmd steps and funnel
        stages keep per-task execution (their workspace layout is per-task).
        Idempotency is unchanged: every original task still gets its own
        once-marker and ``_bundle_done`` accounting, and already-done tasks
        are skipped before grouping.  If a fused execution fails, the whole
        group falls back to per-task ``execute_real`` so one poison task
        cannot take down its batch-mates' progress or retry accounting.
        """
        groups: Dict[tuple, List[Task]] = {}
        singles: List[Task] = []
        for t in tasks:
            if self.counters.once_exists(self._done_key(t)):
                continue  # a previous attempt completed: no-op, no re-count
            p = t.payload
            stage = self._stages[p["study"]][p["stage"]]
            if self._stage_fusable(stage):
                groups.setdefault((p["study"], p["stage"], p["combo"]),
                                  []).append(t)
            else:
                singles.append(t)
        for t in singles:
            self.execute_real(t)
        for run in self._contiguous_runs(groups):
            if len(run) == 1:
                self.execute_real(run[0])
                continue
            try:
                self._execute_coalesced(run)
            except Exception:
                for t in run:  # isolate the failure: per-task retry semantics
                    self.execute_real(t)

    @staticmethod
    def _contiguous_runs(groups: Dict[tuple, List[Task]]) -> List[List[Task]]:
        runs: List[List[Task]] = []
        for ts in groups.values():
            ts.sort(key=lambda t: t.payload["samples"][0])
            cur = [ts[0]]
            for t in ts[1:]:
                if t.payload["samples"][0] == cur[-1].payload["samples"][1]:
                    cur.append(t)
                else:
                    runs.append(cur)
                    cur = [t]
            runs.append(cur)
        return runs

    def _execute_coalesced(self, run: List[Task]) -> None:
        """One fused execution covering a contiguous run of leaf tasks."""
        p = run[0].payload
        study, stage_idx, combo_idx = p["study"], p["stage"], p["combo"]
        lo = p["samples"][0]
        hi = run[-1].payload["samples"][1]
        spec = self._specs[study]
        stage = self._stages[study][stage_idx]
        combo = self._combos[study][combo_idx]
        samples = self._samples.get(study)
        wdir = os.path.join(self.workspace, study, f"s{stage_idx}",
                            f"c{combo_idx}", f"b{lo:09d}_{hi:09d}")
        os.makedirs(wdir, exist_ok=True)
        ctx = Context(self, study, combo, samples, lo, hi, wdir,
                      spec.variables,
                      sub_ranges=[tuple(t.payload["samples"]) for t in run])
        for step in stage["steps"]:
            self._run_step(step, ctx)
        for t in run:  # per-sub-bundle markers + stage accounting, as before
            if self.counters.once(self._done_key(t)):
                self._bundle_done(t)

    def _run_step(self, step: Step, ctx: Context) -> None:
        if step.fn is not None:
            self.fns[step.fn](ctx)
            return
        env = {**ctx.variables, **ctx.combo,
               "SAMPLE_LO": ctx.lo, "SAMPLE_HI": ctx.hi,
               "WORKSPACE": ctx.workspace, "MERLIN_STUDY": ctx.study}
        cmd = substitute(step.cmd or "", env)
        script = os.path.join(ctx.workspace, f"{step.name}.sh")
        with open(script, "w") as f:
            f.write(cmd if cmd.endswith("\n") else cmd + "\n")
        res = subprocess.run([step.shell, script], cwd=ctx.workspace,
                             capture_output=True, text=True, timeout=600)
        if res.returncode != 0:
            raise RuntimeError(
                f"step {step.name} failed rc={res.returncode}: {res.stderr[-500:]}")

    # -- completion ----------------------------------------------------------
    def study_done(self, study: str) -> bool:
        n_combos = len(self._combos[study])
        return all(self.counters.once_exists(f"{study}/done/{ci}")
                   for ci in range(n_combos))

    def wait(self, study: str, timeout: float = 120.0, poll: float = 0.02) -> bool:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.study_done(study):
                return True
            time.sleep(poll)
        return False


def _spec_to_dict(spec: StudySpec) -> Dict:
    import dataclasses as dc
    return {"name": spec.name, "parameters": spec.parameters,
            "variables": spec.variables,
            "steps": [dc.asdict(s) for s in spec.steps]}


def _spec_from_dict(d: Dict) -> StudySpec:
    steps = [Step(**{**s, "depends": tuple(s.get("depends", ()))})
             for s in d["steps"]]
    return StudySpec(name=d["name"], steps=steps,
                     parameters=d.get("parameters", {}),
                     variables=d.get("variables", {}))
