"""Task brokers: the Celery/RabbitMQ stand-in (DESIGN.md mapping C1).

Routing semantics (paper Sec. 2.2-3.1):

* **Named queues.** Every :class:`Task` carries a ``queue`` name and is
  delivered *only* to consumers subscribed to that queue — the analogue of
  RabbitMQ routing keys, which is how the paper pins simulation workers and
  ML workers to disjoint work streams.  ``get(queues=None)`` subscribes to
  every queue; ``get(queues=("sims",))`` sees only ``sims`` tasks.
* **Priorities across queues.** Within a consumer's subscription, tasks are
  delivered in global ``(priority, enqueue-sequence)`` order: real
  simulation tasks (PRIORITY_REAL) drain before task-generation tasks
  (PRIORITY_GEN) even when they live in different queues — the paper's
  server-stability property (drain the queue before filling it).
* **Leases.** A claim is a lease with a visibility timeout: a worker that
  dies mid-task never acks, the lease expires, and the task is redelivered
  with ``task.retries`` incremented — identically in both backends, so
  retry caps (core/resilience.py) behave the same everywhere.  Delivery is
  at-least-once; execution idempotency is the runtime's job (once-markers).
* **Batched operations.** ``get_many``/``ack_many``/``put_many`` amortize
  lock/filesystem round-trips for high-throughput draining
  (benchmarks/broker_throughput.py).

The interface is the formal :class:`Broker` protocol below.  Three
implementations:

* :class:`InMemoryBroker` — thread-safe, condition-variable based (no
  polling slices), per-queue binary heaps; for in-process worker pools and
  the performance benchmarks (Figs. 3-6 analogues).
* :class:`FileBroker` — directory-backed, multiprocess-safe via atomic
  renames (claim = rename into ``claimed/``), one subdirectory per named
  queue, and a cached in-memory index keyed by ``(priority, seq)`` so the
  claim hot path does NOT re-list + re-sort the directory per task.
  Independent worker *processes* ("batch allocations") can attach to a
  shared queue directory — the surge-computing model of Sec. 3.
* :class:`repro.core.netbroker.NetBroker` — a TCP client speaking to a
  :class:`repro.core.netbroker.BrokerServer` fronting either backend above:
  allocations on *different nodes* coordinate with no shared filesystem at
  all, the paper's actual RabbitMQ deployment model.
* :class:`repro.core.shardbroker.ShardedBroker` — the federation layer:
  the full protocol over N endpoints, each *queue* routed to one shard by
  stable hash, for when ensemble throughput outgrows one broker process.

Cross-cutting policies, identical in every backend:

* **Per-queue visibility timeouts** (``queue_timeouts=`` /
  ``set_visibility_timeout``): a long-running simulation queue and a fast
  generation queue no longer share one lease clock.
* **Fairness** (``fairness="weighted"``, ``queue_weights=``): optional
  weighted round-robin across the subscribed queues so one flooding queue
  cannot starve the others; strict global priority stays the default.
  ``stats["starvation_avoided"]`` counts deliveries where fairness picked a
  different queue than strict priority would have.
* **Backpressure** (``max_queue_depth=``, ``put_timeout=``): producers
  against a full queue block until it drains, then get a typed
  :class:`BrokerFull`; redelivery is exempt so recovery never wedges.
  Workers throttle generation-task expansion on it instead of dying.
  ``queue_depths=`` / ``set_max_queue_depth(queue, depth)`` override the
  bound per named queue (``depth=None`` clears an override back to the
  global bound) — a flood-prone generation queue can be clamped tight
  while the simulation queue stays deep.  FileBroker persists overrides
  to ``<root>/.depth.json`` (like ``.vt.json``) so every instance on the
  directory honors them; NetBroker relays the op, and ShardedBroker
  routes it to the queue's owning shard.
* **Consumer heartbeats** (``heartbeat(consumer_id, queues)``,
  ``heartbeat_ttl=``): ``stats["consumers"]`` is a live per-queue
  consumer count instead of a connection-count guess — the basis for
  "are there any workers on the sims queue?" operational checks.
* **Queue-name validation**: enforced once, at :class:`Task` creation
  (``validate_queue_name``), so a name FileBroker cannot store fails
  identically and immediately on every backend.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import json
import os
import threading
import time
import uuid
from typing import (Any, Dict, Iterable, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

from repro.core import jsonstore


class BrokerError(RuntimeError):
    """A broker operation failed (bad request, protocol violation)."""


class BrokerUnavailable(BrokerError, ConnectionError):
    """The broker cannot be reached (remote down / unreachable).

    Raised by :class:`repro.core.netbroker.NetBroker` after its reconnect
    window is exhausted; consumers (core/worker.py) treat it as transient
    and keep polling so a restarted broker server is picked back up."""


class BrokerFull(BrokerError):
    """Backpressure: a put could not complete within ``put_timeout``
    because the target queue is at ``max_queue_depth``.

    ``put``/``put_many`` block first and raise only at the deadline;
    ``put_timeout`` bounds the TOTAL blocking time of one call (not one
    stall), so a server-side put relayed by a BrokerServer can never park
    a handler thread longer than ``put_timeout`` — keep it below the
    clients' ``request_grace`` (10 s) and a blocked put always surfaces
    as this typed error, never as a socket timeout.  In a ``put_many``
    the tasks admitted before the raise ARE enqueued (delivery is
    at-least-once, so retrying is safe — duplicates no-op on the
    runtime's once-markers; retry in bounded chunks, as the worker's gen
    expansion does, so re-sent prefixes stay small).  Producers should
    throttle and retry, never treat this as fatal."""


class StaleEpochError(BrokerError):
    """An ack/nack carried a lease tag minted under a superseded shard
    epoch (the tag's primary died and ownership failed over).  The old
    primary's leases are fenced: the operation is rejected so a zombie
    cannot complete work the new primary has already redelivered."""


def validate_queue_name(queue: str) -> str:
    """The ONE queue-name rule, enforced at Task creation for every backend.

    ``__`` is the FileBroker claim-file field separator, ``/`` would escape
    the queue directory, and a leading ``.`` collides with temp/hidden
    files — but a name must fail identically on InMemoryBroker/NetBroker
    too, or the same study spec runs on ``mem://`` and crashes mid-run the
    first time it is pointed at ``file://`` (or poisons one shard of a
    federation late in a run)."""
    if not queue or "__" in queue or "/" in queue or queue.startswith("."):
        raise ValueError(
            f"invalid queue name {queue!r}: must be non-empty and contain "
            "no '__' or '/', and not start with '.'")
    return queue

# priorities: lower = served first.  Real work drains before generation work.
PRIORITY_REAL = 0
PRIORITY_GEN = 1
PRIORITY_LOW = 2

# --- dead-letter queues ------------------------------------------------------
# A task whose step policy is ``on_failure: dead_letter`` moves, at retry
# exhaustion, to ``dlq.<original queue>`` on the SAME broker.  DLQ queues are
# ordinary queues for explicit addressing (merlin-dlq lists/inspects/requeues
# them over any broker URL) but are EXCLUDED from wildcard subscriptions,
# wildcard qsize, and idle() — otherwise any ``queues=None`` worker would
# re-execute dead letters forever and a drain would wedge on them.
DLQ_PREFIX = "dlq."


def dlq_queue_name(queue: str) -> str:
    return queue if is_dlq(queue) else DLQ_PREFIX + queue


def is_dlq(queue: str) -> bool:
    return queue.startswith(DLQ_PREFIX)


def original_queue(queue: str) -> str:
    return queue[len(DLQ_PREFIX):] if is_dlq(queue) else queue


@dataclasses.dataclass
class Task:
    id: str
    kind: str  # "gen" | "real" | "step" | custom
    payload: Dict[str, Any]
    priority: int = PRIORITY_REAL
    queue: str = "default"
    retries: int = 0
    enqueued_at: float = 0.0

    def __post_init__(self) -> None:
        # every construction path — new_task, from_json, the wire layer's
        # Task(**d) — funnels through here, so a bad queue name fails at
        # task creation in EVERY backend, not at FileBroker's first put
        validate_queue_name(self.queue)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @staticmethod
    def from_json(s: str) -> "Task":
        return Task(**json.loads(s))


_TASK_FIELDS = tuple(f.name for f in dataclasses.fields(Task))


def task_to_wire(task: "Task") -> Dict[str, Any]:
    """Shallow task -> dict for immediate serialization.

    ``dataclasses.asdict`` recursively *deep-copies* every payload value
    (~0.5 ms per task at 512 payload floats — it dominated the broker
    wire hot path, dwarfing both codecs).  Encoders only read the tree,
    so sharing the payload references is safe; use this everywhere a
    task dict goes straight into a codec.
    """
    return {f: getattr(task, f) for f in _TASK_FIELDS}


# -- FileBroker task-file format ---------------------------------------------
# v1 is Task.to_json() text (first byte "{", readable forever); v2 is one
# format-version byte \x02 followed by the bin1 binary encoding of the
# task dict (core/wirecodec.py) — payloads dominated by float arrays skip
# text float formatting/parsing entirely.  Readers sniff the first byte,
# so directories mixing formats (rolling upgrade, old producers) just work.
TASK_FILE_V2_MAGIC = b"\x02"
_TASK_FORMATS = ("auto", "json", "binary")
_BIG_FLOAT_FIELD = 16  # floats; shorter lists aren't worth the binary path


def _has_big_float_field(obj: Any, depth: int = 0) -> bool:
    """Does this payload contain a float list long enough that binary
    array encoding pays?  Cheap structural sniff, not a full scan."""
    if depth > 4:
        return False
    if isinstance(obj, list):
        if len(obj) >= _BIG_FLOAT_FIELD and isinstance(obj[0], float):
            return True
        return any(_has_big_float_field(v, depth + 1) for v in obj[:32])
    if isinstance(obj, dict):
        return any(_has_big_float_field(v, depth + 1) for v in obj.values())
    # ndarray payloads (duck-typed: queue.py stays numpy-free) always
    # take the binary path — Task.to_json can't carry them at all
    return hasattr(obj, "dtype") and getattr(obj, "size", 0) > 0


def encode_task_file(task: "Task", fmt: str = "auto") -> bytes:
    """Serialize a task for a FileBroker task file.

    ``auto`` picks v2 binary only when the payload carries large numeric
    fields (everything else stays greppable JSON text); ``json`` forces
    v1 (what pre-v2 readers understand); ``binary`` forces v2.
    """
    if fmt == "binary" or (fmt == "auto"
                           and _has_big_float_field(task.payload)):
        from repro.core.wirecodec import BIN_CODEC
        return TASK_FILE_V2_MAGIC + BIN_CODEC.encode(task_to_wire(task))
    return task.to_json().encode("utf-8")


def decode_task_file(data: bytes) -> "Task":
    """Parse either task-file format (first-byte sniff)."""
    if data[:1] == TASK_FILE_V2_MAGIC:
        from repro.core.wirecodec import BIN_CODEC
        doc = BIN_CODEC.decode(data[1:])
        if not isinstance(doc, dict):
            raise ValueError("task file v2 does not hold a task object")
        return Task(**doc)
    return Task.from_json(data.decode("utf-8"))


# fast process-unique task ids: one random prefix + a counter.  uuid4 per
# task costs ~1.5us (os.urandom) and dominated hierarchy expansion at
# >1e5 tasks/s (§Perf host-side log in EXPERIMENTS.md).
_ID_PREFIX = uuid.uuid4().hex[:10]
_ID_SEQ = itertools.count()


def new_task(kind: str, payload: Dict[str, Any], *, priority: int = PRIORITY_REAL,
             queue: str = "default") -> Task:
    return Task(id=f"{_ID_PREFIX}{next(_ID_SEQ):011x}", kind=kind,
                payload=payload, priority=priority, queue=queue)


@dataclasses.dataclass
class Lease:
    task: Task
    tag: str


def _normalize_queues(queues) -> Optional[Tuple[str, ...]]:
    """None = all queues; a string is a single-queue subscription."""
    if queues is None:
        return None
    if isinstance(queues, str):
        return (queues,)
    return tuple(queues)


@runtime_checkable
class Broker(Protocol):
    """The formal broker contract every backend implements.

    Semantics (shared by InMemoryBroker, FileBroker, and NetBroker):

    * ``put``/``put_many`` enqueue; delivery is at-least-once, so producers
      may safely retry (execution idempotency is the runtime's job).
    * ``get``/``get_many(queues=...)`` claim leases from the subscribed
      queues (``None`` = all, a string = one queue), blocking up to
      ``timeout`` (``None`` = forever) for the *first* task only.
    * ``ack``/``ack_many`` complete a lease; acking an unknown or already
      acked tag is a **no-op** (idempotent — required for safe client
      retries over a network).
    * ``nack`` returns a lease to its queue immediately with
      ``task.retries`` incremented; an unacked lease does the same on its
      own once its queue's visibility timeout expires.
    * ``qsize``/``queue_names``/``inflight``/``idle`` introspect;
      ``stats`` is a dict of monotonic counters (``enqueued``, ``acked``,
      ``redelivered``, ``starvation_avoided``, ...) plus ``consumers``:
      a ``{queue: live-consumer-count}`` view built from heartbeats.
    * ``put``/``put_many`` against a queue at ``max_queue_depth`` block up
      to ``put_timeout`` then raise :class:`BrokerFull` (backpressure);
      redelivery (nack / lease expiry) is exempt so recovery never wedges.
      ``set_max_queue_depth(queue, depth)`` overrides the bound for one
      named queue (``None`` clears the override).
    * ``heartbeat(consumer_id, queues)`` registers/refreshes a consumer's
      subscription; entries older than the backend's ``heartbeat_ttl`` are
      dropped, so ``stats["consumers"]`` reports *live* consumers per
      queue instead of guessing from connection counts.  A ``None``
      subscription (all queues) is reported under ``"*"``.
    * ``set_visibility_timeout(queue, t)`` overrides the lease clock for
      one named queue; ``inflight_tasks()`` snapshots leased tasks with
      their lease ages (straggler reissue, core/resilience.py).
    """

    stats: Dict[str, Any]

    def put(self, task: Task) -> None: ...
    def put_many(self, tasks: List[Task]) -> None: ...
    def get(self, timeout: Optional[float] = 0.0,
            queues: Optional[Sequence[str]] = None) -> Optional[Lease]: ...
    def get_many(self, n: int, timeout: Optional[float] = 0.0,
                 queues: Optional[Sequence[str]] = None) -> List[Lease]: ...
    def ack(self, tag: str) -> None: ...
    def ack_many(self, tags: Iterable[str]) -> None: ...
    def nack(self, tag: str) -> None: ...
    def qsize(self, queues: Optional[Sequence[str]] = None) -> int: ...
    def queue_names(self) -> List[str]: ...
    def inflight(self) -> int: ...
    def idle(self) -> bool: ...
    def set_visibility_timeout(self, queue: str, timeout: float) -> None: ...
    def set_max_queue_depth(self, queue: str,
                            depth: Optional[int]) -> None: ...
    def inflight_tasks(self) -> List[Tuple[Task, float]]: ...
    def heartbeat(self, consumer_id: str,
                  queues: Optional[Sequence[str]] = None) -> None: ...


class _WeightedRR:
    """Weighted round-robin queue picker shared by both local backends.

    Each cycle grants every currently-backlogged queue ``weight`` delivery
    credits (default 1); queues are then served in rotation until the cycle's
    credits run out, at which point a fresh cycle starts.  A queue flooding
    10x faster than its neighbors therefore gets at most ``weight`` slots per
    cycle instead of monopolizing delivery.  Caller must hold the backend's
    lock — this object keeps no lock of its own.
    """

    def __init__(self, weights: Optional[Dict[str, float]] = None):
        self.weights = {q: max(1, int(w)) for q, w in (weights or {}).items()}
        self.credits: Dict[str, int] = {}
        self.last: Optional[str] = None

    def pick(self, nonempty: Sequence[str]) -> str:
        order = sorted(nonempty)
        if all(self.credits.get(q, 0) <= 0 for q in order):
            # new cycle: only backlogged queues get credits, so an idle
            # queue cannot bank slots it will never use
            self.credits = {q: self.weights.get(q, 1) for q in order}
        start = (order.index(self.last) + 1) % len(order) \
            if self.last in order else 0
        for i in range(len(order)):
            q = order[(start + i) % len(order)]
            if self.credits.get(q, 0) > 0:
                self.credits[q] -= 1
                self.last = q
                return q
        # unreachable (the reset above guarantees a credit), but never pick
        # nothing if it somehow is
        self.last = order[start]
        return order[start]


def _check_fairness(fairness: str) -> str:
    if fairness not in ("priority", "weighted"):
        raise ValueError(f"fairness must be 'priority' or 'weighted', "
                         f"got {fairness!r}")
    return fairness


class InMemoryBroker:
    """Thread-safe multi-queue priority broker with visibility timeouts."""

    def __init__(self, visibility_timeout: float = 60.0,
                 queue_timeouts: Optional[Dict[str, float]] = None,
                 fairness: str = "priority",
                 queue_weights: Optional[Dict[str, float]] = None,
                 max_queue_depth: Optional[int] = None,
                 put_timeout: float = 5.0,
                 heartbeat_ttl: float = 15.0,
                 queue_depths: Optional[Dict[str, int]] = None):
        self._lock = threading.Condition()
        self._heaps: Dict[str, List[Tuple[int, int, Task]]] = {}
        self._seq = itertools.count()
        # tag -> (task, leased-at).  Expiry is computed at sweep time from
        # the queue's CURRENT visibility timeout (not frozen at lease time)
        # so set_visibility_timeout acts retroactively on in-flight leases,
        # exactly like FileBroker's sweep — the backends must not diverge
        # behind a NetBroker.
        self._leased: Dict[str, Tuple[Task, float]] = {}
        self._vt = visibility_timeout
        self._vt_queue: Dict[str, float] = dict(queue_timeouts or {})
        self._fairness = _check_fairness(fairness)
        self._rr = _WeightedRR(queue_weights)
        # backpressure: producers block while a queue holds max_queue_depth
        # pending tasks, and raise BrokerFull after put_timeout seconds
        # without forward progress.  None = unbounded (the default).
        self._max_depth = None if max_queue_depth is None \
            else max(1, int(max_queue_depth))
        # per-queue depth overrides take precedence over the global bound
        # (a queue can be bounded on an otherwise-unbounded broker)
        self._depth_queue: Dict[str, int] = {
            q: max(1, int(d)) for q, d in (queue_depths or {}).items()}
        self._put_timeout = put_timeout
        # consumer heartbeats: id -> (subscribed queues or None, last-seen)
        self._hb_ttl = heartbeat_ttl
        self._consumers: Dict[str, Tuple[Optional[Tuple[str, ...]], float]] = {}
        self._stats = {"enqueued": 0, "acked": 0, "redelivered": 0,
                       "starvation_avoided": 0}
        # per-queue ack counters feed merlin-status --watch throughput
        self._acked_q: Dict[str, int] = {}
        # live-migration marks: queue -> forward target URL.  While set,
        # consumers see the queue as empty, new puts forward to the
        # target, and in-flight leases drain in place (their acks/nacks
        # still land here).  See ShardedBroker.migrate_queue_between.
        self._migrating: Dict[str, str] = {}
        self._fwd_clients: Dict[str, Any] = {}

    @property
    def stats(self) -> Dict[str, Any]:
        with self._lock:
            s = dict(self._stats)
            s["acked_by_queue"] = dict(self._acked_q)
            s["consumers"] = self._consumers_view_locked()
            if self._migrating:
                s["migrating"] = sorted(self._migrating)
        return s

    # -- consumer heartbeats -------------------------------------------------
    def heartbeat(self, consumer_id: str,
                  queues: Optional[Sequence[str]] = None) -> None:
        """Register/refresh a consumer; entries expire after heartbeat_ttl."""
        qsel = _normalize_queues(queues)
        now = time.monotonic()
        with self._lock:
            self._consumers[consumer_id] = (qsel, now)
            dead = [c for c, (_, at) in self._consumers.items()
                    if now - at > 4 * self._hb_ttl]
            for c in dead:
                del self._consumers[c]

    def _consumers_view_locked(self) -> Dict[str, int]:
        now = time.monotonic()
        view: Dict[str, int] = {}
        for qsel, at in self._consumers.values():
            if now - at > self._hb_ttl:
                continue
            for q in (qsel if qsel is not None else ("*",)):
                view[q] = view.get(q, 0) + 1
        return view

    def set_visibility_timeout(self, queue: str, timeout: float) -> None:
        """Override the lease clock for one named queue (including leases
        already in flight, as in FileBroker)."""
        with self._lock:
            self._vt_queue[queue] = float(timeout)
            self._lock.notify_all()  # waiters recompute their next expiry

    def _vt_for(self, queue: str) -> float:
        return self._vt_queue.get(queue, self._vt)

    def _deadline(self, task: Task, leased_at: float) -> float:
        return leased_at + self._vt_for(task.queue)

    # -- per-queue depth overrides -------------------------------------------
    def set_max_queue_depth(self, queue: str, depth: Optional[int]) -> None:
        """Override (or, with ``None``, clear) one queue's depth bound."""
        with self._lock:
            if depth is None:
                self._depth_queue.pop(queue, None)
            else:
                self._depth_queue[queue] = max(1, int(depth))
            self._lock.notify_all()  # a raised bound unblocks producers

    def _depth_for(self, queue: str) -> Optional[int]:
        return self._depth_queue.get(queue, self._max_depth)

    def _bounded(self) -> bool:
        return self._max_depth is not None or bool(self._depth_queue)

    # -- producer side -----------------------------------------------------
    def _push_locked(self, task: Task) -> None:
        heap = self._heaps.setdefault(task.queue, [])
        heapq.heappush(heap, (task.priority, next(self._seq), task))

    def _wait_capacity_locked(self, queue: str, deadline: float) -> None:
        """Block while ``queue`` is at its depth bound; BrokerFull at the
        deadline.  Consumers claiming tasks notify the condition, so a
        blocked producer wakes as soon as the queue drains."""
        while True:
            limit = self._depth_for(queue)
            if limit is None or len(self._heaps.get(queue, ())) < limit:
                return
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise BrokerFull(
                    f"queue {queue!r} held {limit} pending tasks "
                    f"for {self._put_timeout}s (max_queue_depth)")
            self._lock.wait(remaining)

    # -- live queue migration -----------------------------------------------
    def migrate_queue(self, queue: str, target: Optional[str]) -> None:
        """Mark ``queue`` migrating to ``target`` (a broker URL), or clear
        the mark with ``None``.  While marked: gets skip the queue, puts
        forward to the target, in-flight leases drain in place."""
        validate_queue_name(queue)
        orphans = []
        with self._lock:
            if target is None:
                self._migrating.pop(queue, None)
                live = set(self._migrating.values())
                orphans = [self._fwd_clients.pop(u)
                           for u in list(self._fwd_clients)
                           if u not in live]
            else:
                self._migrating[queue] = str(target)
            self._lock.notify_all()
        for c in orphans:
            close = getattr(c, "close", None)
            if close is not None:
                try:
                    close()
                except Exception:
                    pass

    def _forward(self, target: str, tasks: List[Task]) -> None:
        client = self._fwd_clients.get(target)
        if client is None:
            from repro.core.netbroker import make_broker
            with self._lock:
                client = self._fwd_clients.get(target)
                if client is None:
                    client = self._fwd_clients[target] = make_broker(target)
        client.put_many(tasks)  # target applies its own backpressure
        with self._lock:
            self._stats["forwarded"] = \
                self._stats.get("forwarded", 0) + len(tasks)

    def export_queue(self, queue: str, max_n: int = 256) -> List[Dict[str, Any]]:
        """Atomically pop up to ``max_n`` pending tasks as wire dicts (the
        migration drain path; works on migrating and normal queues)."""
        out: List[Dict[str, Any]] = []
        with self._lock:
            heap = self._heaps.get(queue)
            while heap and len(out) < int(max_n):
                out.append(task_to_wire(heapq.heappop(heap)[2]))
            if out:
                self._stats["exported"] = \
                    self._stats.get("exported", 0) + len(out)
                self._lock.notify_all()  # freed capacity wakes producers
        return out

    def import_tasks(self, tasks: List[Any]) -> None:
        """Enqueue exported task dicts (or Tasks).  Exempt from the depth
        bound like redelivery — the tasks were already admitted once by
        the federation; blocking a migration on a full queue would strand
        them between owners."""
        with self._lock:
            now = time.monotonic()
            for d in tasks:
                t = d if isinstance(d, Task) else Task(**d)
                t.enqueued_at = now
                self._push_locked(t)
            self._stats["imported"] = \
                self._stats.get("imported", 0) + len(tasks)
            self._lock.notify_all()

    def put(self, task: Task) -> None:
        with self._lock:
            target = self._migrating.get(task.queue)
            if target is None:
                if self._bounded():
                    self._wait_capacity_locked(
                        task.queue, time.monotonic() + self._put_timeout)
                task.enqueued_at = time.monotonic()
                self._push_locked(task)
                self._stats["enqueued"] += 1
                self._lock.notify_all()
                return
        self._forward(target, [task])

    def put_many(self, tasks: List[Task]) -> None:
        fwd: Dict[str, List[Task]] = {}
        if self._migrating:
            with self._lock:
                if self._migrating:
                    local: List[Task] = []
                    for t in tasks:
                        tgt = self._migrating.get(t.queue)
                        if tgt is None:
                            local.append(t)
                        else:
                            fwd.setdefault(tgt, []).append(t)
                    tasks = local
        try:
            if not tasks:
                return
            if not self._bounded():  # unbounded: one lock, one wakeup
                now = time.monotonic()
                with self._lock:
                    for t in tasks:
                        t.enqueued_at = now
                        self._push_locked(t)
                    self._stats["enqueued"] += len(tasks)
                    self._lock.notify_all()
                return
            self._put_many_bounded(tasks)
        finally:
            for target, ts in fwd.items():
                self._forward(target, ts)

    def _put_many_bounded(self, tasks: List[Task]) -> None:
        with self._lock:
            # ONE deadline for the whole call: put_timeout bounds total
            # blocking, so a relayed put_many can never park a server
            # handler thread past the clients' request_grace (a huge batch
            # trickling into a small bounded queue fails fast instead —
            # callers retry in chunks, e.g. the worker's gen throttle)
            deadline = time.monotonic() + self._put_timeout
            for t in tasks:
                self._wait_capacity_locked(t.queue, deadline)
                t.enqueued_at = time.monotonic()
                self._push_locked(t)
                self._stats["enqueued"] += 1
                # wake consumers per task (not once at the end): with the
                # producer parked waiting for capacity mid-batch, consumers
                # must be draining concurrently or nobody ever wakes anybody
                self._lock.notify_all()

    # -- consumer side ------------------------------------------------------
    def _pop_best_locked(self, queues: Optional[Tuple[str, ...]]) -> Optional[Task]:
        # wildcard subscribers never see dead-letter queues; dlq.* must be
        # addressed explicitly (merlin-dlq) or its tasks would re-execute.
        # Migrating queues are invisible even to explicit subscribers —
        # their pending tasks are mid-handoff to the new owner.
        names = ([q for q in self._heaps if not is_dlq(q)]
                 if queues is None else queues)
        if self._migrating:
            names = [q for q in names if q not in self._migrating]
        best_q = None
        best_key: Optional[Tuple[int, int]] = None
        nonempty: List[str] = []
        for q in names:
            heap = self._heaps.get(q)
            if not heap:
                continue
            nonempty.append(q)
            key = heap[0][:2]
            if best_key is None or key < best_key:
                best_key, best_q = key, q
        if best_q is None:
            return None
        if self._fairness == "weighted" and len(nonempty) > 1:
            pick = self._rr.pick(nonempty)
            if pick != best_q:
                self._stats["starvation_avoided"] += 1
            best_q = pick
        return heapq.heappop(self._heaps[best_q])[2]

    def _lease_locked(self, task: Task) -> Lease:
        tag = uuid.uuid4().hex
        self._leased[tag] = (task, time.monotonic())
        return Lease(task, tag)

    def _wait_locked(self, deadline: Optional[float]) -> bool:
        """Block until notified, the next lease expiry, or the deadline.

        Returns False when the deadline has passed.  No fixed polling
        slices: producers notify the condition, so idle consumers wake
        immediately on put/nack and otherwise only for expiry sweeps.
        """
        now = time.monotonic()
        if deadline is not None and now >= deadline:
            return False
        wake_at = deadline
        if self._leased:
            next_expiry = min(self._deadline(t, at)
                              for t, at in self._leased.values())
            wake_at = next_expiry if wake_at is None else min(wake_at, next_expiry)
        self._lock.wait(None if wake_at is None else max(0.0, wake_at - now))
        return True

    def get(self, timeout: Optional[float] = 0.0,
            queues: Optional[Sequence[str]] = None) -> Optional[Lease]:
        """Claim one task from the subscribed queues (None = all)."""
        leases = self.get_many(1, timeout=timeout, queues=queues)
        return leases[0] if leases else None

    def get_many(self, n: int, timeout: Optional[float] = 0.0,
                 queues: Optional[Sequence[str]] = None) -> List[Lease]:
        """Claim up to ``n`` tasks in one lock round-trip.

        Blocks (up to ``timeout``) only for the *first* task; once anything
        is available the batch is whatever can be claimed right now.
        """
        qsel = _normalize_queues(queues)
        deadline = None if timeout is None else time.monotonic() + timeout
        out: List[Lease] = []
        with self._lock:
            while True:
                self._requeue_expired_locked()
                while len(out) < n:
                    task = self._pop_best_locked(qsel)
                    if task is None:
                        break
                    out.append(self._lease_locked(task))
                if out:
                    if self._bounded():
                        # claims free queue capacity: wake blocked producers
                        self._lock.notify_all()
                    return out
                if not self._wait_locked(deadline):
                    return out

    def ack(self, tag: str) -> None:
        with self._lock:
            if tag in self._leased:
                task, _ = self._leased.pop(tag)
                self._stats["acked"] += 1
                self._acked_q[task.queue] = self._acked_q.get(task.queue, 0) + 1

    def ack_many(self, tags: Iterable[str]) -> None:
        with self._lock:
            for tag in tags:
                if tag in self._leased:
                    task, _ = self._leased.pop(tag)
                    self._stats["acked"] += 1
                    self._acked_q[task.queue] = \
                        self._acked_q.get(task.queue, 0) + 1

    def nack(self, tag: str) -> None:
        """Return a leased task to its queue immediately (worker failure).

        Redelivery is exempt from the max_queue_depth bound: blocking a
        nack/expiry sweep on a full queue would wedge recovery."""
        with self._lock:
            if tag in self._leased:
                task, _ = self._leased.pop(tag)
                task.retries += 1
                self._push_locked(task)
                self._stats["redelivered"] += 1
                self._lock.notify_all()

    def _requeue_expired_locked(self) -> None:
        now = time.monotonic()
        expired = [tag for tag, (t, at) in self._leased.items()
                   if self._deadline(t, at) < now]
        for tag in expired:
            task, _ = self._leased.pop(tag)
            task.retries += 1
            self._push_locked(task)
            self._stats["redelivered"] += 1
        if expired:
            self._lock.notify_all()

    def qsize(self, queues: Optional[Sequence[str]] = None) -> int:
        qsel = _normalize_queues(queues)
        with self._lock:
            names = ([q for q in self._heaps if not is_dlq(q)]
                     if qsel is None else qsel)
            return sum(len(self._heaps.get(q, ())) for q in names)

    def queue_names(self) -> List[str]:
        with self._lock:
            return sorted(q for q, h in self._heaps.items() if h)

    def inflight(self) -> int:
        with self._lock:
            return len(self._leased)

    def inflight_tasks(self) -> List[Tuple[Task, float]]:
        """Snapshot of leased tasks with lease ages (straggler detection)."""
        now = time.monotonic()
        with self._lock:
            return [(task, now - leased_at)
                    for task, leased_at in self._leased.values()]

    def idle(self) -> bool:
        with self._lock:
            self._requeue_expired_locked()
            # dead-lettered tasks don't keep a drain alive
            return (not any(h for q, h in self._heaps.items()
                            if not is_dlq(q))
                    and not self._leased)


class FileBroker:
    """Directory-backed broker; multiprocess-safe via atomic renames.

    Layout::

        <root>/queues/<queue>/<prio:03d>-<seq:012d>-<id>.json   pending
        <root>/queues/<queue>/.tmp-<uuid>                       in-flight write
        <root>/claimed/<ts>__<queue>__<name>                    leased

    A claim renames the pending file into ``claimed/`` (os.rename is atomic
    within a filesystem); acks delete it; expiry rewrites it back into its
    queue directory with ``retries`` incremented.  This is the stand-in for
    a standalone RabbitMQ host: workers in different processes (different
    "batch jobs") coordinate only through this directory tree.

    The claim hot path is served from a cached per-queue index (a heap of
    pending filenames, which encode ``(priority, seq)`` in fixed-width
    fields so lexicographic order == delivery order).  The index is
    maintained incrementally by this instance's puts/claims and re-listed
    from disk only when it runs dry or ``rescan_interval`` elapses — O(1)
    claims instead of the seed's O(n log n) listdir+sort per poll.  Tasks
    enqueued by *other* processes are therefore picked up within one rescan
    interval; strict priority order is guaranteed among tasks the index has
    seen (global order across processes is best-effort, as with any
    distributed queue).
    """

    _TMP_PREFIX = ".tmp-"

    def __init__(self, root: str, visibility_timeout: float = 120.0,
                 rescan_interval: float = 0.25,
                 queue_timeouts: Optional[Dict[str, float]] = None,
                 fairness: str = "priority",
                 queue_weights: Optional[Dict[str, float]] = None,
                 max_queue_depth: Optional[int] = None,
                 put_timeout: float = 5.0,
                 heartbeat_ttl: float = 15.0,
                 queue_depths: Optional[Dict[str, int]] = None,
                 task_format: str = "auto"):
        if task_format not in _TASK_FORMATS:
            raise ValueError(f"task_format must be one of {_TASK_FORMATS}, "
                             f"got {task_format!r}")
        # how THIS instance writes task files; reading always sniffs the
        # format byte, so instances with different settings interoperate
        self._task_format = task_format
        self.root = root
        self.qroot = os.path.join(root, "queues")
        self.cdir = os.path.join(root, "claimed")
        # consumer heartbeats are queue state like the queue itself: one
        # file per consumer id, mtime = last seen, visible to every
        # instance sharing this directory
        self.hbdir = os.path.join(root, "consumers")
        os.makedirs(self.qroot, exist_ok=True)
        os.makedirs(self.cdir, exist_ok=True)
        self._max_depth = None if max_queue_depth is None \
            else max(1, int(max_queue_depth))
        self._put_timeout = put_timeout
        # serializes THIS instance's bounded puts so its own threads can't
        # race the check-then-write and overshoot the depth bound; across
        # processes the bound stays best-effort (see _wait_capacity)
        self._plock = threading.Lock()
        # per-queue depth overrides are shared queue state like .vt.json:
        # persisted to <root>/.depth.json so other instances' producers
        # honor them (reloaded on sweeps and, throttled, on puts)
        self._depthconf = jsonstore.SharedJsonConfig(
            os.path.join(root, ".depth.json"))
        self._depth_queue: Dict[str, int] = {}
        self._last_depth_check = 0.0
        self._load_depthconf()
        if queue_depths:
            ov = {q: max(1, int(d)) for q, d in queue_depths.items()}
            doc = self._depthconf.update(lambda d: d.update(ov))
            self._depth_queue = {q: max(1, int(d)) for q, d in doc.items()}
        self._hb_ttl = heartbeat_ttl
        self._vt = visibility_timeout
        self._seq = itertools.count(int(time.time() * 1e3) % 10 ** 9)
        self._rescan_interval = rescan_interval
        # per-queue visibility overrides are shared state like the queue
        # itself: persisted to <root>/.vt.json so every instance on this
        # directory (other processes' sweeps included) honors them
        self._vtconf = jsonstore.SharedJsonConfig(
            os.path.join(root, ".vt.json"))
        self._vt_queue: Dict[str, float] = {}
        self._load_vtconf()
        self._vt_queue.update(queue_timeouts or {})
        self._fairness = _check_fairness(fairness)
        self._rr = _WeightedRR(queue_weights)
        self._recompute_sweep_interval()
        # the cached index is in-process state shared by consumer threads
        # (WorkerPool); filesystem ops are atomic on their own, but the
        # peek-then-pop on the heaps needs a lock
        self._ilock = threading.Lock()
        self._index: Dict[str, List[str]] = {}   # queue -> heap of pending names
        self._last_rescan: Dict[str, float] = {}  # per queue, not global: a
        # rescan for one subscription must not suppress another's
        self._last_discover = 0.0
        self._last_sweep = 0.0
        self._last_tmp_reap = 0.0
        # stale-claim tracking: when another instance (process/thread on the
        # same root) wins the rename race, our index entry was stale; the
        # consumer loop uses this signal to force an immediate re-list
        # instead of sleeping through the rescan throttle
        self._saw_stale = False
        self._stats = {"enqueued": 0, "acked": 0, "redelivered": 0,
                       "stale_claims": 0, "starvation_avoided": 0}
        # per-queue ack counters (this instance's acks only — each worker
        # process counts its own work) feed merlin-status --watch rates
        self._acked_q: Dict[str, int] = {}
        # live-migration marks (in-memory, held by the serving instance):
        # queue -> forward target URL.  See InMemoryBroker._migrating.
        self._migrating: Dict[str, str] = {}
        self._fwd_clients: Dict[str, Any] = {}
        if queue_timeouts:  # constructor overrides are shared state too
            self._save_vtconf()

    @property
    def stats(self) -> Dict[str, Any]:
        with self._ilock:
            s = dict(self._stats)
            s["acked_by_queue"] = dict(self._acked_q)
            if self._migrating:
                s["migrating"] = sorted(self._migrating)
        s["consumers"] = self._consumers_view()
        return s

    # -- consumer heartbeats -------------------------------------------------
    def heartbeat(self, consumer_id: str,
                  queues: Optional[Sequence[str]] = None) -> None:
        """Write/refresh this consumer's heartbeat file (atomic rename)."""
        qsel = _normalize_queues(queues)
        os.makedirs(self.hbdir, exist_ok=True)
        safe = "hb-" + "".join(c if c.isalnum() or c in "-_.:" else "_"
                               for c in consumer_id)
        tmp = os.path.join(self.hbdir, f"{self._TMP_PREFIX}{uuid.uuid4().hex}")
        try:
            with open(tmp, "w") as f:
                json.dump({"id": consumer_id,
                           "queues": None if qsel is None else list(qsel)}, f)
            os.rename(tmp, os.path.join(self.hbdir, safe + ".json"))
        except OSError:
            pass  # heartbeat is advisory: never fail the worker over it

    def _consumers_view(self) -> Dict[str, int]:
        now = time.time()
        view: Dict[str, int] = {}
        try:
            names = os.listdir(self.hbdir)
        except OSError:
            return view
        for n in names:
            if n.startswith("."):
                continue
            path = os.path.join(self.hbdir, n)
            try:
                age = now - os.path.getmtime(path)
                if age > self._hb_ttl:
                    if age > 4 * self._hb_ttl:
                        os.unlink(path)  # reap long-dead consumers
                    continue
                with open(path) as f:
                    conf = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
            for q in (conf.get("queues") or ("*",)):
                view[q] = view.get(q, 0) + 1
        return view

    # -- per-queue visibility timeouts ---------------------------------------
    def set_visibility_timeout(self, queue: str, timeout: float) -> None:
        """Override the lease clock for one named queue.

        Takes effect at the next expiry sweep (claims store their claim
        timestamp, not a deadline), including sweeps run by *other*
        instances on this directory: the override is persisted to
        ``<root>/.vt.json`` and reloaded when its signature changes.
        """
        with self._ilock:
            self._load_vtconf()
            self._vt_queue[queue] = float(timeout)
            self._recompute_sweep_interval()
        # locked merge via jsonstore: concurrent writers from any process
        # serialize on the .vt.json.lock sidecar instead of dropping each
        # other's overrides (the old unlocked merge-before-write race)
        self._save_vtconf()

    def _vt_for(self, queue: str) -> float:
        return self._vt_queue.get(queue, self._vt)

    def _recompute_sweep_interval(self) -> None:
        min_vt = min([self._vt] + list(self._vt_queue.values()))
        self._sweep_interval = min(1.0, max(0.05, min_vt / 4.0))

    def _save_vtconf(self) -> None:
        """Merge this instance's overrides into the shared file (locked)."""
        ov = {q: float(t) for q, t in self._vt_queue.items()}
        self._vtconf.update(lambda doc: doc.update(ov))

    def _load_vtconf(self) -> None:
        doc = self._vtconf.load_if_changed()
        if doc is None:
            return
        self._vt_queue.update({q: float(t) for q, t in doc.items()})
        # a shorter timeout learned from another instance must also tighten
        # OUR sweep cadence, or its leases expire up to a full (stale)
        # sweep interval late
        self._recompute_sweep_interval()

    # -- per-queue depth overrides -------------------------------------------
    def set_max_queue_depth(self, queue: str, depth: Optional[int]) -> None:
        """Override (or clear, with ``None``) one queue's depth bound.

        Persisted to ``<root>/.depth.json`` so other instances on this
        directory pick it up: their sweeps reload eagerly, their put paths
        re-check the file signature at most twice a second (an override is
        rare, slowly-changing config — ops, not dataplane).  The
        read-merge-write is serialized ACROSS processes by jsonstore's
        fcntl lock sidecar — unlocked merging would let two processes'
        concurrent overrides silently drop one (and, because loads REPLACE
        the local view, later erase the loser's own bound).
        """
        def _apply(doc: Dict[str, Any]) -> None:
            if depth is None:
                doc.pop(queue, None)
            else:
                doc[queue] = max(1, int(depth))
        with self._ilock:
            doc = self._depthconf.update(_apply)
            # the file is authoritative (REPLACE, not update): clearing an
            # override must propagate, not resurrect
            self._depth_queue = {q: max(1, int(d)) for q, d in doc.items()}

    def _depth_for(self, queue: str) -> Optional[int]:
        return self._depth_queue.get(queue, self._max_depth)

    def _load_depthconf(self, force: bool = False) -> None:
        """Reload overrides when the file changed (throttled to 0.5s unless
        forced — puts call this on their hot path)."""
        now = time.monotonic()
        if not force and now - self._last_depth_check < 0.5:
            return
        self._last_depth_check = now
        doc = self._depthconf.load_if_changed()
        if doc is None:
            return
        # REPLACE semantics (see set_max_queue_depth)
        self._depth_queue = {q: max(1, int(d)) for q, d in doc.items()}

    # -- paths ---------------------------------------------------------------
    def _qdir(self, queue: str) -> str:
        return os.path.join(self.qroot, queue)

    def _ensure_queue(self, queue: str) -> str:
        validate_queue_name(queue)  # backstop; Task construction validated
        qdir = self._qdir(queue)
        with self._ilock:
            if queue not in self._index:
                os.makedirs(qdir, exist_ok=True)
                self._index[queue] = []
        return qdir

    # -- producer side -------------------------------------------------------
    @staticmethod
    def _check_priority(task: Task) -> None:
        if not 0 <= task.priority <= 999:
            # the filename encodes priority as %03d so lexicographic order
            # == delivery order; out-of-range values would silently
            # mis-sort on disk while ordering fine in-memory
            raise ValueError(f"FileBroker priority must be in [0, 999], "
                             f"got {task.priority}")

    def _pending_count(self, queue: str) -> int:
        try:
            return sum(1 for n in os.listdir(self._qdir(queue))
                       if not n.startswith("."))
        except OSError:
            return 0

    def _wait_capacity(self, queue: str, deadline: float) -> int:
        """Return available room (>= 1) in ``queue``; BrokerFull when it
        stays at its depth bound until the deadline.  Counts the directory
        (not the cached index) so other processes' puts count against the
        bound — but the check-then-write is unlocked across processes, so
        concurrent producers in different processes can briefly overshoot
        by their batch sizes (best-effort, like every cross-process
        property of this directory-based broker).  Within one instance,
        ``_plock`` serializes producers and the bound is exact."""
        while True:
            limit = self._depth_for(queue)
            if limit is None:
                return 1 << 30  # override cleared while we waited
            room = limit - self._pending_count(queue)
            if room > 0:
                return room
            if time.monotonic() >= deadline:
                raise BrokerFull(
                    f"queue {queue!r} held {limit} pending tasks "
                    f"for {self._put_timeout}s (max_queue_depth)")
            time.sleep(0.02)

    def _write_pending(self, qdir: str, task: Task) -> str:
        """Write one task file (temp + atomic rename); returns its name."""
        name = f"{task.priority:03d}-{next(self._seq):012d}-{task.id}.json"
        # temp lives INSIDE the queue dir (same fs, skipped by the index and
        # reaped by the expiry sweep if a crashed producer leaks it)
        tmp = os.path.join(qdir, f"{self._TMP_PREFIX}{uuid.uuid4().hex}")
        with open(tmp, "wb") as f:
            f.write(encode_task_file(task, self._task_format))
        os.rename(tmp, os.path.join(qdir, name))
        return name

    # -- live queue migration -----------------------------------------------
    def migrate_queue(self, queue: str, target: Optional[str]) -> None:
        """Mark ``queue`` migrating to ``target`` (a broker URL), or clear
        the mark with ``None``.  The mark is in-memory state of the
        serving instance (one BrokerServer per root): while set, gets skip
        the queue, puts forward, in-flight leases drain in place."""
        validate_queue_name(queue)
        orphans = []
        with self._ilock:
            if target is None:
                self._migrating.pop(queue, None)
                live = set(self._migrating.values())
                orphans = [self._fwd_clients.pop(u)
                           for u in list(self._fwd_clients)
                           if u not in live]
            else:
                self._migrating[queue] = str(target)
        for c in orphans:
            close = getattr(c, "close", None)
            if close is not None:
                try:
                    close()
                except Exception:
                    pass

    def _forward(self, target: str, tasks: List[Task]) -> None:
        client = self._fwd_clients.get(target)
        if client is None:
            from repro.core.netbroker import make_broker
            with self._ilock:
                client = self._fwd_clients.get(target)
                if client is None:
                    client = self._fwd_clients[target] = make_broker(target)
        client.put_many(tasks)  # target applies its own backpressure
        with self._ilock:
            self._stats["forwarded"] = \
                self._stats.get("forwarded", 0) + len(tasks)

    def export_queue(self, queue: str, max_n: int = 256) -> List[Dict[str, Any]]:
        """Atomically pop up to ``max_n`` pending tasks as wire dicts.

        Each task file is claimed by atomic rename (so concurrent local
        consumers cannot double-deliver it), decoded, and removed.  The
        migration orchestrator imports the returned batch on the new
        owner; a crash between export and import is the at-least-once
        window every pull-based handoff has — the exactly-once *completion*
        guarantee stays with the once-marker machinery downstream."""
        validate_queue_name(queue)
        out: List[Dict[str, Any]] = []
        self._rescan((queue,), force=True)
        while len(out) < int(max_n):
            with self._ilock:
                heap = self._index.get(queue)
                name = heapq.heappop(heap) if heap else None
            if name is None:
                break
            src = os.path.join(self._qdir(queue), name)
            dst = os.path.join(self.cdir,
                               f"{time.time():.6f}__{queue}__{name}")
            try:
                os.rename(src, dst)  # atomic claim-for-export
            except OSError:
                with self._ilock:
                    self._saw_stale = True
                continue
            try:
                with open(dst, "rb") as f:
                    task = decode_task_file(f.read())
            except (OSError, json.JSONDecodeError, TypeError, ValueError):
                self._dead_letter(dst)
                continue
            out.append(task_to_wire(task))
            try:
                os.unlink(dst)
            except OSError:
                pass
        if out:
            with self._ilock:
                self._stats["exported"] = \
                    self._stats.get("exported", 0) + len(out)
        return out

    def import_tasks(self, tasks: List[Any]) -> None:
        """Enqueue exported task dicts (or Tasks), exempt from the depth
        bound like nack redelivery — the federation already admitted them
        once; blocking mid-migration would strand them between owners."""
        now = time.time()
        by_q: Dict[str, List[Task]] = {}
        for d in tasks:
            t = d if isinstance(d, Task) else Task(**d)
            self._check_priority(t)
            t.enqueued_at = now
            by_q.setdefault(t.queue, []).append(t)
        for queue, ts in by_q.items():
            qdir = self._ensure_queue(queue)
            names = [self._write_pending(qdir, t) for t in ts]
            with self._ilock:
                index = self._index[queue]
                for name in names:
                    heapq.heappush(index, name)
                self._stats["imported"] = \
                    self._stats.get("imported", 0) + len(names)

    def put(self, task: Task) -> None:
        self._check_priority(task)
        if self._migrating:
            with self._ilock:
                target = self._migrating.get(task.queue)
            if target is not None:
                self._forward(target, [task])
                return
        qdir = self._ensure_queue(task.queue)
        self._load_depthconf()  # throttled: other instances' overrides
        if self._depth_for(task.queue) is not None:
            # deadline BEFORE the producer lock: time queued behind another
            # blocked producer counts against put_timeout, so total
            # blocking stays bounded per call (the documented contract)
            deadline = time.monotonic() + self._put_timeout
            with self._plock:
                self._wait_capacity(task.queue, deadline)
                task.enqueued_at = time.time()
                name = self._write_pending(qdir, task)
        else:
            task.enqueued_at = time.time()
            name = self._write_pending(qdir, task)
        with self._ilock:
            heapq.heappush(self._index[task.queue], name)
            self._stats["enqueued"] += 1

    def put_many(self, tasks: List[Task]) -> None:
        """Batched enqueue: per *queue*, one `_ensure_queue` check, all
        task files written (temp + atomic rename each), then ONE locked
        index merge + stats bump — not a per-task put() loop.  Behind a
        BrokerServer a 1000-task batch previously took 1000 lock
        acquisitions and heappushes while consumers fought for the same
        lock; now it takes one per queue (per capacity chunk)."""
        now = time.time()
        by_q: Dict[str, List[Task]] = {}
        for t in tasks:
            self._check_priority(t)
            t.enqueued_at = now
            by_q.setdefault(t.queue, []).append(t)
        if self._migrating:
            with self._ilock:
                marks = {q: self._migrating[q] for q in by_q
                         if q in self._migrating}
            for q, target in marks.items():
                self._forward(target, by_q.pop(q))
        self._load_depthconf()  # throttled: other instances' overrides
        for queue, ts in by_q.items():
            qdir = self._ensure_queue(queue)
            if self._depth_for(queue) is not None:
                # ONE deadline for the whole queue batch, computed BEFORE
                # the producer lock (put_timeout bounds total blocking
                # including time queued behind other producers — a
                # server-relayed put_many must never outlast the clients'
                # request_grace); producers of this instance serialized so
                # they can't jointly overshoot the bound
                deadline = time.monotonic() + self._put_timeout
                with self._plock:
                    i = 0
                    while i < len(ts):
                        # admit in capacity-sized chunks; _wait_capacity
                        # blocks until room exists, BrokerFull at deadline
                        room = min(len(ts) - i,
                                   self._wait_capacity(queue, deadline))
                        self._index_chunk(qdir, queue, ts[i:i + room])
                        i += room
            else:
                self._index_chunk(qdir, queue, ts)

    def _index_chunk(self, qdir: str, queue: str, chunk: List[Task]) -> None:
        """Write a run of task files, then ONE locked index merge."""
        names = [self._write_pending(qdir, t) for t in chunk]
        with self._ilock:
            index = self._index[queue]
            for name in names:
                heapq.heappush(index, name)
            self._stats["enqueued"] += len(names)

    # -- index maintenance ---------------------------------------------------
    def _rescan(self, queues: Optional[Tuple[str, ...]],
                force: bool = False) -> None:
        """Re-list pending files from disk (picks up other processes' puts).

        Self-throttled per queue on ``rescan_interval`` — a never-scanned
        queue is always stale, so a fresh instance or subscription sees
        disk immediately.  ``force=True`` bypasses the throttle: used after
        stale-index claim races (another worker renamed a file we still had
        indexed), where waiting out the throttle would starve this consumer
        of work that IS on disk.
        """
        now = time.monotonic()
        if queues is None:
            if force or self._last_discover == 0.0 or \
                    now - self._last_discover > self._rescan_interval:
                self._last_discover = now
                try:
                    queues = tuple(q for q in os.listdir(self.qroot)
                                   if os.path.isdir(self._qdir(q)))
                except OSError:
                    queues = ()
            else:
                with self._ilock:
                    queues = tuple(self._index)
        for q in queues:
            if not force and \
                    now - self._last_rescan.get(q, 0.0) <= self._rescan_interval:
                continue
            try:
                names = [n for n in os.listdir(self._qdir(q))
                         if not n.startswith(".")]
            except OSError:
                continue
            with self._ilock:
                # union-merge, never replace: a concurrent same-process
                # put()/nack() may have pushed a name after our listdir
                # snapshot; replacing would silently drop it.  Stale
                # entries (claimed since the snapshot) just fail their
                # rename and are skipped.
                merged = list(set(names) | set(self._index.get(q, ())))
                heapq.heapify(merged)
                self._index[q] = merged
            self._last_rescan[q] = now

    def _pop_best(self, queues: Optional[Tuple[str, ...]]) -> Optional[Tuple[str, str]]:
        with self._ilock:
            # wildcard consumers skip dead-letter queues (see DLQ_PREFIX);
            # migrating queues are invisible even to explicit subscribers
            names = ([q for q in self._index if not is_dlq(q)]
                     if queues is None else queues)
            if self._migrating:
                names = [q for q in names if q not in self._migrating]
            best_q = None
            nonempty = []
            for q in names:
                heap = self._index.get(q)
                if not heap:
                    continue
                nonempty.append(q)
                if best_q is None or heap[0] < self._index[best_q][0]:
                    best_q = q
            if best_q is None:
                return None
            if self._fairness == "weighted" and len(nonempty) > 1:
                pick = self._rr.pick(nonempty)
                if pick != best_q:
                    self._stats["starvation_avoided"] += 1
                best_q = pick
            return best_q, heapq.heappop(self._index[best_q])

    def _dead_letter(self, path: str) -> None:
        """Quarantine an unparseable task file so it can't cycle forever
        between pending and claimed (it would otherwise pin idle() False)."""
        ddir = os.path.join(self.root, "dead")
        os.makedirs(ddir, exist_ok=True)
        try:
            os.rename(path, os.path.join(ddir, os.path.basename(path)))
        except OSError:
            pass

    def _try_claim(self, queues: Optional[Tuple[str, ...]]) -> Optional[Lease]:
        while True:
            picked = self._pop_best(queues)
            if picked is None:
                return None
            best_q, name = picked
            src = os.path.join(self._qdir(best_q), name)
            dst = os.path.join(self.cdir, f"{time.time():.6f}__{best_q}__{name}")
            try:
                os.rename(src, dst)  # atomic claim
            except OSError:
                # another worker won the rename race; our index entry was
                # stale.  Record it so the consumer loop can force a fresh
                # disk listing instead of concluding the queue is empty.
                with self._ilock:
                    self._saw_stale = True
                    self._stats["stale_claims"] += 1
                continue
            try:
                with open(dst, "rb") as f:
                    task = decode_task_file(f.read())
            except (OSError, json.JSONDecodeError, TypeError, ValueError):
                # unparseable (either format — CodecError is a ValueError)
                # OR carrying an invalid queue name (ValueError from Task
                # validation): quarantine, move on
                self._dead_letter(dst)
                continue
            return Lease(task, dst)

    # -- consumer side -------------------------------------------------------
    def get(self, timeout: Optional[float] = 0.0,
            queues: Optional[Sequence[str]] = None) -> Optional[Lease]:
        leases = self.get_many(1, timeout=timeout, queues=queues)
        return leases[0] if leases else None

    def get_many(self, n: int, timeout: Optional[float] = 0.0,
                 queues: Optional[Sequence[str]] = None) -> List[Lease]:
        qsel = _normalize_queues(queues)
        deadline = None if timeout is None else time.monotonic() + timeout
        out: List[Lease] = []
        fresh = False  # index reflects a disk scan done this wait cycle
        while True:
            with self._ilock:
                # check-and-set under the lock: exactly one consumer thread
                # runs each sweep, so two threads can't both nack the same
                # expired claim (double-redelivery / double-counted stats)
                sweep_due = time.monotonic() - self._last_sweep > self._sweep_interval
                if sweep_due:
                    self._last_sweep = time.monotonic()
            if sweep_due:
                self._requeue_expired()
            while len(out) < n:
                lease = self._try_claim(qsel)
                if lease is None:
                    break
                out.append(lease)
            if out:
                return out
            if not fresh:
                # index ran dry: consult disk for other processes' puts.
                # _rescan self-throttles per queue, so idle consumers do
                # NOT reintroduce the listdir-per-poll load the cached
                # index exists to remove.  Exception: if this claim round
                # lost rename races (stale index entries), other consumers
                # are actively draining the same directory and pending work
                # may exist that we have never listed — force the rescan
                # so contention degrades to extra listdirs, not to lost
                # throughput while the throttle runs out.
                with self._ilock:
                    force, self._saw_stale = self._saw_stale, False
                self._rescan(qsel, force=force)
                fresh = True
                continue
            if deadline is not None and time.monotonic() >= deadline:
                return out
            time.sleep(0.02)
            fresh = False

    def ack(self, tag: str) -> None:
        try:
            os.unlink(tag)
        except OSError:
            return
        # claim tags are "<ts>__<queue>__<name>": recover the queue for the
        # per-queue ack counter without touching the (deleted) payload
        try:
            queue = os.path.basename(tag).split("__", 2)[1]
        except IndexError:
            queue = ""
        with self._ilock:
            self._stats["acked"] += 1
            if queue:
                self._acked_q[queue] = self._acked_q.get(queue, 0) + 1

    def ack_many(self, tags: Iterable[str]) -> None:
        for tag in tags:
            self.ack(tag)

    def nack(self, tag: str) -> None:
        """Requeue a leased task, incrementing its retry count."""
        base = os.path.basename(tag)
        try:
            _, queue, name = base.split("__", 2)
        except ValueError:
            return
        qdir = self._ensure_queue(queue)
        dst = os.path.join(qdir, name)
        try:
            with open(tag, "rb") as f:
                raw = f.read()
        except OSError:
            return  # claim already gone: a concurrent sweep/ack won
        try:
            task = decode_task_file(raw)
        except (json.JSONDecodeError, TypeError, ValueError):
            # unparseable poison: redelivering would ping-pong it between
            # pending and claimed forever (retries can never increment)
            self._dead_letter(tag)
            return
        task.retries += 1
        tmp = os.path.join(qdir, f"{self._TMP_PREFIX}{uuid.uuid4().hex}")
        try:
            with open(tmp, "wb") as f:
                f.write(encode_task_file(task, self._task_format))
            os.rename(tmp, dst)
        except OSError:
            return
        try:
            os.unlink(tag)
        except OSError:
            pass
        with self._ilock:
            heapq.heappush(self._index.setdefault(queue, []), name)
            self._stats["redelivered"] += 1

    def _requeue_expired(self) -> None:
        """Expiry sweep: redeliver timed-out leases, reap leaked temp files."""
        self._last_sweep = time.monotonic()
        self._load_vtconf()  # pick up other instances' per-queue overrides
        self._load_depthconf(force=True)  # ... and their depth bounds
        now = time.time()
        for name in os.listdir(self.cdir):
            try:
                ts_s, queue, _ = name.split("__", 2)
                ts = float(ts_s)
            except ValueError:
                continue
            if now - ts > self._vt_for(queue):
                self.nack(os.path.join(self.cdir, name))
        # reap temps a crashed producer left behind (live producers hold a
        # temp for microseconds; anything older than the lease window is
        # junk).  Own, longer cadence: idle()/drain() polls call this sweep
        # every ~20 ms and must not pay a full per-queue directory walk
        tmp_max_age = max(30.0, self._vt)
        if self._last_tmp_reap != 0.0 and \
                time.monotonic() - self._last_tmp_reap < tmp_max_age / 2:
            return
        self._last_tmp_reap = time.monotonic()
        try:
            queues = os.listdir(self.qroot)
        except OSError:
            queues = []
        for q in queues:
            qdir = self._qdir(q)
            try:
                names = os.listdir(qdir)
            except OSError:
                continue
            for n in names:
                if not n.startswith(self._TMP_PREFIX):
                    continue
                path = os.path.join(qdir, n)
                try:
                    if now - os.path.getmtime(path) > tmp_max_age:
                        os.unlink(path)
                except OSError:
                    pass
        # prune stale consumer heartbeat files on the same cadence: the
        # read path (_consumers_view) reaps long-dead entries only when
        # someone actually reads stats, so an unwatched root would grow
        # <root>/consumers/ forever as worker fleets churn
        try:
            hb_names = os.listdir(self.hbdir)
        except OSError:
            hb_names = []
        for n in hb_names:
            path = os.path.join(self.hbdir, n)
            try:
                if now - os.path.getmtime(path) > 4 * self._hb_ttl:
                    os.unlink(path)
            except OSError:
                pass

    # -- introspection -------------------------------------------------------
    def qsize(self, queues: Optional[Sequence[str]] = None) -> int:
        qsel = _normalize_queues(queues)
        if qsel is None:
            # wildcard size mirrors wildcard consumption: no dlq.* queues
            try:
                qsel = tuple(q for q in os.listdir(self.qroot)
                             if not is_dlq(q))
            except OSError:
                return 0
        total = 0
        for q in qsel:
            try:
                total += sum(1 for n in os.listdir(self._qdir(q))
                             if not n.startswith("."))
            except OSError:
                pass
        return total

    def queue_names(self) -> List[str]:
        try:
            return sorted(q for q in os.listdir(self.qroot)
                          if self.qsize((q,)) > 0)
        except OSError:
            return []

    def inflight(self) -> int:
        return len(os.listdir(self.cdir))

    def inflight_tasks(self) -> List[Tuple[Task, float]]:
        """Snapshot of leased tasks with lease ages (straggler detection)."""
        now = time.time()
        out: List[Tuple[Task, float]] = []
        for name in os.listdir(self.cdir):
            try:
                ts = float(name.split("__", 1)[0])
                with open(os.path.join(self.cdir, name), "rb") as f:
                    task = decode_task_file(f.read())
            except (ValueError, OSError, json.JSONDecodeError, TypeError):
                continue  # claim vanished (acked) or poison mid-read
            out.append((task, now - ts))
        return out

    def idle(self) -> bool:
        self._requeue_expired()
        return self.qsize() == 0 and self.inflight() == 0
