"""Task brokers: the Celery/RabbitMQ stand-in (DESIGN.md mapping C1).

Semantics preserved from the paper's stack: priority queues (real simulation
tasks drain before task-generation tasks, Sec. 2.2), leases with visibility
timeouts (a worker that dies mid-task gets its task redelivered — the
resilience substrate of Sec. 3.1), acks, and multiple named queues.

Two implementations behind one interface:

* :class:`InMemoryBroker` — thread-safe, for in-process worker pools and the
  performance benchmarks (Figs. 3-6 analogues).
* :class:`FileBroker` — directory-backed, multiprocess-safe via atomic
  renames (claim = rename into ``claimed/``), so independent worker
  *processes* ("batch allocations") can attach to a shared queue — the
  surge-computing model of Sec. 3.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import json
import os
import threading
import time
import uuid
from typing import Any, Dict, List, Optional, Tuple

# priorities: lower = served first.  Real work drains before generation work.
PRIORITY_REAL = 0
PRIORITY_GEN = 1
PRIORITY_LOW = 2


@dataclasses.dataclass
class Task:
    id: str
    kind: str  # "gen" | "real" | "step" | custom
    payload: Dict[str, Any]
    priority: int = PRIORITY_REAL
    queue: str = "default"
    retries: int = 0
    enqueued_at: float = 0.0

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @staticmethod
    def from_json(s: str) -> "Task":
        return Task(**json.loads(s))


# fast process-unique task ids: one random prefix + a counter.  uuid4 per
# task costs ~1.5us (os.urandom) and dominated hierarchy expansion at
# >1e5 tasks/s (§Perf host-side log in EXPERIMENTS.md).
_ID_PREFIX = uuid.uuid4().hex[:10]
_ID_SEQ = itertools.count()


def new_task(kind: str, payload: Dict[str, Any], *, priority: int = PRIORITY_REAL,
             queue: str = "default") -> Task:
    return Task(id=f"{_ID_PREFIX}{next(_ID_SEQ):011x}", kind=kind,
                payload=payload, priority=priority, queue=queue)


@dataclasses.dataclass
class Lease:
    task: Task
    tag: str


class InMemoryBroker:
    """Thread-safe priority broker with visibility timeouts."""

    def __init__(self, visibility_timeout: float = 60.0):
        self._lock = threading.Condition()
        self._heap: List[Tuple[int, int, Task]] = []
        self._seq = itertools.count()
        self._leased: Dict[str, Tuple[Task, float]] = {}
        self._vt = visibility_timeout
        self.stats = {"enqueued": 0, "acked": 0, "redelivered": 0}

    # -- producer side -----------------------------------------------------
    def put(self, task: Task) -> None:
        task.enqueued_at = time.monotonic()
        with self._lock:
            heapq.heappush(self._heap, (task.priority, next(self._seq), task))
            self.stats["enqueued"] += 1
            self._lock.notify()

    def put_many(self, tasks: List[Task]) -> None:
        now = time.monotonic()
        with self._lock:
            for t in tasks:
                t.enqueued_at = now
                heapq.heappush(self._heap, (t.priority, next(self._seq), t))
            self.stats["enqueued"] += len(tasks)
            self._lock.notify_all()

    # -- consumer side ------------------------------------------------------
    def get(self, timeout: Optional[float] = 0.0) -> Optional[Lease]:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while True:
                self._requeue_expired_locked()
                if self._heap:
                    _, _, task = heapq.heappop(self._heap)
                    tag = uuid.uuid4().hex
                    self._leased[tag] = (task, time.monotonic() + self._vt)
                    return Lease(task, tag)
                if deadline is None:
                    self._lock.wait(0.05)
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return None
                    self._lock.wait(min(remaining, 0.05))

    def ack(self, tag: str) -> None:
        with self._lock:
            if tag in self._leased:
                del self._leased[tag]
                self.stats["acked"] += 1

    def nack(self, tag: str) -> None:
        """Return a leased task to the queue immediately (worker failure)."""
        with self._lock:
            if tag in self._leased:
                task, _ = self._leased.pop(tag)
                task.retries += 1
                heapq.heappush(self._heap, (task.priority, next(self._seq), task))
                self.stats["redelivered"] += 1
                self._lock.notify()

    def _requeue_expired_locked(self) -> None:
        now = time.monotonic()
        expired = [tag for tag, (_, dl) in self._leased.items() if dl < now]
        for tag in expired:
            task, _ = self._leased.pop(tag)
            task.retries += 1
            heapq.heappush(self._heap, (task.priority, next(self._seq), task))
            self.stats["redelivered"] += 1

    def qsize(self) -> int:
        with self._lock:
            return len(self._heap)

    def inflight(self) -> int:
        with self._lock:
            return len(self._leased)

    def idle(self) -> bool:
        with self._lock:
            self._requeue_expired_locked()
            return not self._heap and not self._leased


class FileBroker:
    """Directory-backed broker; multiprocess-safe via atomic renames.

    Layout: <root>/queue/<prio>-<seq>-<id>.json ; claims move the file to
    <root>/claimed/ (os.rename is atomic within a filesystem), acks delete
    it, expiry moves it back.  This is the stand-in for a standalone
    RabbitMQ host: workers in different processes (different "batch jobs")
    coordinate only through this directory.
    """

    def __init__(self, root: str, visibility_timeout: float = 120.0):
        self.root = root
        self.qdir = os.path.join(root, "queue")
        self.cdir = os.path.join(root, "claimed")
        os.makedirs(self.qdir, exist_ok=True)
        os.makedirs(self.cdir, exist_ok=True)
        self._vt = visibility_timeout
        self._seq = itertools.count(int(time.time() * 1e3) % 10 ** 9)

    def put(self, task: Task) -> None:
        task.enqueued_at = time.time()
        name = f"{task.priority}-{next(self._seq):012d}-{task.id}.json"
        tmp = os.path.join(self.root, f".tmp-{name}")
        with open(tmp, "w") as f:
            f.write(task.to_json())
        os.rename(tmp, os.path.join(self.qdir, name))

    def put_many(self, tasks: List[Task]) -> None:
        for t in tasks:
            self.put(t)

    def get(self, timeout: Optional[float] = 0.0) -> Optional[Lease]:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            self._requeue_expired()
            names = sorted(os.listdir(self.qdir))
            for name in names:
                src = os.path.join(self.qdir, name)
                dst = os.path.join(self.cdir, f"{time.time():.3f}__{name}")
                try:
                    os.rename(src, dst)  # atomic claim
                except OSError:
                    continue  # another worker won
                with open(dst) as f:
                    task = Task.from_json(f.read())
                return Lease(task, dst)
            if deadline is not None and time.monotonic() >= deadline:
                return None
            time.sleep(0.02)

    def ack(self, tag: str) -> None:
        try:
            os.unlink(tag)
        except OSError:
            pass

    def nack(self, tag: str) -> None:
        name = os.path.basename(tag).split("__", 1)[1]
        try:
            os.rename(tag, os.path.join(self.qdir, name))
        except OSError:
            pass

    def _requeue_expired(self) -> None:
        now = time.time()
        for name in os.listdir(self.cdir):
            try:
                ts = float(name.split("__", 1)[0])
            except ValueError:
                continue
            if now - ts > self._vt:
                self.nack(os.path.join(self.cdir, name))

    def qsize(self) -> int:
        return len(os.listdir(self.qdir))

    def inflight(self) -> int:
        return len(os.listdir(self.cdir))

    def idle(self) -> bool:
        self._requeue_expired()
        return self.qsize() == 0 and self.inflight() == 0
