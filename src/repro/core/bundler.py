"""Hierarchical result bundling/aggregation (paper Sec. 3.1, Fig. 7).

The JAG study's layout, Conduit/HDF5 swapped for npz (no h5py offline):
N simulations per *bundle file*, ``files_per_leaf`` bundle files per leaf
directory; once a leaf fills, an aggregation step merges it into a single
aggregate file of ``bundle * files_per_leaf`` simulations.  All writes are
atomic renames — no file locking or I/O coordination between the
asynchronous writers, exactly the paper's design.

``crawl()`` is the resilience primitive: walk the tree, return which sample
ids actually made it to disk (and which files are corrupt), so missing work
can be resubmitted (the 70% -> 99.755% story).

Incremental loading
-------------------
The learner side of the loop (core/active.py) re-reads the archive at every
funnel.  ``load_all`` therefore keeps a per-file cache keyed by the file's
``(inode, mtime_ns, size)`` signature: only files that appeared or changed
since the previous call are decompressed, everything else is served from
memory, and an unchanged tree returns the previously concatenated result
without touching the files at all.  ``load_since(cursor)`` exposes the same
machinery as an explicit delta: it returns only the records from files not
yet covered by ``cursor`` plus the advanced cursor.  Writers publish via
atomic rename (fresh inode per publish), so a cached signature can never
alias a concurrent rewrite.  Note that aggregation rewrites sample ids into
a *new* file, so a cursor held across ``aggregate_leaf`` re-delivers those
ids — hold cursors within one aggregation epoch.
"""
from __future__ import annotations

import os
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Set, Tuple

import numpy as np

# a file's identity-and-content signature: (inode, mtime_ns, size)
Sig = Tuple[int, int, int]


class Bundler:
    def __init__(self, root: str, files_per_leaf: int = 100, sink=None):
        self.root = root
        self.files_per_leaf = files_per_leaf
        # optional same-host fast path: any object with
        # ``push_bundle(lo, hi, results) -> bool`` (e.g.
        # core/shmring.BundleRing).  Fed AFTER the durable file write —
        # the npz tree stays the source of truth and of load_since
        # cursors; a full/broken sink only costs the latency shortcut.
        self.sink = sink
        os.makedirs(root, exist_ok=True)
        self._file_cache: Dict[str, Tuple[Sig, Dict[str, np.ndarray]]] = {}
        self._all_cache: Optional[Tuple[Dict[str, Sig],
                                        Dict[str, np.ndarray]]] = None

    def attach_sink(self, sink) -> None:
        """Install/replace the write sink (None detaches)."""
        self.sink = sink

    # -- writing -------------------------------------------------------------
    def leaf_dir(self, bundle_lo: int, bundle_size: int) -> str:
        leaf = (bundle_lo // bundle_size) // self.files_per_leaf
        d = os.path.join(self.root, f"leaf_{leaf:06d}")
        os.makedirs(d, exist_ok=True)
        return d

    def write_bundle(self, lo: int, hi: int, results: Dict[str, np.ndarray]) -> str:
        """results: dict of arrays with leading dim == hi-lo."""
        d = self.leaf_dir(lo, hi - lo)
        path = os.path.join(d, f"bundle_{lo:09d}_{hi:09d}.npz")
        # np.savez appends ".npz" unless present: keep the suffix on the tmp
        tmp = os.path.join(d, f".tmp-{os.getpid()}-{lo}-{hi}.npz")
        ids = np.arange(lo, hi)
        np.savez_compressed(tmp, _sample_ids=ids, **results)
        os.rename(tmp, path)  # atomic publish
        if self.sink is not None:
            try:
                self.sink.push_bundle(lo, hi, results)
            except Exception:
                pass  # the file above is the durable record; sink is best-effort
        return path

    # -- aggregation ----------------------------------------------------------
    def aggregate_leaf(self, leaf_dir: str) -> Optional[str]:
        files = sorted(f for f in os.listdir(leaf_dir) if f.startswith("bundle_"))
        if not files:
            return None
        parts = [dict(np.load(os.path.join(leaf_dir, f))) for f in files]
        keys = parts[0].keys()
        merged = {k: np.concatenate([p[k] for p in parts]) for k in keys}
        out = os.path.join(leaf_dir, "aggregate.npz")
        tmp = os.path.join(leaf_dir, f".tmp-agg-{os.getpid()}.npz")
        np.savez_compressed(tmp, **merged)
        os.rename(tmp, out)
        for f in files:  # bundles are subsumed by the aggregate
            os.unlink(os.path.join(leaf_dir, f))
        return out

    def aggregate_all(self) -> List[str]:
        outs = []
        for leaf in sorted(os.listdir(self.root)):
            d = os.path.join(self.root, leaf)
            if os.path.isdir(d):
                out = self.aggregate_leaf(d)
                if out:
                    outs.append(out)
        return outs

    # -- resilience -----------------------------------------------------------
    def crawl(self) -> Tuple[Set[int], List[str]]:
        """Return (sample ids present on disk, corrupt file paths)."""
        present: Set[int] = set()
        corrupt: List[str] = []
        for dirpath, _, files in os.walk(self.root):
            for f in files:
                if not f.endswith(".npz") or f.startswith("."):
                    continue
                path = os.path.join(dirpath, f)
                try:
                    with np.load(path) as z:
                        present.update(int(i) for i in z["_sample_ids"])
                except Exception:
                    corrupt.append(path)
        return present, corrupt

    # -- loading --------------------------------------------------------------
    def _scan(self) -> Dict[str, Sig]:
        """Stat every published result file: path -> signature."""
        sigs: Dict[str, Sig] = {}
        for dirpath, _, files in os.walk(self.root):
            for f in sorted(files):
                if not f.endswith(".npz") or f.startswith("."):
                    continue
                path = os.path.join(dirpath, f)
                try:
                    st = os.stat(path)
                except OSError:
                    continue  # deleted between walk and stat (aggregation)
                sigs[path] = (st.st_ino, st.st_mtime_ns, st.st_size)
        return sigs

    def _load_file(self, path: str, sig: Sig) -> Optional[Dict[str, np.ndarray]]:
        """Load one bundle through the per-file cache (None if it vanished)."""
        hit = self._file_cache.get(path)
        if hit is not None and hit[0] == sig:
            return hit[1]
        try:
            with np.load(path) as z:
                data = {k: z[k] for k in z.files}
        except (OSError, ValueError):
            self._file_cache.pop(path, None)
            return None
        self._file_cache[path] = (sig, data)
        return data

    @staticmethod
    def _concat(chunks: Sequence[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
        if not chunks:
            return {}
        order = np.argsort(np.concatenate([c["_sample_ids"] for c in chunks]))
        return {k: np.concatenate([c[k] for c in chunks])[order]
                for k in chunks[0].keys()}

    def load_all(self) -> Dict[str, np.ndarray]:
        """Load every result in sample-id order (for the learner side).

        Incremental: only files whose signature changed since the previous
        call are read from disk; an unchanged tree returns the cached
        concatenation directly.
        """
        sigs = self._scan()
        if self._all_cache is not None and self._all_cache[0] == sigs:
            return dict(self._all_cache[1])  # shallow copy: callers may pop
        chunks = []
        for path in sorted(sigs):
            data = self._load_file(path, sigs[path])
            if data is not None:
                chunks.append(data)
            else:
                sigs.pop(path)
        # evict cache entries for files that no longer exist (aggregation)
        for stale in set(self._file_cache) - set(sigs):
            del self._file_cache[stale]
        out = self._concat(chunks)
        self._all_cache = (sigs, out)
        return dict(out)

    def load_since(self, cursor: Optional[Mapping[str, Sig]] = None
                   ) -> Tuple[Dict[str, np.ndarray], Dict[str, Sig]]:
        """Delta load: records from files not covered by ``cursor``.

        Returns ``(data, new_cursor)``; start with ``cursor=None`` and feed
        each returned cursor into the next call.  Safe under concurrent
        writers: publishes are atomic renames, so every bundle is returned
        exactly once per cursor chain (aggregation epochs aside, see module
        docstring).
        """
        cursor = dict(cursor) if cursor else {}
        sigs = self._scan()
        chunks = []
        for path in sorted(sigs):
            if cursor.get(path) == sigs[path]:
                continue
            data = self._load_file(path, sigs[path])
            if data is not None:
                chunks.append(data)
            else:
                sigs.pop(path)
        return self._concat(chunks), sigs


def missing_samples(expected_n: int, present: Set[int]) -> List[Tuple[int, int]]:
    """Contiguous [lo, hi) ranges of missing sample ids (for resubmission)."""
    missing = sorted(set(range(expected_n)) - present)
    if not missing:
        return []
    ranges = []
    lo = prev = missing[0]
    for i in missing[1:]:
        if i != prev + 1:
            ranges.append((lo, prev + 1))
            lo = i
        prev = i
    ranges.append((lo, prev + 1))
    return ranges
