"""Hierarchical result bundling/aggregation (paper Sec. 3.1, Fig. 7).

The JAG study's layout, Conduit/HDF5 swapped for npz (no h5py offline):
N simulations per *bundle file*, ``files_per_leaf`` bundle files per leaf
directory; once a leaf fills, an aggregation step merges it into a single
aggregate file of ``bundle * files_per_leaf`` simulations.  All writes are
atomic renames — no file locking or I/O coordination between the
asynchronous writers, exactly the paper's design.

``crawl()`` is the resilience primitive: walk the tree, return which sample
ids actually made it to disk (and which files are corrupt), so missing work
can be resubmitted (the 70% -> 99.755% story).
"""
from __future__ import annotations

import os
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np


class Bundler:
    def __init__(self, root: str, files_per_leaf: int = 100):
        self.root = root
        self.files_per_leaf = files_per_leaf
        os.makedirs(root, exist_ok=True)

    # -- writing -------------------------------------------------------------
    def leaf_dir(self, bundle_lo: int, bundle_size: int) -> str:
        leaf = (bundle_lo // bundle_size) // self.files_per_leaf
        d = os.path.join(self.root, f"leaf_{leaf:06d}")
        os.makedirs(d, exist_ok=True)
        return d

    def write_bundle(self, lo: int, hi: int, results: Dict[str, np.ndarray]) -> str:
        """results: dict of arrays with leading dim == hi-lo."""
        d = self.leaf_dir(lo, hi - lo)
        path = os.path.join(d, f"bundle_{lo:09d}_{hi:09d}.npz")
        # np.savez appends ".npz" unless present: keep the suffix on the tmp
        tmp = os.path.join(d, f".tmp-{os.getpid()}-{lo}-{hi}.npz")
        ids = np.arange(lo, hi)
        np.savez_compressed(tmp, _sample_ids=ids, **results)
        os.rename(tmp, path)  # atomic publish
        return path

    # -- aggregation ----------------------------------------------------------
    def aggregate_leaf(self, leaf_dir: str) -> Optional[str]:
        files = sorted(f for f in os.listdir(leaf_dir) if f.startswith("bundle_"))
        if not files:
            return None
        parts = [dict(np.load(os.path.join(leaf_dir, f))) for f in files]
        keys = parts[0].keys()
        merged = {k: np.concatenate([p[k] for p in parts]) for k in keys}
        out = os.path.join(leaf_dir, "aggregate.npz")
        tmp = os.path.join(leaf_dir, f".tmp-agg-{os.getpid()}.npz")
        np.savez_compressed(tmp, **merged)
        os.rename(tmp, out)
        for f in files:  # bundles are subsumed by the aggregate
            os.unlink(os.path.join(leaf_dir, f))
        return out

    def aggregate_all(self) -> List[str]:
        outs = []
        for leaf in sorted(os.listdir(self.root)):
            d = os.path.join(self.root, leaf)
            if os.path.isdir(d):
                out = self.aggregate_leaf(d)
                if out:
                    outs.append(out)
        return outs

    # -- resilience -----------------------------------------------------------
    def crawl(self) -> Tuple[Set[int], List[str]]:
        """Return (sample ids present on disk, corrupt file paths)."""
        present: Set[int] = set()
        corrupt: List[str] = []
        for dirpath, _, files in os.walk(self.root):
            for f in files:
                if not f.endswith(".npz") or f.startswith("."):
                    continue
                path = os.path.join(dirpath, f)
                try:
                    with np.load(path) as z:
                        present.update(int(i) for i in z["_sample_ids"])
                except Exception:
                    corrupt.append(path)
        return present, corrupt

    def load_all(self) -> Dict[str, np.ndarray]:
        """Load every result in sample-id order (for the learner side)."""
        chunks: List[Dict[str, np.ndarray]] = []
        for dirpath, _, files in os.walk(self.root):
            for f in sorted(files):
                if f.endswith(".npz") and not f.startswith("."):
                    chunks.append(dict(np.load(os.path.join(dirpath, f))))
        if not chunks:
            return {}
        order = np.argsort(np.concatenate([c["_sample_ids"] for c in chunks]))
        out = {}
        for k in chunks[0].keys():
            out[k] = np.concatenate([c[k] for c in chunks])[order]
        return out


def missing_samples(expected_n: int, present: Set[int]) -> List[Tuple[int, int]]:
    """Contiguous [lo, hi) ranges of missing sample ids (for resubmission)."""
    missing = sorted(set(range(expected_n)) - present)
    if not missing:
        return []
    ranges = []
    lo = prev = missing[0]
    for i in missing[1:]:
        if i != prev + 1:
            ranges.append((lo, prev + 1))
            lo = i
        prev = i
    ranges.append((lo, prev + 1))
    return ranges
