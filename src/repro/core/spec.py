"""Study specification — the Maestro-YAML-like interface (paper Sec. 2.2).

A study has named *steps* with shell commands (``cmd``) or registered Python
callables (``fn``), DAG dependencies (``depends``), Maestro-style
*parameters* (expanded combinatorially into the DAG) and Merlin's *samples*
(huge embarrassingly-parallel index space, expanded lazily through the task
hierarchy — Fig. 1's layering).  ``$(NAME)`` tokens in commands are
substituted from parameters / sample columns / workspace variables.

Dependency edges come in two flavors (both Maestro idioms):

* ``depends: ["step"]`` — a *matched* edge: each instance of the child
  waits for the parent instances whose parameter values agree on the
  keys both steps share (per-combo when they share all keys, a broadcast
  fan-out/fan-in when they share only some, everything when they share
  none).
* ``depends: ["step_*"]`` — a *funnel*: every instance of the child waits
  for **all** instances of the parent.

Steps may restrict which parameters they expand over (``params``), pick a
named sample set (``sample_set`` — producers publish extra sets at run
time via ``ctx.publish_samples``), route to a dedicated queue (``queue``),
and choose an execution handler (``handler``: ``fn`` / ``subprocess`` /
``scheduler`` — see ``core/handlers.py``).  The spec is *compiled* into an
explicit task-graph IR by ``core/dag.py``; nothing here executes.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Tuple

import yaml

ZIP_SUFFIX = "%zip"

#: Legal per-step ``on_failure`` actions (applied once the retry budget is
#: exhausted): re-queue and eventually poison (``retry``, the default),
#: move to the step's ``dlq.<queue>`` dead-letter queue (``dead_letter``),
#: mark the instance complete so children unlock (``skip``), or halt the
#: whole study and drain its pending instances (``halt_study``).
ON_FAILURE_MODES = ("retry", "dead_letter", "skip", "halt_study")


class SpecError(ValueError):
    """A study spec failed validation; the message says which rule and where."""


@dataclasses.dataclass
class Step:
    name: str
    cmd: Optional[str] = None          # shell command template
    fn: Optional[str] = None           # name in the runtime's fn-registry
    shell: str = "/bin/bash"           # per-step shell (paper's extension)
    depends: Tuple[str, ...] = ()
    over_samples: bool = True          # runs per sample bundle vs once
    max_retries: int = 2
    params: Optional[Tuple[str, ...]] = None  # None = expand over all params
    sample_set: str = "default"        # which published sample set to iterate
    queue: Optional[str] = None        # route to a dedicated broker queue
    handler: Optional[str] = None      # execution handler; None = infer
    resources: Dict[str, Any] = dataclasses.field(default_factory=dict)
    timeout: Optional[float] = None    # wall-clock seconds per execution
    on_failure: str = "retry"          # action once retries are exhausted

    def handler_name(self) -> str:
        """The effective handler: explicit, else inferred from fn/cmd."""
        if self.handler:
            return self.handler
        return "fn" if self.fn else "subprocess"


@dataclasses.dataclass
class StudySpec:
    name: str
    steps: List[Step]
    parameters: Dict[str, List[Any]] = dataclasses.field(default_factory=dict)
    variables: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def step(self, name: str) -> Step:
        for s in self.steps:
            if s.name == name:
                return s
        raise KeyError(name)

    def validate(self) -> None:
        """Raise :class:`SpecError` with a pointed message on the first
        violated rule (duplicate names, unknown deps/params, cycles...)."""
        if not self.steps:
            raise SpecError(f"study '{self.name}' has no steps")
        names = [s.name for s in self.steps]
        seen = set()
        for n in names:
            if n in seen:
                raise SpecError(
                    f"study '{self.name}': duplicate step name '{n}'")
            seen.add(n)
        param_keys = set(strip_zip(k) for k in self.parameters)
        for s in self.steps:
            if s.cmd is None and s.fn is None:
                raise SpecError(
                    f"step '{s.name}': needs either 'cmd' or 'fn'")
            for d in s.depends:
                base = d[:-2] if d.endswith("_*") else d
                if base not in seen:
                    raise SpecError(
                        f"step '{s.name}': depends on unknown step '{base}' "
                        f"(known steps: {', '.join(names)})")
                if base == s.name:
                    raise SpecError(
                        f"step '{s.name}': depends on itself")
            if s.on_failure not in ON_FAILURE_MODES:
                raise SpecError(
                    f"step '{s.name}': on_failure must be one of "
                    f"{', '.join(ON_FAILURE_MODES)}, got '{s.on_failure}'")
            if s.timeout is not None and s.timeout <= 0:
                raise SpecError(
                    f"step '{s.name}': timeout must be positive, "
                    f"got {s.timeout}")
            if s.max_retries < 0:
                raise SpecError(
                    f"step '{s.name}': retries must be >= 0, "
                    f"got {s.max_retries}")
            if s.params is not None:
                for p in s.params:
                    if p not in param_keys:
                        raise SpecError(
                            f"step '{s.name}': params names unknown "
                            f"parameter '{p}' (declared: "
                            f"{', '.join(sorted(param_keys)) or 'none'})")
        order = topo_order(self)
        if len(order) != len(self.steps):
            stuck = [n for n in names if n not in {s.name for s in order}]
            raise SpecError(
                f"study '{self.name}': dependency cycle involving step(s) "
                f"{', '.join(stuck)}")
        zip_lens = {k: len(v) for k, v in self.parameters.items()
                    if k.endswith(ZIP_SUFFIX)}
        if zip_lens and len(set(zip_lens.values())) > 1:
            raise SpecError(
                f"study '{self.name}': %zip parameter lists must have equal "
                f"lengths, got { {strip_zip(k): n for k, n in zip_lens.items()} }")

    @staticmethod
    def from_yaml(text: str) -> "StudySpec":
        doc = yaml.safe_load(text)
        if not isinstance(doc, dict):
            raise SpecError("spec document is not a YAML mapping")
        steps = []
        for sd in doc.get("study", []):
            run = sd.get("run", {})
            params = run.get("params")
            steps.append(Step(
                name=sd["name"],
                cmd=run.get("cmd"),
                fn=run.get("fn"),
                shell=run.get("shell", "/bin/bash"),
                depends=tuple(run.get("depends", ())),
                over_samples=bool(run.get("samples", True)),
                max_retries=int(run.get("retries",
                                        run.get("max_retries", 2))),
                params=tuple(params) if params is not None else None,
                sample_set=str(run.get("sample_set", "default")),
                queue=run.get("queue"),
                handler=run.get("handler"),
                resources=dict(run.get("resources", {}) or {}),
                timeout=(float(run["timeout"])
                         if run.get("timeout") is not None else None),
                on_failure=str(run.get("on_failure", "retry")),
            ))
        params = {k: v["values"] if isinstance(v, dict) else v
                  for k, v in (doc.get("global.parameters") or {}).items()}
        return StudySpec(
            name=doc.get("description", {}).get("name", "study"),
            steps=steps, parameters=params,
            variables=(doc.get("env", {}) or {}).get("variables", {}) or {})


def strip_zip(key: str) -> str:
    return key[:-len(ZIP_SUFFIX)] if key.endswith(ZIP_SUFFIX) else key


def topo_order(spec: StudySpec) -> List[Step]:
    done: List[Step] = []
    names_done: set = set()
    pending = list(spec.steps)
    while pending:
        progressed = False
        for s in list(pending):
            deps = {d[:-2] if d.endswith("_*") else d for d in s.depends}
            if deps <= names_done:
                done.append(s)
                names_done.add(s.name)
                pending.remove(s)
                progressed = True
        if not progressed:
            break  # cycle; validate() reports the stuck steps
    return done


def expand_parameters(spec: StudySpec) -> List[Dict[str, Any]]:
    """Expansion of the DAG parameters (Fig. 1's discrete values).

    Keys declared with a ``%zip`` suffix expand *zipped* — position i of
    every zipped list forms one combo slice (lists must have equal
    lengths) — and the zipped slice is crossed with the full Cartesian
    product of the remaining keys.  The suffix is stripped in the
    resulting combo dicts.
    """
    if not spec.parameters:
        return [{}]
    zip_keys = sorted(k for k in spec.parameters if k.endswith(ZIP_SUFFIX))
    prod_keys = sorted(k for k in spec.parameters if not k.endswith(ZIP_SUFFIX))
    zip_slices: List[Dict[str, Any]] = [{}]
    if zip_keys:
        lens = {len(spec.parameters[k]) for k in zip_keys}
        if len(lens) > 1:
            raise SpecError(
                f"%zip parameter lists must have equal lengths, got "
                f"{ {strip_zip(k): len(spec.parameters[k]) for k in zip_keys} }")
        n = lens.pop()
        zip_slices = [{strip_zip(k): spec.parameters[k][i] for k in zip_keys}
                      for i in range(n)]
    combos = []
    for zs in zip_slices:
        for vals in itertools.product(*(spec.parameters[k] for k in prod_keys)):
            combo = dict(zs)
            combo.update(zip(prod_keys, vals))
            combos.append(combo)
    return combos


def substitute(template: str, env: Dict[str, Any]) -> str:
    out = template
    for k, v in env.items():
        out = out.replace(f"$({k})", str(v))
    return out
