"""Study specification — the Maestro-YAML-like interface (paper Sec. 2.2).

A study has named *steps* with shell commands (``cmd``) or registered Python
callables (``fn``), DAG dependencies (``depends``), Maestro-style
*parameters* (expanded combinatorially into the DAG) and Merlin's *samples*
(huge embarrassingly-parallel index space, expanded lazily through the task
hierarchy — Fig. 1's layering).  ``$(NAME)`` tokens in commands are
substituted from parameters / sample columns / workspace variables; a
``depends: ["step_*"]`` entry is a funnel (wait for every parameter/sample
instance, like Maestro).  Steps may carry a per-step ``shell`` and may call
``merlin run`` again via the runtime handle — that is how the COVID cascade
(Sec. 3.3) launches phase 2 from inside phase 1.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import yaml


@dataclasses.dataclass
class Step:
    name: str
    cmd: Optional[str] = None          # shell command template
    fn: Optional[str] = None           # name in the runtime's fn-registry
    shell: str = "/bin/bash"           # per-step shell (paper's extension)
    depends: Tuple[str, ...] = ()
    over_samples: bool = True          # runs per sample bundle vs once
    max_retries: int = 2


@dataclasses.dataclass
class StudySpec:
    name: str
    steps: List[Step]
    parameters: Dict[str, List[Any]] = dataclasses.field(default_factory=dict)
    variables: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def step(self, name: str) -> Step:
        for s in self.steps:
            if s.name == name:
                return s
        raise KeyError(name)

    def validate(self) -> None:
        names = {s.name for s in self.steps}
        assert len(names) == len(self.steps), "duplicate step names"
        for s in self.steps:
            for d in s.depends:
                base = d[:-2] if d.endswith("_*") else d
                assert base in names, f"{s.name} depends on unknown step {base}"
        # no cycles
        order = topo_order(self)
        assert len(order) == len(self.steps)

    @staticmethod
    def from_yaml(text: str) -> "StudySpec":
        doc = yaml.safe_load(text)
        steps = []
        for sd in doc.get("study", []):
            run = sd.get("run", {})
            steps.append(Step(
                name=sd["name"],
                cmd=run.get("cmd"),
                fn=run.get("fn"),
                shell=run.get("shell", "/bin/bash"),
                depends=tuple(run.get("depends", ())),
                over_samples=bool(run.get("samples", True)),
                max_retries=int(run.get("max_retries", 2)),
            ))
        params = {k: v["values"] if isinstance(v, dict) else v
                  for k, v in (doc.get("global.parameters") or {}).items()}
        return StudySpec(
            name=doc.get("description", {}).get("name", "study"),
            steps=steps, parameters=params,
            variables=(doc.get("env", {}) or {}).get("variables", {}) or {})


def topo_order(spec: StudySpec) -> List[Step]:
    done: List[Step] = []
    names_done: set = set()
    pending = list(spec.steps)
    while pending:
        progressed = False
        for s in list(pending):
            deps = {d[:-2] if d.endswith("_*") else d for d in s.depends}
            if deps <= names_done:
                done.append(s)
                names_done.add(s.name)
                pending.remove(s)
                progressed = True
        if not progressed:
            break  # cycle; validate() reports via length mismatch
    return done


def expand_parameters(spec: StudySpec) -> List[Dict[str, Any]]:
    """Cartesian expansion of the DAG parameters (Fig. 1's discrete values).

    Lists of equal length expand zipped when declared via a ``%zip`` suffix
    convention; otherwise full product.
    """
    if not spec.parameters:
        return [{}]
    keys = sorted(spec.parameters)
    combos = []
    for vals in itertools.product(*(spec.parameters[k] for k in keys)):
        combos.append(dict(zip(keys, vals)))
    return combos


def substitute(template: str, env: Dict[str, Any]) -> str:
    out = template
    for k, v in env.items():
        out = out.replace(f"$({k})", str(v))
    return out
