"""Workers (``merlin run-workers``): the consumer side of the model.

Workers are deliberately decoupled from the work (paper Sec. 2.2 / Fig. 6):
they attach to a broker, lease whatever is queued — generation tasks get
expanded, real tasks get executed — and can join or leave at any time
("surge computing": ``WorkerPool.scale()`` mid-study adds capacity exactly
like a new batch allocation attaching to the Rabbit server).

Named-queue routing: a worker constructed with ``queues=("sims",)`` only
ever leases from the ``sims`` queue — the paper's routing-key mechanism for
pinning simulation vs. ML workers to disjoint streams.  ``queues=None``
(the default) subscribes to everything.  ``batch`` > 1 leases several tasks
per broker round-trip (``get_many``/``ack_many``), which matters for the
FileBroker where each claim is a filesystem rename.

Fault injection (``failure_rate``) and the broker's visibility timeout
together reproduce the paper's resilience story: a worker that "dies"
mid-task simply never acks; the task is redelivered and, because real-task
execution is idempotent (journal/once markers), re-running is safe.  Retry
caps come from one shared :class:`~repro.core.resilience.RetryPolicy`, so
both broker backends age out poison tasks identically.

Remote brokers: when the runtime's broker is a NetBroker, a broker-server
restart surfaces here as :class:`~repro.core.queue.BrokerUnavailable` after
the client's reconnect window.  Workers treat it as transient — back off,
keep polling, and effectively resubscribe once the server returns
(subscriptions are stateless: the queue list rides on every ``get_many``).
Leases stranded by the outage expire server-side and redeliver; completed
work re-acked after a reconnect is a no-op (acks are idempotent).  Acks
that hit the outage are retried after the reconnect instead of dropped
(``stats["acks_retried"]``) — far cheaper than letting a finished batch's
leases all expire and re-execute.

Backpressure: a bounded broker queue (``max_queue_depth``) surfaces as
:class:`~repro.core.queue.BrokerFull` during generation-task expansion.
Workers throttle — hold the gen lease, back off ``throttle_backoff``,
retry (``stats["throttled"]``) — and only after ``max_throttle_retries``
give the task back via the normal nack path.  Expansion never dies over a
full queue.

Heartbeats: each worker pings ``broker.heartbeat(consumer_id, queues)``
every ``heartbeat_interval`` seconds, so ``broker.stats["consumers"]``
reports live consumers per queue across all processes.

Execution engine: by default every WorkerPool routes its real fn-step
tasks through the runtime's shared :class:`~repro.core.engine.
ExecutionEngine` — workers become pure lease pumps (lease, submit, wait
for per-task outcomes, ack), and the engine's deadline-based
micro-batcher coalesces compatible tasks across get_many batches, across
workers, and across queues into single fused device launches.  Pass
``engine=None`` to keep the pre-engine behavior (per-worker, per-batch
coalescing inside the worker thread), or an ExecutionEngine instance to
share one scheduler between pools explicitly.
"""
from __future__ import annotations

import os
import random
import socket
import threading
import time
from typing import List, Optional, Sequence

from repro.core import hierarchy as H
from repro.core.engine import EngineClosed, ExecutionEngine
from repro.core.queue import (BrokerError, BrokerFull, Lease, Task,
                              dlq_queue_name)
from repro.core.resilience import BackoffPolicy, RetryPolicy
from repro.core.runtime import MerlinRuntime


class WorkerError(RuntimeError):
    pass


class Worker(threading.Thread):
    def __init__(self, runtime: MerlinRuntime, worker_id: str,
                 stop_event: threading.Event, failure_rate: float = 0.0,
                 seed: int = 0, poll_timeout: float = 0.05,
                 queues: Optional[Sequence[str]] = None, batch: int = 1,
                 retry_policy: Optional[RetryPolicy] = None,
                 heartbeat_interval: float = 2.0,
                 throttle_backoff: float = 0.2,
                 max_throttle_retries: int = 50,
                 engine: Optional[ExecutionEngine] = None,
                 broker_backoff: Optional[BackoffPolicy] = None):
        super().__init__(daemon=True, name=f"merlin-worker-{worker_id}")
        self.runtime = runtime
        self.worker_id = worker_id
        self.stop_event = stop_event
        self.failure_rate = failure_rate
        self.rng = random.Random(seed)
        self.poll_timeout = poll_timeout
        self.queues = queues
        self.batch = max(1, batch)
        self.retry_policy = retry_policy or RetryPolicy()
        self.heartbeat_interval = heartbeat_interval
        self.throttle_backoff = throttle_backoff
        self.max_throttle_retries = max_throttle_retries
        self.engine = engine
        # host-qualified: workers in different allocations (nodes) sharing
        # one broker must not collide in the heartbeat registry, or
        # stats["consumers"] undercounts the fleet
        self.consumer_id = f"{socket.gethostname()}:{os.getpid()}:{self.name}"
        self.stats = {"gen": 0, "real": 0, "failed": 0, "broker_retries": 0,
                      "acks_retried": 0, "throttled": 0, "acks_dropped": 0,
                      "dead_lettered": 0, "skipped": 0, "halted_drained": 0}
        self.first_real_at: Optional[float] = None
        self._last_hb = 0.0
        # jittered-exponential backoff for broker outages (replaces the old
        # fixed 0.2 s sleep); the streak resets on any successful lease call
        self.broker_backoff = broker_backoff or BackoffPolicy(
            base=0.05, cap=1.0, rng=self.rng)
        self._broker_err_streak = 0
        # studies known halted: positive cache so the drain check is one
        # set lookup per task instead of a counter stat
        self._halted_studies: set = set()
        # acks that hit a broker blip: retried on later iterations instead
        # of being dropped (satellite: a transient error after a successful
        # batch must not force N lease-expiry re-executions)
        self._pending_acks: List[str] = []

    _MAX_PENDING_ACKS = 10_000  # beyond this the leases have long expired
    _PUT_CHUNK = 64  # children per put_many during gen expansion

    def _heartbeat(self, broker) -> None:
        """Advisory liveness ping: the broker's stats["consumers"] view."""
        now = time.monotonic()
        if now - self._last_hb < self.heartbeat_interval:
            return
        self._last_hb = now
        hb = getattr(broker, "heartbeat", None)
        if hb is None:
            return  # non-protocol broker (a test stub): skip, don't die
        try:
            hb(self.consumer_id, self.queues)
        except BrokerError:
            pass  # broker blip: the next lease attempt handles backoff

    def _flush_acks(self, broker, fresh: List[str]) -> None:
        """Ack ``fresh`` plus anything a previous iteration failed to ack.

        Acks are idempotent and leases are broker-held, so retrying stale
        tags after a reconnect is safe — and FAR cheaper than letting every
        lease of a completed batch expire and re-execute (idempotently but
        wastefully) on another worker."""
        retried = len(self._pending_acks)
        self._pending_acks.extend(fresh)
        if not self._pending_acks:
            return
        try:
            broker.ack_many(self._pending_acks)
        except BrokerError:
            self.stats["broker_retries"] += 1
            # keep them for the next iteration; cap the backlog — anything
            # old enough to overflow it has already expired server-side.
            # The drop is journaled, never silent: operators auditing a
            # long outage can see exactly which leases were abandoned to
            # visibility-timeout redelivery.
            overflow = len(self._pending_acks) - self._MAX_PENDING_ACKS
            if overflow > 0:
                dropped = self._pending_acks[:overflow]
                self.stats["acks_dropped"] += overflow
                self.runtime.journal.append(
                    {"ev": "acks_dropped", "worker": self.worker_id,
                     "n": overflow, "tags": dropped[:100]})
                del self._pending_acks[:overflow]
        else:
            self.stats["acks_retried"] += retried
            self._pending_acks.clear()

    def run(self) -> None:
        broker = self.runtime.broker
        while not self.stop_event.is_set():
            self._heartbeat(broker)
            if self._pending_acks:
                self._flush_acks(broker, [])
            try:
                leases = broker.get_many(self.batch,
                                         timeout=self.poll_timeout,
                                         queues=self.queues)
            except BrokerError:
                # broker down (BrokerUnavailable) or a transient server-side
                # failure relayed as a structured error: back off and keep
                # polling — a dead worker thread is strictly worse, and once
                # the broker heals we lease again (reconnect-and-resubscribe;
                # the subscription is stateless, it rides on every get_many)
                self.stats["broker_retries"] += 1
                self.stop_event.wait(
                    self.broker_backoff.delay(self._broker_err_streak))
                self._broker_err_streak += 1
                continue
            self._broker_err_streak = 0
            if not leases:
                continue
            leases = self._drop_halted(leases, broker)
            if not leases:
                continue
            acks: List[str] = []
            # Coalesced execution: real leases from one get_many batch are
            # handed to the runtime together, which fuses contiguous sample
            # ranges into single device launches (execute_real_many).  Gen
            # tasks and injected failures keep the per-lease path.
            reals: List[Lease] = []
            for lease in leases:
                if lease.task.kind == "real":
                    if self.failure_rate and \
                            self.rng.random() < self.failure_rate:
                        # injected death: same bookkeeping as a raised
                        # WorkerError in the per-lease path
                        self._record_failure(lease, broker)
                    else:
                        reals.append(lease)
                    continue
                if self._run_one(lease, broker):
                    acks.append(lease.tag)
            if reals:
                if self.first_real_at is None:
                    self.first_real_at = time.monotonic()
                acks.extend(self._execute_reals(reals, broker))
            if acks:
                self._flush_acks(broker, acks)

    def _drop_halted(self, leases: List[Lease], broker) -> List[Lease]:
        """The passive drain for ``on_failure: halt_study``: tasks of a
        halted study are acked away unexecuted.  Positives are cached so
        steady-state drain costs one set lookup per task."""
        keep: List[Lease] = []
        drained: List[str] = []
        for lease in leases:
            study = lease.task.payload.get("study") \
                if isinstance(lease.task.payload, dict) else None
            if isinstance(study, str) and (
                    study in self._halted_studies
                    or self.runtime.study_halted(study)):
                self._halted_studies.add(study)
                drained.append(lease.tag)
            else:
                keep.append(lease)
        if drained:
            self.stats["halted_drained"] += len(drained)
            self._flush_acks(broker, drained)
        return keep

    def _execute_reals(self, reals: List[Lease], broker) -> List[str]:
        """Run a lease batch's real tasks; returns the ackable tags.

        Engine path (the default): fusable tasks — sample-parallel nodes
        whose :class:`~repro.core.handlers.ExecutionHandler` is
        in-process — go to the shared micro-batching scheduler and this
        thread waits for the per-task outcomes: cross-worker fusion
        happens there, and a failed task comes back as ITS handle's error
        while batch-mates succeed.  Everything else — subprocess and
        scheduler-job handlers, funnel nodes, unknown studies, or all
        tasks when ``engine=None`` — runs in-thread (fusing within this
        lease batch only, per-lease fallback on failure).  The worker
        never inspects fn vs cmd itself: ``runtime.coalescable`` consults
        the node's handler, so new handlers slot in without touching
        this dispatch."""
        acks: List[str] = []
        if self.engine is not None:
            # only fusable work goes through the shared dispatcher;
            # out-of-process handlers (subprocess, scheduler jobs) and
            # funnel nodes stay in THIS thread, so a pool of N workers
            # still runs N subprocess simulations concurrently and a slow
            # command step cannot head-of-line-block fn-step batches
            fusable, direct = [], []
            for lease in reals:
                (fusable if self.runtime.coalescable(lease.task)
                 else direct).append(lease)
            pendings = None
            if fusable:
                try:
                    pendings = self.engine.submit_many(
                        [l.task for l in fusable])
                except EngineClosed:
                    direct = reals  # pool tearing down: all in-thread
            if direct:
                acks.extend(self._execute_reals_inline(direct, broker))
            if pendings is not None:
                for lease, p in zip(fusable, pendings):
                    # dispatch is deadline-bounded (max_wait_ms), so this
                    # wait is short unless the device itself is busy
                    p.wait()
                    if isinstance(p.error, EngineClosed):
                        continue  # never executed: lease expiry redelivers
                    if p.error is None:
                        self.stats["real"] += 1
                        acks.append(lease.tag)
                    else:
                        self._record_failure(lease, broker)
            return acks
        return acks + self._execute_reals_inline(reals, broker)

    def _execute_reals_inline(self, reals: List[Lease],
                              broker) -> List[str]:
        """The in-thread path: fuse within this lease batch only."""
        acks: List[str] = []
        try:
            self.runtime.execute_real_many([l.task for l in reals])
            self.stats["real"] += len(reals)
            acks.extend(l.tag for l in reals)
        except Exception:
            # a task in the batch failed even under the runtime's
            # per-task fallback: re-run each lease individually so
            # ack/nack/retry accounting stays per-task
            for lease in reals:
                if self._run_one(lease, broker):
                    acks.append(lease.tag)
        return acks

    def _run_one(self, lease: Lease, broker) -> bool:
        """Per-lease dispatch with failure accounting; True if ackable."""
        try:
            self._dispatch(lease.task)
        except Exception:
            self._record_failure(lease, broker)
            return False
        return True

    def _record_failure(self, lease: Lease, broker) -> None:
        """Failure bookkeeping + the per-step ``on_failure`` policy.

        Every mode first consumes the retry budget — the step's
        ``retries:`` when the runtime knows the study, else this worker's
        RetryPolicy — and the mode's action applies only at exhaustion:
        ``retry`` acks the poison away (the crawler's job from then on),
        ``dead_letter`` moves it to ``dlq.<queue>``, ``skip`` marks the
        bundle complete so children unlock, ``halt_study`` stops the whole
        study and the fleet drains its tasks."""
        self.stats["failed"] += 1
        task = lease.task
        self.runtime.journal.append(
            {"ev": "task_failed", "task": task.id, "kind": task.kind,
             "payload": {k: v for k, v in task.payload.items()
                         if k != "spec"}})
        policy = self.runtime.failure_policy(task)
        if policy is None:
            mode, retry_ok = "retry", self.retry_policy.should_retry(task)
        else:
            mode, retry_ok = policy[0], task.retries < policy[1]
        try:
            if retry_ok:
                broker.nack(lease.tag)
                return
            if mode == "dead_letter":
                self._dead_letter(lease, broker)
            elif mode == "skip" and task.kind == "real":
                # gen tasks can't skip-complete (no bundle of their own);
                # they fall through to the poison path below
                self.runtime.complete_skipped(task)
                broker.ack(lease.tag)
                self.stats["skipped"] += 1
            elif mode == "halt_study":
                study = task.payload.get("study") \
                    if isinstance(task.payload, dict) else None
                if isinstance(study, str):
                    self.runtime.halt_study(
                        study, reason=f"task {task.id} exhausted retries")
                    self._halted_studies.add(study)
                broker.ack(lease.tag)
                self.runtime.note_failure(task)
            else:  # "retry" exhausted: poison, give up, leave to crawler
                broker.ack(lease.tag)
                if task.kind == "real":
                    # surface the give-up in the persisted DAG state so
                    # merlin-status shows the node as failed, not running
                    self.runtime.note_failure(task)
        except BrokerError:
            # lease expiry redelivers with retries bumped — same outcome
            self.stats["broker_retries"] += 1

    def _dead_letter(self, lease: Lease, broker) -> None:
        """Move an exhausted task to its queue's ``dlq.`` twin.  The clone
        is put BEFORE the original is acked: a crash in between leaves a
        duplicate (at-least-once, harmless), never a lost task."""
        task = lease.task
        broker.put(Task(id=task.id, kind=task.kind,
                        payload=dict(task.payload), priority=task.priority,
                        queue=dlq_queue_name(task.queue),
                        retries=task.retries))
        broker.ack(lease.tag)
        self.stats["dead_lettered"] += 1
        self.runtime.journal.append(
            {"ev": "task_dead_lettered", "task": task.id,
             "queue": task.queue, "dlq": dlq_queue_name(task.queue)})
        if task.kind == "real":
            self.runtime.note_failure(task)

    def _dispatch(self, task: Task) -> None:
        # injected failure: worker "dies" on this task (no ack, no effect)
        if self.failure_rate and self.rng.random() < self.failure_rate:
            raise WorkerError("injected failure")
        if task.kind == "gen":
            children = H.expand(task)
            # chunked puts: typical fanouts (<= _PUT_CHUNK) stay one
            # round-trip, and when backpressure strikes, a retry re-sends
            # at most one chunk — not the whole expansion — so duplicates
            # of already-admitted children stay bounded instead of
            # re-flooding the very queue whose bound tripped
            attempt = 0
            for lo in range(0, len(children), self._PUT_CHUNK):
                chunk = children[lo:lo + self._PUT_CHUNK]
                while True:
                    try:
                        self.runtime.broker.put_many(chunk)
                        break
                    except BrokerFull:
                        # backpressure: the downstream queue is at its
                        # bound.  Throttle expansion instead of dying —
                        # hold the gen lease, back off, retry this chunk
                        # (re-putting an already-admitted child duplicates
                        # it, which is safe: delivery is at-least-once and
                        # execution idempotent)
                        self.stats["throttled"] += 1
                        attempt += 1
                        if attempt >= self.max_throttle_retries or \
                                self.stop_event.wait(self.throttle_backoff):
                            # give the queue back instead of spinning
                            # forever: the raised error nacks this gen
                            # task, so expansion resumes (on any worker,
                            # re-enqueueing some duplicate children) once
                            # the flood drains
                            raise WorkerError(
                                "gen expansion backpressured past retry "
                                "budget")
            self.stats["gen"] += 1
        elif task.kind == "real":
            if self.first_real_at is None:
                self.first_real_at = time.monotonic()
            self.runtime.execute_real(task)
            self.stats["real"] += 1
        else:
            raise WorkerError(f"unknown task kind {task.kind}")


class WorkerPool:
    """An elastic pool of worker threads sharing one broker.

    ``queues`` pins every worker in the pool to the named queues (None =
    all); ``batch`` sets the per-poll lease batch size.

    ``engine`` selects the execution path for real fn-step tasks:

    * ``"auto"`` (default) — the runtime's shared
      :class:`~repro.core.engine.ExecutionEngine`: every pool on the
      runtime feeds one micro-batching scheduler, so fusion spans
      workers, pools, and queues.  ``engine_cfg`` (``max_batch``,
      ``max_wait_ms``) parameterizes it when this pool creates it.
    * ``None``/``False`` — the legacy in-thread path (coalescing only
      within one worker's lease batch).
    * an :class:`~repro.core.engine.ExecutionEngine` instance — share an
      explicitly-constructed scheduler.
    """

    def __init__(self, runtime: MerlinRuntime, n_workers: int = 2,
                 failure_rate: float = 0.0, seed: int = 0,
                 queues: Optional[Sequence[str]] = None, batch: int = 1,
                 retry_policy: Optional[RetryPolicy] = None,
                 engine="auto", engine_cfg: Optional[dict] = None):
        self.runtime = runtime
        self.stop_event = threading.Event()
        self.failure_rate = failure_rate
        self.seed = seed
        self.queues = queues
        self.batch = batch
        self.retry_policy = retry_policy
        if engine == "auto":
            self.engine = runtime.shared_engine(**(engine_cfg or {}))
        elif engine in (None, False):
            self.engine = None
        else:
            self.engine = engine.attach()
        self.workers: List[Worker] = []
        self.scale(n_workers)

    def scale(self, n_more: int) -> None:
        """Surge: attach n_more workers to the running study."""
        base = len(self.workers)
        for i in range(n_more):
            w = Worker(self.runtime, f"w{base + i}", self.stop_event,
                       failure_rate=self.failure_rate,
                       seed=self.seed + base + i,
                       queues=self.queues, batch=self.batch,
                       retry_policy=self.retry_policy,
                       engine=self.engine)
            w.start()
            self.workers.append(w)

    def drain(self, timeout: float = 120.0, poll: float = 0.02) -> bool:
        """Wait until the broker is idle (queue empty, nothing in flight).

        Once nothing is left to LEASE, kicks the engine's partial
        micro-batch out so tail-end tasks (fewer than ``max_batch`` under
        a long ``max_wait_ms``) execute now instead of waiting out the
        batching deadline — or, worse, their visibility timeout.  While
        the queue still holds work the engine is left alone: flushing
        mid-stream would shred the micro-batches drain exists to finish,
        not to defeat."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            try:
                if self.runtime.broker.idle():
                    return True
                # gate on the LOCAL buffer count first: the extra qsize/
                # inflight round-trips (they fan out per shard on a
                # federation) are only worth paying when there is
                # something to flush
                if self.engine is not None:
                    buf = self.engine.buffered()
                    # flush only when every outstanding lease has reached
                    # the buffer (inflight == buffered): a worker that has
                    # leased tasks (qsize already 0) but not yet submitted
                    # them is about to make the batch FULLER — flushing
                    # around it would shred the very micro-batch drain
                    # exists to finish.  Leasing moves a task from ready
                    # to in-flight atomically, so this check is race-free;
                    # stale foreign leases merely defer to the engine's
                    # own deadline flush.
                    if buf > 0 and self.runtime.broker.qsize() == 0 \
                            and self.runtime.broker.inflight() <= buf:
                        self.engine.flush()
            except BrokerError:
                pass  # server restarting/erroring: not idle, keep waiting
            time.sleep(poll)
        return False

    def shutdown(self) -> None:
        if self.stop_event.is_set():
            return  # idempotent: explicit shutdown + context-manager exit
        # flush BEFORE stopping: workers may be parked on handles for a
        # partially-filled micro-batch; the forced dispatch resolves them
        # so every leased task is executed and acked, not stranded until
        # its visibility timeout redelivers it.  Skipped while OTHER
        # pools share the engine — force-dispatching THEIR accumulating
        # batches would shred cross-pool coalescing, and our own workers'
        # waits are deadline-bounded (max_wait_ms) regardless.
        if self.engine is not None and self.engine.refs <= 1:
            self.engine.flush()
        self.stop_event.set()
        for w in self.workers:
            w.join(timeout=5.0)
        if self.engine is not None:
            self.engine.detach()  # last pool out closes the dispatcher

    def stats(self) -> dict:
        agg = {"gen": 0, "real": 0, "failed": 0, "broker_retries": 0,
               "acks_retried": 0, "throttled": 0, "acks_dropped": 0,
               "dead_lettered": 0, "skipped": 0, "halted_drained": 0}
        for w in self.workers:
            for k in agg:
                agg[k] += w.stats[k]
        if self.engine is not None:
            agg["engine"] = self.engine.stats()
        return agg

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
