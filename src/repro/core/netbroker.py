"""NetBroker: the shared-filesystem-free broker layer (paper Sec. 2-3).

The paper's producers and consumers on *different batch allocations*
coordinate through a standalone RabbitMQ host — not through a parallel
filesystem.  This module is that host:

* :class:`BrokerServer` fronts ANY :class:`~repro.core.queue.Broker`
  backend (InMemoryBroker or FileBroker) over a length-prefixed JSON TCP
  protocol with one daemon thread per connection.  Blocking ``get``
  requests park in the handler thread on the backend's condition variable,
  so idle consumers cost zero wire traffic — no client polling.
* :class:`NetBroker` is a TCP client implementing the full Broker
  protocol.  Batched leases (``get_many``/``ack_many``) are one round-trip
  each; every calling thread gets its own connection so a WorkerPool
  sharing one NetBroker never serializes a blocking get behind an ack.

Failure model (what makes reconnect safe):

* All queue and lease state is **server-held** (in the backend).  A client
  that vanishes mid-lease simply never acks; the lease expires and the
  task redelivers exactly like a dead in-process worker's.
* Acks are idempotent in every backend, so a client that re-sends an ack
  after a reconnect (request applied, response lost) is a no-op.
* Puts retried across a reconnect may duplicate a task — delivery is
  at-least-once by contract, and the runtime's once-markers make duplicate
  execution a no-op.
* :meth:`NetBroker._call` transparently reconnects with backoff for up to
  ``reconnect_timeout`` seconds, then raises
  :class:`~repro.core.queue.BrokerUnavailable`; workers treat that as
  transient and keep polling, so a broker server restart (same address)
  heals without worker restarts.

URL scheme (``make_broker``): ``mem://`` (fresh InMemoryBroker),
``file:///path`` (FileBroker on a shared directory), ``tcp://host:port``
(NetBroker), ``shard://h1:p1,h2:p2`` or a list of URLs (a
:class:`~repro.core.shardbroker.ShardedBroker` federation).
``MerlinRuntime(broker=...)`` accepts all of these directly.

Server-side errors relay as structured replies carrying the exception
class name, so typed conditions — notably
:class:`~repro.core.queue.BrokerFull` backpressure — survive the wire.
(Keep backends' ``put_timeout`` below the client's ``request_grace``,
default 10 s, or a blocking put times the socket out first.)

Wire codec negotiation (core/wirecodec.py): every connection starts in
JSON — the compatibility floor.  A client that prefers the binary codec
sends ``{"op": "hello", "codecs": ["bin1", "json"]}`` as its first
request; a codec-aware server replies ``{"ok": true, "codec": "bin1",
"codecs": [...]}`` and both sides switch for the rest of the
connection.  An old server answers hello with its normal unknown-op
error — the client just stays on JSON — and an old client never sends
hello, so mixed-codec fleets interoperate and a rolling upgrade never
bricks a federation.  A frame that arrives intact but fails to decode
is *quarantined*: the server replies with a typed ``CodecError``
instead of killing the connection thread (transport-level garbage —
truncated length prefix, oversized frame — still drops the
connection).

Deployment: ``python -m repro.launch.serve broker-serve`` runs a
BrokerServer as a standalone process (see examples/quickstart.py
``--two-process``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import hmac as _hmac
import os
import socket
import struct
import threading
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.queue import (Broker, BrokerError, BrokerFull,
                              BrokerUnavailable, FileBroker, InMemoryBroker,
                              Lease, StaleEpochError, Task,
                              _normalize_queues, task_to_wire)
from repro.core.resilience import BackoffPolicy, CircuitBreaker
from repro.core.wirecodec import (CodecError, DEFAULT_PREFERENCE, JSON_CODEC,
                                  get_codec, negotiate_codec)


class AuthError(BrokerError):
    """The hello handshake's HMAC was missing or invalid (shared-secret
    auth, ``REPRO_AUTH_TOKEN``) — or an op arrived before authenticating
    on a server that requires it."""


def hello_mac(token: str, codecs: Sequence[str]) -> str:
    """HMAC-SHA256 over the hello's codec offer, keyed by the shared
    secret.  Binding the offer (not just a constant) means a recorded
    hello cannot be replayed with a different negotiation."""
    msg = ("merlin-hello:" + ",".join(codecs)).encode()
    return _hmac.new(token.encode(), msg, hashlib.sha256).hexdigest()


# structured server errors carry the exception class name; the client maps
# it back to the right BrokerError subclass so e.g. backpressure
# (BrokerFull) is catchable as BrokerFull on the producer's side of the
# wire, not as a generic failure.  CodecError rides along so a
# quarantined frame surfaces typed on the sender's side too.
_ERROR_TYPES = {"BrokerFull": BrokerFull,
                "StaleEpochError": StaleEpochError,
                "CodecError": CodecError,
                "AuthError": AuthError}

# one frame = one request or response; big enough for a 32-task lease batch
# of fat payloads, small enough to reject garbage (e.g. an HTTP client)
_MAX_FRAME = 32 * 1024 * 1024


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def _send_frame(sock: socket.socket, obj: dict, codec=JSON_CODEC) -> None:
    # encode failures raise BrokerError, NOT CodecError: an unencodable
    # object is a local bug, and BrokerError is outside the client's
    # retry-on-transport-failure set, so it surfaces instead of looping
    try:
        data = codec.encode(obj)
    except (TypeError, ValueError) as e:
        raise BrokerError(f"unencodable {codec.name} frame: {e}") from e
    if len(data) > _MAX_FRAME:
        raise BrokerError(f"frame of {len(data)} bytes exceeds {_MAX_FRAME}")
    sock.sendall(struct.pack(">I", len(data)) + data)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed connection")
        buf.extend(chunk)
    return bytes(buf)


def _recv_raw(sock: socket.socket) -> bytes:
    """One intact length-prefixed frame, codec-agnostic.

    Transport-level garbage (truncated prefix, oversized length — e.g.
    an HTTP client) raises ConnectionError: the stream itself is
    unusable.  Whether the *payload* decodes is the caller's problem —
    that split is what lets the server quarantine a corrupt frame
    without dropping the connection."""
    (n,) = struct.unpack(">I", _recv_exact(sock, 4))
    if n > _MAX_FRAME:
        raise ConnectionError(f"oversized frame ({n} bytes)")
    return _recv_exact(sock, n)


def _recv_frame(sock: socket.socket, codec=JSON_CODEC) -> dict:
    return codec.decode(_recv_raw(sock))


def parse_address(address: str) -> Tuple[str, int]:
    """``tcp://host:port`` or bare ``host:port`` -> (host, port)."""
    if address.startswith("tcp://"):
        address = address[len("tcp://"):]
    host, sep, port = address.rpartition(":")
    if not sep or not port.isdigit():
        raise ValueError(f"broker address must be host:port, got {address!r}")
    return host or "127.0.0.1", int(port)


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------

class BrokerServer:
    """Serve any Broker backend to NetBroker clients over TCP.

    One daemon thread per connection; requests on a connection run in
    order (clients parallelize with per-thread connections).  A blocking
    ``get_many`` waits inside the backend for at most ``MAX_BLOCK_S`` per
    request — clients chunk longer timeouts into successive requests, which
    bounds how long a handler thread can be parked and lets ``stop()``
    return promptly.

    ``codecs`` is the preference-ordered list of wire codecs this server
    is willing to speak (advertised in the hello reply); ``("json",)``
    emulates a binary-unaware server for rolling-upgrade testing.  The
    ``shm_path`` option additionally serves the same backend over a
    same-host shared-memory registry (see core/shmring.py).
    """

    MAX_BLOCK_S = 10.0
    # served puts must come back strictly BEFORE the clients' socket
    # timeout (request_grace, 10 s) or the BrokerFull reply loses the race
    # and the client re-sends the batch; half the grace leaves room for
    # request decode + scheduling jitter
    MAX_PUT_BLOCK_S = 5.0

    def __init__(self, backend: Broker, host: str = "127.0.0.1",
                 port: int = 0, codecs: Sequence[str] = DEFAULT_PREFERENCE,
                 shm_path: Optional[str] = None,
                 auth_token: Optional[str] = None):
        self.backend = backend
        self.auth_token = auth_token
        self.codecs = tuple(codecs)
        for name in self.codecs:
            get_codec(name)  # fail fast on a typo'd codec name
        self.shm_path = shm_path
        self._shm_listener = None
        # clamp the backend's backpressure window like MAX_BLOCK_S clamps
        # gets: a put blocking past the clients' request_grace would make
        # them time out mid-put, reconnect, and re-send the batch —
        # duplicating every admitted task and stacking blocked handler
        # threads — instead of receiving the typed BrokerFull
        pt = getattr(backend, "_put_timeout", None)
        if pt is not None and pt > self.MAX_PUT_BLOCK_S:
            backend._put_timeout = self.MAX_PUT_BLOCK_S
        self.host = host
        self._requested_port = port
        self.port: Optional[int] = None
        self._lsock: Optional[socket.socket] = None
        self._accept_thread: Optional[threading.Thread] = None
        self._stopping = threading.Event()
        self._conns: set = set()
        self._conns_lock = threading.Lock()
        self.stats = {"connections": 0, "requests": 0, "errors": 0,
                      "codec_errors": 0, "auth_failures": 0,
                      "codecs": {name: 0 for name in self.codecs}}

    @property
    def address(self) -> str:
        return f"tcp://{self.host}:{self.port}"

    def start(self) -> "BrokerServer":
        s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        s.bind((self.host, self._requested_port))
        s.listen(128)
        self._lsock = s
        self.port = s.getsockname()[1]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True,
            name=f"netbroker-accept-{self.port}")
        self._accept_thread.start()
        if self.shm_path is not None:
            from repro.core.shmring import ShmListener
            self._shm_listener = ShmListener(
                self.shm_path, self._dispatch,
                max_block_s=self.MAX_BLOCK_S).start()
        return self

    def stop(self) -> None:
        """Close the listener and all client connections.

        Connections are closed abortively (SO_LINGER 0 -> RST): a graceful
        FIN would leave the server side in FIN_WAIT_2 until every client
        closes too, blocking a restart from re-binding this port.  RST
        destroys the kernel state immediately — and clients already treat
        a reset exactly like a crashed server (reconnect, idempotent
        re-ack).  Handler threads parked in a backend wait finish their
        (bounded) wait, fail to write to the closed socket, and exit."""
        self._stopping.set()
        if self._shm_listener is not None:
            self._shm_listener.stop()
            self._shm_listener = None
        if self._lsock is not None:
            # shutdown() first: close() alone does NOT wake a thread blocked
            # in accept()/recv(), and the in-flight syscall would keep the
            # LISTEN socket alive, blocking a restart from re-binding
            try:
                self._lsock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._lsock.close()
            except OSError:
                pass
        with self._conns_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                             struct.pack("ii", 1, 0))
            except OSError:
                pass
            try:
                c.shutdown(socket.SHUT_RDWR)  # wake the handler's recv()
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=2.0)

    def serve_forever(self, poll: float = 0.5) -> None:
        while not self._stopping.is_set():
            time.sleep(poll)

    def __enter__(self) -> "BrokerServer":
        if self._lsock is None:
            self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- internals -----------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stopping.is_set():
            try:
                conn, _ = self._lsock.accept()
            except OSError:
                return  # listener closed by stop()
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._conns_lock:
                self._conns.add(conn)
                self.stats["connections"] += 1
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True, name="netbroker-conn").start()

    def _serve_conn(self, conn: socket.socket) -> None:
        codec = JSON_CODEC  # every connection starts on the floor
        counted = False  # stats["codecs"]: one bump per connection
        authed = self.auth_token is None  # no token -> open server
        try:
            while not self._stopping.is_set():
                try:
                    raw = _recv_raw(conn)
                except (ConnectionError, OSError, struct.error):
                    return  # client went away / stream unusable: drop conn
                try:
                    req = codec.decode(raw)
                    if not isinstance(req, dict):
                        raise CodecError("frame is not a request object")
                except CodecError as e:
                    # quarantine: the frame arrived intact but does not
                    # decode in the negotiated codec — reply typed and keep
                    # the connection (and its handler thread) alive
                    self.stats["codec_errors"] += 1
                    try:
                        _send_frame(conn, {"ok": False,
                                           "error_type": "CodecError",
                                           "error": f"CodecError: {e}"},
                                    codec)
                    except OSError:
                        return
                    continue
                if req.get("op") == "hello":
                    if self.auth_token is not None:
                        # the MAC covers the client's codec OFFER as sent,
                        # so verify against that exact list
                        offer = [str(c) for c in (req.get("codecs") or ())]
                        mac = hello_mac(self.auth_token, offer)
                        got = req.get("auth")
                        if not (isinstance(got, str)
                                and _hmac.compare_digest(got, mac)):
                            self.stats["auth_failures"] += 1
                            try:
                                _send_frame(
                                    conn,
                                    {"ok": False,
                                     "error_type": "AuthError",
                                     "error": "AuthError: hello HMAC "
                                              "missing or invalid"},
                                    codec)
                            except OSError:
                                return
                            continue
                        authed = True
                    chosen = negotiate_codec(self.codecs,
                                             req.get("codecs") or ())
                    try:
                        _send_frame(conn, {"ok": True, "codec": chosen,
                                           "codecs": list(self.codecs)},
                                    codec)
                    except OSError:
                        return
                    codec = get_codec(chosen)  # switch AFTER the reply
                    counts = self.stats["codecs"]
                    counts[chosen] = counts.get(chosen, 0) + 1
                    counted = True
                    continue
                if not authed:
                    # ops before a valid authenticated hello are refused
                    # (typed, connection kept) — the client re-hellos with
                    # the right MAC or gives up with AuthError
                    self.stats["auth_failures"] += 1
                    try:
                        _send_frame(
                            conn,
                            {"ok": False, "error_type": "AuthError",
                             "error": "AuthError: server requires "
                                      "REPRO_AUTH_TOKEN hello auth"},
                            codec)
                    except OSError:
                        return
                    continue
                if not counted:
                    # a pre-negotiation client never sends hello: count its
                    # connection under the JSON floor so stats["codecs"]
                    # reflects the whole mixed fleet, not just upgraders
                    counts = self.stats["codecs"]
                    counts["json"] = counts.get("json", 0) + 1
                    counted = True
                try:
                    resp = {"ok": True, **(self._dispatch(req) or {})}
                except Exception as e:  # backend error -> structured reply
                    self.stats["errors"] += 1
                    resp = {"ok": False,
                            "error_type": type(e).__name__,
                            "error": f"{type(e).__name__}: {e}"}
                try:
                    _send_frame(conn, resp, codec)
                except BrokerError as e:  # reply unencodable / oversized
                    self.stats["errors"] += 1
                    try:
                        _send_frame(conn, {"ok": False,
                                           "error_type": "BrokerError",
                                           "error": f"BrokerError: {e}"},
                                    codec)
                    except OSError:
                        return
                except OSError:
                    return
        finally:
            with self._conns_lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _dispatch(self, req: dict) -> Optional[dict]:
        self.stats["requests"] += 1
        op = req.get("op")
        b = self.backend
        if op == "ping":
            return {}
        if op == "put":
            b.put(Task(**req["task"]))
            return {}
        if op == "put_many":
            b.put_many([Task(**t) for t in req["tasks"]])
            return {}
        if op == "get_many":
            timeout = req.get("timeout", 0.0)
            if timeout is None or timeout > self.MAX_BLOCK_S:
                timeout = self.MAX_BLOCK_S
            queues = req.get("queues")
            leases = b.get_many(
                int(req["n"]), timeout=float(timeout),
                queues=tuple(queues) if queues is not None else None)
            return {"leases": [{"task": task_to_wire(l.task),
                                "tag": l.tag} for l in leases]}
        if op == "ack":
            b.ack(req["tag"])
            return {}
        if op == "ack_many":
            b.ack_many(list(req["tags"]))
            return {}
        if op == "nack":
            b.nack(req["tag"])
            return {}
        if op == "qsize":
            queues = req.get("queues")
            return {"n": b.qsize(tuple(queues) if queues is not None
                                 else None)}
        if op == "queue_names":
            return {"names": b.queue_names()}
        if op == "inflight":
            return {"n": b.inflight()}
        if op == "idle":
            return {"idle": bool(b.idle())}
        if op == "stats":
            return {"stats": dict(b.stats)}
        if op == "set_visibility_timeout":
            b.set_visibility_timeout(req["queue"], float(req["timeout"]))
            return {}
        if op == "set_max_queue_depth":
            depth = req.get("depth")
            b.set_max_queue_depth(req["queue"],
                                  None if depth is None else int(depth))
            return {}
        if op == "inflight_tasks":
            return {"tasks": [[task_to_wire(t), age]
                              for t, age in b.inflight_tasks()]}
        if op == "heartbeat":
            queues = req.get("queues")
            b.heartbeat(str(req["consumer_id"]),
                        tuple(queues) if queues is not None else None)
            return {}
        # live-migration protocol ops (drain-and-forward queue handoff;
        # see repro.core.shardbroker.migrate_queue_between)
        if op == "migrate_queue":
            target = req.get("target")
            b.migrate_queue(str(req["queue"]),
                            None if target is None else str(target))
            return {}
        if op == "export_queue":
            return {"tasks": b.export_queue(str(req["queue"]),
                                            int(req.get("max_n", 256)))}
        if op == "import_tasks":
            b.import_tasks(req["tasks"])
            return {"n": len(req["tasks"])}
        raise BrokerError(f"unknown op {op!r}")


# ---------------------------------------------------------------------------
# client
# ---------------------------------------------------------------------------

class NetBroker:
    """TCP client implementing the full Broker protocol.

    Thread safety: each calling thread gets its own connection, so one
    worker thread's blocking ``get_many`` never serializes another's acks.
    All lease state lives server-side; any connection may ack any tag.

    ``get(timeout=...)`` blocks **server-side** (the handler parks on the
    backend's condition variable); the client chunks timeouts longer than
    ``block_chunk`` into successive requests so a dead server is detected
    within ``block_chunk + request_grace`` rather than the full timeout.

    ``codec="auto"`` (default) opens every connection with a JSON hello
    preferring the binary codec and transparently falls back to JSON
    when the server predates negotiation; ``"json"`` skips the hello
    entirely (byte-identical to the legacy client); ``"bin1"`` insists
    on offering only bin1 (still lands on JSON against an old server —
    JSON is the floor, never an error).
    """

    def __init__(self, address: str, connect_timeout: float = 5.0,
                 reconnect_timeout: float = 10.0,
                 request_grace: float = 10.0, block_chunk: float = 5.0,
                 breaker: Optional[CircuitBreaker] = None,
                 codec: str = "auto",
                 auth_token: Optional[str] = None):
        self.host, self.port = parse_address(address)
        self.auth_token = (auth_token if auth_token is not None
                           else os.environ.get("REPRO_AUTH_TOKEN"))
        if codec == "auto":
            self._codec_pref: Tuple[str, ...] = DEFAULT_PREFERENCE
        elif codec == "json":
            self._codec_pref = ()  # legacy wire: no hello at all
        else:
            get_codec(codec)  # fail fast on a typo'd codec name
            self._codec_pref = (codec,)
        self._negotiated = "json"  # last handshake outcome, for stats
        self.connect_timeout = connect_timeout
        self.reconnect_timeout = reconnect_timeout
        self.request_grace = request_grace
        self.block_chunk = block_chunk
        # per-endpoint circuit breaker: once a few calls have each burned a
        # full reconnect window, later calls fail fast (the endpoint is
        # DOWN) until a half-open probe heals it.  reset_timeout is short
        # so a restarted server is re-adopted within ~0.5 s, preserving the
        # pre-breaker restart-survival behavior.  Transient blips that
        # recover *within* a reconnect window never count as failures.
        self.breaker = breaker or CircuitBreaker(failure_threshold=3,
                                                 reset_timeout=0.5)
        self._backoff = BackoffPolicy(base=0.05, cap=1.0)
        self._tls = threading.local()
        # sock -> owning thread; pruned when that thread exits, else a
        # long-lived client shared by successive WorkerPools would pin one
        # fd (and one parked server handler thread) per dead worker thread
        self._socks: Dict[socket.socket, threading.Thread] = {}
        self._socks_lock = threading.Lock()
        self._reconnects = 0
        self._closed = False

    @property
    def address(self) -> str:
        return f"tcp://{self.host}:{self.port}"

    # -- connection management ----------------------------------------------
    def _connected(self) -> socket.socket:
        sock = getattr(self._tls, "sock", None)
        if sock is not None:
            return sock
        sock = socket.create_connection((self.host, self.port),
                                        timeout=self.connect_timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._tls.codec = JSON_CODEC
        # an auth token forces a hello even on the legacy-JSON wire: the
        # handshake is the only place the shared-secret MAC can travel
        if self._codec_pref or self.auth_token is not None:
            # hello travels in JSON (the floor).  An old server answers
            # with its unknown-op error — that's a valid "json" outcome,
            # not a failure; only transport errors propagate (and the
            # _call retry loop treats them like any connect failure).
            try:
                hello = {"op": "hello", "codecs": list(self._codec_pref)}
                if self.auth_token is not None:
                    hello["auth"] = hello_mac(self.auth_token,
                                              hello["codecs"])
                _send_frame(sock, hello)
                resp = _recv_frame(sock)
                chosen = resp.get("codec", "json") if resp.get("ok") \
                    else "json"
            except CodecError:
                chosen = "json"  # unintelligible reply: stay on the floor
            except (OSError, ConnectionError, struct.error):
                try:
                    sock.close()
                except OSError:
                    pass
                raise
            if chosen not in self._codec_pref:
                chosen = "json"  # never adopt a codec we didn't offer
            self._tls.codec = get_codec(chosen)
            self._negotiated = chosen
        self._tls.sock = sock
        with self._socks_lock:
            dead = [s for s, t in self._socks.items() if not t.is_alive()]
            for s in dead:
                del self._socks[s]
            self._socks[sock] = threading.current_thread()
        for s in dead:
            try:
                s.close()
            except OSError:
                pass
        return sock

    def _drop_conn(self) -> None:
        sock = getattr(self._tls, "sock", None)
        if sock is None:
            return
        self._tls.sock = None
        self._tls.codec = JSON_CODEC  # renegotiated on the next connect
        with self._socks_lock:
            self._socks.pop(sock, None)
            self._reconnects += 1
        try:
            sock.close()
        except OSError:
            pass

    def close(self) -> None:
        self._closed = True
        with self._socks_lock:
            socks, self._socks = list(self._socks), {}
        for s in socks:
            try:
                s.close()
            except OSError:
                pass

    def __enter__(self) -> "NetBroker":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- RPC core ------------------------------------------------------------
    def _call(self, op: str, _timeout_hint: float = 0.0, **payload) -> dict:
        """One request/response with transparent reconnect.

        Retries transport failures (send/recv) until ``reconnect_timeout``
        elapses, then raises BrokerUnavailable.  Retrying is safe for every
        op: gets whose response was lost leave leases that expire
        server-side, acks are idempotent, puts are at-least-once."""
        if self._closed:
            raise BrokerError("NetBroker is closed")
        if not self.breaker.allow():
            # endpoint known-dead: fail fast instead of burning another
            # caller's full reconnect window (half-open probes re-test it)
            raise BrokerUnavailable(
                f"broker at {self.address}: circuit open (failing fast)")
        deadline = time.monotonic() + self.reconnect_timeout
        attempt = 0
        while True:
            try:
                sock = self._connected()
                sock.settimeout(_timeout_hint + self.request_grace)
                codec = getattr(self._tls, "codec", JSON_CODEC)
                _send_frame(sock, {"op": op, **payload}, codec)
                resp = _recv_frame(sock, codec)
                if not isinstance(resp, dict):
                    raise CodecError("response frame is not an object")
            # CodecError here means the response STREAM desynced (not a
            # quarantined request — those come back as structured replies):
            # reconnect and retry like any transport failure
            except (OSError, ConnectionError, struct.error,
                    CodecError) as e:
                self._drop_conn()
                now = time.monotonic()
                if now >= deadline or self._closed:
                    self.breaker.record_failure()
                    raise BrokerUnavailable(
                        f"broker at {self.address} unreachable: {e}") from e
                time.sleep(min(self._backoff.delay(attempt),
                               max(0.0, deadline - now)))
                attempt += 1
                continue
            # any response — success or a structured error like BrokerFull
            # — proves the endpoint is alive
            self.breaker.record_success()
            if not resp.get("ok"):
                exc = _ERROR_TYPES.get(resp.get("error_type"), BrokerError)
                raise exc(resp.get("error", "remote broker error"))
            return resp

    def ping(self) -> bool:
        try:
            self._call("ping")
            return True
        except BrokerUnavailable:
            return False

    def wait_ready(self, timeout: float = 30.0) -> bool:
        """Poll until the server answers (for just-spawned server procs)."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.ping():
                return True
            time.sleep(0.05)
        return False

    # -- Broker protocol ------------------------------------------------------
    def put(self, task: Task) -> None:
        task.enqueued_at = time.time()
        self._call("put", task=task_to_wire(task))

    def put_many(self, tasks: List[Task]) -> None:
        now = time.time()
        for t in tasks:
            t.enqueued_at = now
        self._call("put_many", tasks=[task_to_wire(t) for t in tasks])

    def get(self, timeout: Optional[float] = 0.0,
            queues: Optional[Sequence[str]] = None) -> Optional[Lease]:
        leases = self.get_many(1, timeout=timeout, queues=queues)
        return leases[0] if leases else None

    def get_many(self, n: int, timeout: Optional[float] = 0.0,
                 queues: Optional[Sequence[str]] = None) -> List[Lease]:
        qsel = _normalize_queues(queues)
        qlist = None if qsel is None else list(qsel)
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if deadline is None:
                chunk = self.block_chunk
            else:
                chunk = max(0.0, min(self.block_chunk,
                                     deadline - time.monotonic()))
            resp = self._call("get_many", _timeout_hint=chunk, n=n,
                              timeout=chunk, queues=qlist)
            leases = [Lease(Task(**d["task"]), d["tag"])
                      for d in resp["leases"]]
            if leases:
                return leases
            if deadline is not None and time.monotonic() >= deadline:
                return []

    def ack(self, tag: str) -> None:
        self._call("ack", tag=tag)

    def ack_many(self, tags: Iterable[str]) -> None:
        tags = list(tags)
        if tags:
            self._call("ack_many", tags=tags)

    def nack(self, tag: str) -> None:
        self._call("nack", tag=tag)

    def qsize(self, queues: Optional[Sequence[str]] = None) -> int:
        qsel = _normalize_queues(queues)
        return int(self._call(
            "qsize", queues=None if qsel is None else list(qsel))["n"])

    def queue_names(self) -> List[str]:
        return list(self._call("queue_names")["names"])

    def inflight(self) -> int:
        return int(self._call("inflight")["n"])

    def idle(self) -> bool:
        return bool(self._call("idle")["idle"])

    def set_visibility_timeout(self, queue: str, timeout: float) -> None:
        self._call("set_visibility_timeout", queue=queue,
                   timeout=float(timeout))

    def set_max_queue_depth(self, queue: str, depth: Optional[int]) -> None:
        """Override one queue's backpressure bound in the server backend
        (None clears it); subsequent puts from ANY client honor it."""
        self._call("set_max_queue_depth", queue=queue,
                   depth=None if depth is None else int(depth))

    def heartbeat(self, consumer_id: str,
                  queues: Optional[Sequence[str]] = None) -> None:
        """Register/refresh this consumer in the server backend's heartbeat
        registry; surfaces in ``stats["consumers"]`` for every client."""
        qsel = _normalize_queues(queues)
        self._call("heartbeat", consumer_id=consumer_id,
                   queues=None if qsel is None else list(qsel))

    def inflight_tasks(self) -> List[Tuple[Task, float]]:
        return [(Task(**d), float(age))
                for d, age in self._call("inflight_tasks")["tasks"]]

    # -- live-migration protocol ops (both codecs: plain dict payloads) ------
    def migrate_queue(self, queue: str, target: Optional[str]) -> None:
        """Mark/clear ``queue`` migrating on the server backend (while
        marked: consumers see it empty, puts forward to ``target``)."""
        self._call("migrate_queue", queue=queue,
                   target=None if target is None else str(target))

    def export_queue(self, queue: str, max_n: int = 256) -> List[Dict[str, Any]]:
        """Atomically pop up to ``max_n`` pending tasks as wire dicts."""
        return list(self._call("export_queue", queue=queue,
                               max_n=int(max_n))["tasks"])

    def import_tasks(self, tasks: List[Dict[str, Any]]) -> None:
        """Enqueue exported task dicts, exempt from the depth bound."""
        self._call("import_tasks",
                   tasks=[t if isinstance(t, dict) else task_to_wire(t)
                          for t in tasks])

    @property
    def stats(self) -> Dict[str, int]:
        s = dict(self._call("stats")["stats"])
        s["net_reconnects"] = self._reconnects
        s["circuit"] = self.breaker.state
        s["wire_codec"] = self._negotiated
        return s


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------

def make_broker(url, **kwargs) -> Broker:
    """Build a broker from a URL (or a list of endpoint URLs).

    * ``mem://``               fresh in-process InMemoryBroker
    * ``file:///shared/dir``   FileBroker on a shared directory
    * ``tcp://host:port``      NetBroker client to a BrokerServer
    * ``shm://<registry>``     ShmBroker: same-host shared-memory channel
      to a BrokerServer started with ``shm_path=<registry>``
    * ``shard://h1:p1,h2:p2``  ShardedBroker federating N endpoints
      (comma-separated; entries without a scheme default to ``tcp://``;
      ``|``-separated replicas per shard — ``shard://h1:p1|h1r:p1r,...``
      — fail over under a fenced per-shard epoch when a primary dies)
    * ``shard+file://<path>``  ShardedBroker assembled from an endpoint
      discovery file published by ``broker-serve --announce <path>``
      (waits for the declared federation size; ``expect=`` overrides it,
      ``discover_timeout=`` bounds the wait)
    * ``ring+file://<path>``   ELASTIC ShardedBroker following the
      membership registry at ``<path>`` (``broker-serve --join <path>``):
      routing re-resolves on membership version bumps, so shards can
      join and leave while this client runs
    * ``["tcp://...", ...]``   a list/tuple of URLs == a ShardedBroker

    Extra kwargs go to the chosen constructor (e.g. ``visibility_timeout``
    for local backends, ``reconnect_timeout`` for NetBroker); for sharded
    brokers, ``queue_shards=`` and ``poll_slice=`` are consumed by
    ShardedBroker and the rest forwarded to every endpoint client.
    """
    if isinstance(url, (list, tuple)):
        from repro.core.shardbroker import ShardedBroker
        return ShardedBroker(list(url), **kwargs)
    if url.startswith("shard+file://"):
        from repro.core.shardbroker import discover_shards
        path = url[len("shard+file://"):]
        if not path:
            raise ValueError("shard+file:// broker URL needs the announce "
                             "file path")
        return discover_shards(path,
                               expect=kwargs.pop("expect", None),
                               timeout=kwargs.pop("discover_timeout", 10.0),
                               **kwargs)
    if url.startswith("ring+file://"):
        from repro.core.shardbroker import ShardedBroker
        path = url[len("ring+file://"):]
        if not path:
            raise ValueError("ring+file:// broker URL needs the "
                             "membership file path")
        return ShardedBroker.from_membership(path, **kwargs)
    if url.startswith("shard://"):
        from repro.core.shardbroker import ShardedBroker
        # each comma-separated shard entry may carry |-separated replica
        # endpoints: "shard://h1:p1|h1r:p1r,h2:p2" — the first endpoint is
        # the initial primary, the rest are failover candidates
        endpoints = []
        for entry in url[len("shard://"):].split(","):
            if not entry:
                continue
            cands = [e if "://" in e else f"tcp://{e}"
                     for e in entry.split("|") if e]
            if not cands:
                continue
            endpoints.append(cands[0] if len(cands) == 1 else cands)
        if not endpoints:
            raise ValueError("shard:// broker URL needs at least one "
                             "comma-separated endpoint")
        return ShardedBroker(endpoints, **kwargs)
    if url.startswith("tcp://"):
        return NetBroker(url, **kwargs)
    if url.startswith("shm://"):
        from repro.core.shmring import ShmBroker
        path = url[len("shm://"):]
        if not path:
            raise ValueError("shm:// broker URL needs the registry file "
                             "path published by the server")
        return ShmBroker(path, **kwargs)
    if url.startswith("mem://"):
        return InMemoryBroker(**kwargs)
    if url.startswith("file://"):
        path = url[len("file://"):]
        if not path:
            raise ValueError("file:// broker URL needs a directory path")
        return FileBroker(path, **kwargs)
    raise ValueError(f"unsupported broker URL {url!r} (expected mem://, "
                     "file://<dir>, tcp://host:port, shm://<registry>, "
                     "or shard://h:p,h:p)")
