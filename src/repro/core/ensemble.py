"""Device-fused ensemble execution — the TPU adaptation of Merlin's bundles.

On Sierra a Merlin "bundle" was 10 serial subprocess simulations per task
(Sec. 3.1); per-sample overhead ~33 ms (Fig. 5).  On a TPU/accelerator the
equivalent unit is a *vmapped batch*: a leaf task's [lo, hi) sample range is
executed as ONE jitted ``vmap(simulator)`` call, so the marginal per-sample
overhead is device-level, not process-level.  The hierarchy
(core/hierarchy.py) still generates the index space; only the leaf
execution is fused.

Multi-device dispatch
---------------------
On hosts exposing more than one device the executor defaults to a shared
1-D mesh (:func:`device_mesh`) and dispatches fused bundles with
``shard_map`` over the ``data`` axis: each device runs the vmapped
simulator on its contiguous slice of the padded batch.  The power-of-two
bucket schedule doubles as the sharding grid — any bucket >= the
(power-of-two) device count divides the mesh evenly, so no extra padding
logic exists for sharding; buckets smaller than the mesh fall back to
single-device jit.  Per-row independence makes the sharded result
bit-for-bit identical to the single-device one (regression-tested with
8 forced host devices), and the compile count stays within the same
bucketed bound: one trace per bucket, shard_mapped or not.

Bucketing policy
----------------
Ragged bundle sizes are the enemy of a jit cache: an optimization loop that
re-slices its batch every iteration produces O(#distinct sizes) distinct
``vmap`` shapes, each a fresh XLA compile.  ``run_bundle`` therefore pads
every batch up to the next power-of-two *bucket* (``bucket_for``) with
repeated edge rows and masked (don't-care) seeds, runs the compiled bucket
program, and slices the outputs back to the real ``[lo, hi)`` extent, so
the total number of compiles for any workload is O(log2 max_bundle), not
O(#distinct sizes).

Compile-cache policy
--------------------
The jit cache is **process-wide** by default: executors created for
different bundlers / iterations / studies share compiled programs keyed by
``(simulator, mesh, data_axis, bucket)``.  A fresh ``EnsembleExecutor`` per
task (the seed behavior) therefore no longer discards compiled code.  Pass
``share_cache=False`` to opt a specific executor out (used by benchmarks to
reproduce the pre-bucketing baseline).  ``trace_count()`` exposes a global
trace counter for compile-count regression tests.

Dispatch is async: the jitted call returns device futures; results are
synchronized (``jax.block_until_ready``) only when they must be
materialized — at bundler-write time, or when the caller asks for numpy
(``block=True``, the default).

``EnsembleExecutor.step_fn()`` returns a Merlin fn-step closure that runs
the simulator over ``ctx.sample_block`` and writes results through the
Bundler — i.e. the whole JAG workflow (Fig. 7) as one registered step.
Coalesced contexts (``ctx.sub_ranges``, core/runtime.py) execute as one
device launch but still publish one bundle file per original sub-task, so
the on-disk layout, crawl/resubmit granularity, and idempotency markers are
identical to per-task execution.
"""
from __future__ import annotations

import threading
import time
import weakref
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bundler import Bundler

# process-wide 1-D device mesh (multi-device dispatch) ------------------------
# Built lazily over ALL local devices with one "data" axis.  Fused bundles
# whose padded (power-of-two) size divides the device count dispatch via
# shard_map; smaller buckets fall back to the single-device jit — the bucket
# schedule is reused as the sharding grid, not duplicated.  Tests force a
# multi-device host with XLA_FLAGS=--xla_force_host_platform_device_count=8
# in a subprocess (the in-process suite keeps 1 device, see tests/conftest).
_DEVICE_MESH = None


def device_mesh(axis: str = "data"):
    """The shared 1-D mesh over this process's local devices; None on
    1-device hosts.  LOCAL devices only: on a multi-host jax.distributed
    deployment a global-device mesh would require every process to enter
    the launch collectively, which broker-driven workers never do."""
    global _DEVICE_MESH
    if jax.local_device_count() <= 1:
        return None
    if _DEVICE_MESH is None or _DEVICE_MESH.axis_names != (axis,):
        from jax.sharding import Mesh
        _DEVICE_MESH = Mesh(np.array(jax.local_devices()), (axis,))
    return _DEVICE_MESH

# process-wide compile cache + trace counter ---------------------------------
# Outer level is a WeakKeyDictionary on the simulator callable: per-study
# simulator closures (and the XLA executables compiled for them) are evicted
# when the last executor referencing them dies, so a long-lived worker
# process does not pin dead simulators forever.
_CACHE_LOCK = threading.Lock()
_SHARED_JIT: "weakref.WeakKeyDictionary[Callable, Dict[Tuple, Callable]]" = \
    weakref.WeakKeyDictionary()
_TRACE_COUNT = 0


def _count_trace() -> None:
    """Called from inside traced functions: runs once per (re)trace."""
    global _TRACE_COUNT
    _TRACE_COUNT += 1


def trace_count() -> int:
    """Total simulator traces (== XLA compiles) in this process so far."""
    return _TRACE_COUNT


def bucket_for(n: int) -> int:
    """Smallest power-of-two >= n: the padded batch size for a ragged n."""
    if n <= 1:
        return 1
    return 1 << (n - 1).bit_length()


def bucket_schedule(max_n: int) -> List[int]:
    """All bucket sizes needed for bundles up to ``max_n`` (the compile
    bound asserted by the regression test: len == ceil(log2 max_n) + 1)."""
    out = [1]
    while out[-1] < max_n:
        out.append(out[-1] * 2)
    return out


def pad_rows(arr: np.ndarray, to: int) -> np.ndarray:
    """Pad a (n, ...) array to ``to`` rows by repeating the last row (keeps
    padded work numerically tame; outputs for pad rows are discarded)."""
    n = len(arr)
    if n == to:
        return arr
    reps = np.repeat(arr[-1:], to - n, axis=0)
    return np.concatenate([arr, reps], axis=0)


class EnsembleExecutor:
    def __init__(self, simulator: Callable, bundler: Optional[Bundler] = None,
                 mesh="auto", data_axis: str = "data", bucketed: bool = True,
                 share_cache: bool = True):
        """simulator: f(params_row: (d,) array, rng) -> dict of arrays.

        ``mesh="auto"`` (default) resolves to the process-wide 1-D
        :func:`device_mesh` when the host exposes more than one device
        (else single-device, exactly the old behavior); ``mesh=None``
        forces single-device; an explicit Mesh pins dispatch to it.
        """
        self.simulator = simulator
        self.bundler = bundler
        self.data_axis = data_axis
        self.mesh = device_mesh(data_axis) if mesh == "auto" else mesh
        self.bucketed = bucketed
        self.share_cache = share_cache
        self._private_jit: Dict[Tuple, Callable] = {}
        self.stats = {"bundles": 0, "samples": 0, "sim_time": 0.0,
                      "write_s": 0.0,
                      "compiles": 0, "launches": 0, "padded_samples": 0,
                      "mesh_launches": 0,
                      "devices": 1 if self.mesh is None
                      else int(self.mesh.shape[data_axis])}

    def _mesh_divides(self, n: int) -> bool:
        """True when size-n batches shard evenly over the mesh.  Power-of-
        two buckets >= a power-of-two device count always do, so the
        bucket padding doubles as the sharding grid; smaller buckets (or
        odd meshes) fall back to single-device dispatch."""
        return self.mesh is not None and \
            n % int(self.mesh.shape[self.data_axis]) == 0

    def _build(self, n: int) -> Callable:
        def run(batch, seeds):
            _count_trace()
            rngs = jax.vmap(jax.random.PRNGKey)(seeds)
            return jax.vmap(self.simulator)(batch, rngs)

        # donation frees the input buffers for reuse by the outputs; XLA on
        # CPU can't honor it and warns, so only donate on real accelerators
        donate = (0, 1) if jax.default_backend() != "cpu" else ()
        if self._mesh_divides(n):
            # shard_map over the 1-D data axis: each device runs the same
            # vmapped simulator on its n/ndev contiguous rows.  Rows are
            # independent (per-row rng from the row's seed), so the split
            # is bit-for-bit identical to the single-device vmap — the
            # multi-device equivalence test asserts exactly that.
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            spec = P(self.data_axis)
            sharded = shard_map(run, mesh=self.mesh,
                                in_specs=(spec, spec), out_specs=spec)
            return jax.jit(sharded, donate_argnums=donate)
        return jax.jit(run, donate_argnums=donate)

    def _compiled(self, n: int) -> Callable:
        """The jitted vmapped simulator for padded size n (cached; shared
        process-wide unless this executor opted out)."""
        key = (self.mesh, self.data_axis, n)
        if self.share_cache:
            with _CACHE_LOCK:
                per_sim = _SHARED_JIT.setdefault(self.simulator, {})
                fn = per_sim.get(key)
                if fn is None:
                    fn = per_sim[key] = self._build(n)
                    self.stats["compiles"] += 1
            return fn
        if key not in self._private_jit:
            self._private_jit[key] = self._build(n)
            self.stats["compiles"] += 1
        return self._private_jit[key]

    def run_bundle(self, lo: int, hi: int, samples: np.ndarray,
                   sub_ranges: Optional[Sequence[Tuple[int, int]]] = None,
                   block: bool = True, defer_write: bool = False):
        """Simulate samples [lo, hi) as one fused device launch.

        ``sub_ranges``: optional absolute [slo, shi) spans partitioning
        [lo, hi); one bundle file is written per span (coalesced execution
        keeps the per-task on-disk layout).  ``block=False`` skips the final
        host sync and returns device arrays (only valid without a bundler).

        ``defer_write=True`` (bundler only) dispatches the compute and
        returns a zero-arg closure that performs the host sync + bundle
        writes when called — the engine's writer thread runs it so the
        write of this bundle overlaps the dispatch of the next one
        (``stats["write_s"]`` accumulates on the closure's thread).
        """
        t0 = time.monotonic()
        n = hi - lo
        samples = np.asarray(samples)
        if len(samples) != n:
            raise ValueError(f"sample block has {len(samples)} rows "
                             f"for range [{lo}, {hi})")
        padded = bucket_for(n) if self.bucketed else n
        batch = jnp.asarray(pad_rows(samples, padded))
        # seeds beyond hi are masked work: their outputs are sliced away
        seeds = jnp.arange(lo, lo + padded, dtype=jnp.uint32)
        out = self._compiled(padded)(batch, seeds)
        if padded != n:
            out = jax.tree.map(lambda a: a[:n], out)
        self.stats["bundles"] += 1
        self.stats["samples"] += n
        self.stats["padded_samples"] += padded - n
        self.stats["launches"] += 1
        if self._mesh_divides(padded):
            self.stats["mesh_launches"] += 1
        if self.bundler is not None:
            spans = tuple(sub_ranges or ((lo, hi),))

            def finish_write(dev_out=out):
                tw = time.monotonic()
                jax.block_until_ready(dev_out)  # sync once, at write time
                host = jax.tree.map(np.asarray, dev_out)
                for slo, shi in spans:
                    sl = slice(slo - lo, shi - lo)
                    self.bundler.write_bundle(
                        slo, shi, {k: v[sl] for k, v in host.items()})
                self.stats["write_s"] += time.monotonic() - tw
                return host
            if defer_write:
                self.stats["sim_time"] += time.monotonic() - t0
                return finish_write
            out = finish_write()
        elif block:
            out = jax.tree.map(np.asarray, out)
        self.stats["sim_time"] += time.monotonic() - t0
        return out

    def step_fn(self) -> Callable:
        """A Merlin fn-step: simulate ctx's sample block and bundle results.

        Under deferred execution (the engine's write pipeline) the bundle
        write is parked on ``ctx.defer`` so it runs on the writer thread,
        after this batch's compute but overlapping the next dispatch."""
        def step(ctx):
            block = ctx.sample_block
            if block is None:
                raise ValueError("ensemble step requires study samples")
            pending = self.run_bundle(
                ctx.lo, ctx.hi, block,
                sub_ranges=getattr(ctx, "sub_ranges", None),
                defer_write=getattr(ctx, "deferrable", False))
            if callable(pending):
                ctx.defer(pending)
        return step
