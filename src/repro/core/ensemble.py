"""Device-fused ensemble execution — the TPU adaptation of Merlin's bundles.

On Sierra a Merlin "bundle" was 10 serial subprocess simulations per task
(Sec. 3.1); per-sample overhead ~33 ms (Fig. 5).  On a TPU/accelerator the
equivalent unit is a *vmapped batch*: a leaf task's [lo, hi) sample range is
executed as ONE jitted ``vmap(simulator)`` call, optionally ``shard_map``-
distributed over the mesh's data axis, so the marginal per-sample overhead
is device-level, not process-level.  The hierarchy (core/hierarchy.py) still
generates the index space; only the leaf execution is fused.

``EnsembleExecutor.step_fn()`` returns a Merlin fn-step closure that runs
the simulator over ``ctx.sample_block`` and writes results through the
Bundler — i.e. the whole JAG workflow (Fig. 7) as one registered step.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bundler import Bundler


class EnsembleExecutor:
    def __init__(self, simulator: Callable, bundler: Optional[Bundler] = None,
                 mesh=None, data_axis: str = "data"):
        """simulator: f(params_row: (d,) array, rng) -> dict of arrays."""
        self.simulator = simulator
        self.bundler = bundler
        self.mesh = mesh
        self.data_axis = data_axis
        self._jitted: Dict[int, Callable] = {}
        self.stats = {"bundles": 0, "samples": 0, "sim_time": 0.0}

    def _compiled(self, n: int) -> Callable:
        """One jitted vmapped simulator per bundle size (cached)."""
        if n not in self._jitted:
            def run(batch, seeds):
                rngs = jax.vmap(jax.random.PRNGKey)(seeds)
                return jax.vmap(self.simulator)(batch, rngs)

            if self.mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec as P
                axis = self.data_axis if n % self.mesh.shape[self.data_axis] == 0 \
                    else None
                sh = NamedSharding(self.mesh, P(axis))
                self._jitted[n] = jax.jit(run, in_shardings=(sh, sh),
                                          out_shardings=sh)
            else:
                self._jitted[n] = jax.jit(run)
        return self._jitted[n]

    def run_bundle(self, lo: int, hi: int, samples: np.ndarray) -> Dict[str, np.ndarray]:
        t0 = time.monotonic()
        batch = jnp.asarray(samples)
        seeds = jnp.arange(lo, hi, dtype=jnp.uint32)
        out = self._compiled(hi - lo)(batch, seeds)
        out = jax.tree.map(lambda a: np.asarray(a), out)
        self.stats["bundles"] += 1
        self.stats["samples"] += hi - lo
        self.stats["sim_time"] += time.monotonic() - t0
        if self.bundler is not None:
            self.bundler.write_bundle(lo, hi, out)
        return out

    def step_fn(self) -> Callable:
        """A Merlin fn-step: simulate ctx's sample block and bundle results."""
        def step(ctx):
            block = ctx.sample_block
            if block is None:
                raise ValueError("ensemble step requires study samples")
            self.run_bundle(ctx.lo, ctx.hi, block)
        return step
