"""Wire codecs: how broker frames and task files become bytes.

The broker wire (core/netbroker.py) originally round-tripped every frame
— including float sample payloads — as length-prefixed JSON text.  For
the array-heavy traffic the ML-in-the-loop ensembles actually generate
(sample vectors, observable slices), text float formatting/parsing
dominates the transport cost end to end.  This module adds a compact
binary codec negotiated per connection, with JSON kept as the
compatibility floor so mixed-codec fleets interoperate and a rolling
upgrade never bricks a federation.

Two codecs, one interface (``encode(obj) -> bytes`` / ``decode(data) ->
obj``):

* :class:`JsonCodec` (``"json"``) — the historical format and the floor
  every peer speaks.  A connection starts in JSON and stays there unless
  a handshake upgrades it.
* :class:`BinCodec` (``"bin1"``) — a flat tag+varint binary encoding of
  the same JSON-shaped objects.  Scalars are tagged values (ints as
  zigzag varints, float64 as 8 raw little-endian bytes); strings/bytes
  are length-prefixed; lists/dicts are count-prefixed.  The payoff tags:
  a homogeneous list of Python floats is carried as ONE raw
  little-endian float64 buffer (``struct.pack``/``unpack`` — C speed,
  no text), and numpy arrays are carried as dtype + shape + raw
  C-contiguous bytes (used by the shm bundle ring, core/shmring.py).
  ``bin1`` round-trips every value JSON can carry, plus ``bytes`` and
  ``np.ndarray``.

Decoding is defensive: every length/count is bounds-checked against the
remaining buffer before allocation, unknown tags, truncation, trailing
garbage, and over-deep nesting all raise :class:`CodecError` — a frame
of corrupt bytes produces a typed error, never a hang or an
interpreter-level blowup (the chaos fuzz tests bit-flip real frames and
assert exactly this).

Negotiation (:func:`negotiate_codec`) picks the first client preference
the server also supports, falling back to ``"json"``; the handshake
itself always travels in JSON (core/netbroker.py documents the hello
op).  The FileBroker's v2 task-file format reuses ``bin1`` behind a
leading format-version byte (see ``core/queue.py``).
"""
from __future__ import annotations

import json
import struct
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


class CodecError(ValueError):
    """A frame or file could not be decoded (corrupt, truncated, or not
    in the negotiated format).  Typed so transports can quarantine the
    frame — reply with a structured error / dead-letter the file —
    instead of killing the connection or redelivering forever."""


# ---------------------------------------------------------------------------
# JSON codec (the compatibility floor)
# ---------------------------------------------------------------------------

def _json_default(obj: Any) -> Any:
    # array payloads must survive a fallback-to-JSON connection (mixed
    # fleet, failed upgrade): ndarrays degrade to nested lists — text,
    # slow, but correct.  bin1 keeps them as raw buffers.
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, (np.integer, np.floating, np.bool_)):
        return obj.item()
    raise TypeError(f"not JSON-serializable: {type(obj).__name__}")


class JsonCodec:
    name = "json"

    @staticmethod
    def encode(obj: Any) -> bytes:
        return json.dumps(obj, default=_json_default).encode("utf-8")

    @staticmethod
    def decode(data: bytes) -> Any:
        try:
            return json.loads(data.decode("utf-8"))
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise CodecError(f"bad JSON frame: {e}") from e


# ---------------------------------------------------------------------------
# bin1: flat tag + varint binary codec
# ---------------------------------------------------------------------------

_T_NONE = 0x00
_T_FALSE = 0x01
_T_TRUE = 0x02
_T_INT = 0x03      # zigzag varint (unbounded)
_T_F64 = 0x04      # 8 bytes LE double
_T_STR = 0x05      # varint byte length + utf8
_T_BYTES = 0x06    # varint length + raw
_T_LIST = 0x07     # varint count + items
_T_DICT = 0x08     # varint count + (key, value) pairs
_T_F64ARR = 0x09   # varint count + count * 8 bytes LE double -> list[float]
_T_NDARR = 0x0A    # dtype str + varint ndim + shape varints + raw C bytes

_MAX_DEPTH = 64


def _pack_varint(out: bytearray, n: int) -> None:
    while True:
        b = n & 0x7F
        n >>= 7
        if n:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def _pack_zigzag(out: bytearray, v: int) -> None:
    _pack_varint(out, (v << 1) if v >= 0 else ((-v << 1) - 1))


def _enc(out: bytearray, obj: Any, depth: int) -> None:
    if depth > _MAX_DEPTH:
        raise CodecError(f"nesting deeper than {_MAX_DEPTH}")
    t = type(obj)
    if t is str:
        raw = obj.encode("utf-8")
        out.append(_T_STR)
        _pack_varint(out, len(raw))
        out += raw
    elif t is bool:
        out.append(_T_TRUE if obj else _T_FALSE)
    elif t is int:
        out.append(_T_INT)
        _pack_zigzag(out, obj)
    elif t is float:
        out.append(_T_F64)
        out += struct.pack("<d", obj)
    elif t is dict:
        out.append(_T_DICT)
        _pack_varint(out, len(obj))
        for k, v in obj.items():
            _enc(out, k, depth + 1)
            _enc(out, v, depth + 1)
    elif t is list or t is tuple:
        n = len(obj)
        if n and type(obj[0]) is float:
            # the payoff path: a homogeneous float list travels as ONE
            # raw LE float64 buffer instead of n formatted text numbers
            for x in obj:
                if type(x) is not float:
                    break
            else:
                out.append(_T_F64ARR)
                _pack_varint(out, n)
                out += struct.pack(f"<{n}d", *obj)
                return
        out.append(_T_LIST)
        _pack_varint(out, n)
        for v in obj:
            _enc(out, v, depth + 1)
    elif obj is None:
        out.append(_T_NONE)
    elif isinstance(obj, np.ndarray):
        arr = np.ascontiguousarray(obj)
        dt = arr.dtype.str.encode("ascii")
        out.append(_T_NDARR)
        _pack_varint(out, len(dt))
        out += dt
        _pack_varint(out, arr.ndim)
        for d in arr.shape:
            _pack_varint(out, d)
        out += arr.tobytes()
    elif t is bytes or t is bytearray:
        out.append(_T_BYTES)
        _pack_varint(out, len(obj))
        out += obj
    elif isinstance(obj, (bool, np.bool_)):  # bool subclasses + numpy bool_
        out.append(_T_TRUE if obj else _T_FALSE)
    elif isinstance(obj, (int, np.integer)):
        out.append(_T_INT)
        _pack_zigzag(out, int(obj))
    elif isinstance(obj, (float, np.floating)):
        out.append(_T_F64)
        out += struct.pack("<d", float(obj))
    elif isinstance(obj, str):
        raw = obj.encode("utf-8")
        out.append(_T_STR)
        _pack_varint(out, len(raw))
        out += raw
    elif isinstance(obj, (list, tuple)):
        out.append(_T_LIST)
        _pack_varint(out, len(obj))
        for v in obj:
            _enc(out, v, depth + 1)
    elif isinstance(obj, dict):
        out.append(_T_DICT)
        _pack_varint(out, len(obj))
        for k, v in obj.items():
            _enc(out, k, depth + 1)
            _enc(out, v, depth + 1)
    else:
        raise CodecError(f"bin1 cannot encode {type(obj).__name__}")


def _read_varint(data: bytes, off: int, end: int) -> Tuple[int, int]:
    # no length cap: ints are unbounded (JSON parity) and the frame end
    # bounds the worst case; counts are sanity-checked by the callers
    n = 0
    shift = 0
    while True:
        if off >= end:
            raise CodecError("truncated varint")
        b = data[off]
        off += 1
        n |= (b & 0x7F) << shift
        if not b & 0x80:
            return n, off
        shift += 7


def _dec(data: bytes, off: int, end: int, depth: int) -> Tuple[Any, int]:
    if depth > _MAX_DEPTH:
        raise CodecError(f"nesting deeper than {_MAX_DEPTH}")
    if off >= end:
        raise CodecError("truncated frame")
    tag = data[off]
    off += 1
    if tag == _T_STR:
        n, off = _read_varint(data, off, end)
        if n > end - off:
            raise CodecError("string length past end of frame")
        try:
            s = data[off:off + n].decode("utf-8")
        except UnicodeDecodeError as e:
            raise CodecError(f"bad utf8 in string: {e}") from e
        return s, off + n
    if tag == _T_INT:
        u, off = _read_varint(data, off, end)
        return (u >> 1) if not (u & 1) else -((u + 1) >> 1), off
    if tag == _T_F64:
        if 8 > end - off:
            raise CodecError("truncated float64")
        return struct.unpack_from("<d", data, off)[0], off + 8
    if tag == _T_DICT:
        n, off = _read_varint(data, off, end)
        if n > (end - off):  # each entry needs >= 2 bytes; cheap bound
            raise CodecError("dict count past end of frame")
        d: Dict[Any, Any] = {}
        for _ in range(n):
            k, off = _dec(data, off, end, depth + 1)
            v, off = _dec(data, off, end, depth + 1)
            try:
                d[k] = v
            except TypeError as e:  # corrupt frame decoded a list/array key
                raise CodecError(f"unhashable dict key: {e}") from e
        return d, off
    if tag == _T_LIST:
        n, off = _read_varint(data, off, end)
        if n > end - off:  # each item needs >= 1 byte
            raise CodecError("list count past end of frame")
        out: List[Any] = []
        for _ in range(n):
            v, off = _dec(data, off, end, depth + 1)
            out.append(v)
        return out, off
    if tag == _T_F64ARR:
        n, off = _read_varint(data, off, end)
        if 8 * n > end - off:
            raise CodecError("float array past end of frame")
        return list(struct.unpack_from(f"<{n}d", data, off)), off + 8 * n
    if tag == _T_NONE:
        return None, off
    if tag == _T_TRUE:
        return True, off
    if tag == _T_FALSE:
        return False, off
    if tag == _T_BYTES:
        n, off = _read_varint(data, off, end)
        if n > end - off:
            raise CodecError("bytes length past end of frame")
        return bytes(data[off:off + n]), off + n
    if tag == _T_NDARR:
        n, off = _read_varint(data, off, end)
        if n > end - off or n > 16:
            raise CodecError("bad ndarray dtype")
        try:
            dt = np.dtype(data[off:off + n].decode("ascii"))
        except (UnicodeDecodeError, TypeError, ValueError) as e:
            raise CodecError(f"bad ndarray dtype: {e}") from e
        off += n
        ndim, off = _read_varint(data, off, end)
        if ndim > 32:
            raise CodecError("ndarray rank too large")
        shape = []
        count = 1
        for _ in range(ndim):
            d, off = _read_varint(data, off, end)
            shape.append(d)
            count *= d
        nbytes = count * dt.itemsize
        if nbytes > end - off:
            raise CodecError("ndarray data past end of frame")
        # bytes() copy: the result must not alias the (reused) recv buffer
        arr = np.frombuffer(bytes(data[off:off + nbytes]),
                            dtype=dt).reshape(shape)
        return arr, off + nbytes
    raise CodecError(f"unknown bin1 tag 0x{tag:02x}")


class BinCodec:
    name = "bin1"

    @staticmethod
    def encode(obj: Any) -> bytes:
        out = bytearray()
        _enc(out, obj, 0)
        return bytes(out)

    @staticmethod
    def decode(data: bytes) -> Any:
        data = bytes(data)
        obj, off = _dec(data, 0, len(data), 0)
        if off != len(data):
            raise CodecError(f"{len(data) - off} trailing bytes after frame")
        return obj


JSON_CODEC = JsonCodec()
BIN_CODEC = BinCodec()

# preference-ordered registry; "json" is the floor every peer speaks
CODECS: Dict[str, Any] = {"bin1": BIN_CODEC, "json": JSON_CODEC}
DEFAULT_PREFERENCE: Tuple[str, ...] = ("bin1", "json")


def get_codec(name: str):
    try:
        return CODECS[name]
    except KeyError:
        raise ValueError(f"unknown wire codec {name!r} "
                         f"(available: {sorted(CODECS)})") from None


def negotiate_codec(server: Sequence[str], client: Iterable[str]) -> str:
    """First client preference the server supports; ``"json"`` floor."""
    server_set = set(server) | {"json"}
    for name in client:
        if name in server_set and name in CODECS:
            return name
    return "json"
