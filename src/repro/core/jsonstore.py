"""Shared-JSON-on-a-directory, done once.

Four subsystems independently grew the same pattern — a small JSON
document on a shared filesystem that several processes read, merge, and
republish: FileBroker's per-queue visibility overrides (``.vt.json``),
its per-queue depth bounds (``.depth.json``), the shard discovery
announce file (``broker-serve --announce``), and now the DAG engine's
persisted node state and published-sample index.  Three of the four had
subtly different concurrency stories (unlocked merge-before-write,
fcntl-locked read-modify-write, ad-hoc lock sidecar), and one of those
differences was a real bug class: two unlocked mergers can drop each
other's writes, then a signature-triggered reload erases the loser's own
entry.

This module is the ONE implementation all of them share:

* :func:`save_json` — atomic publish (temp file + ``os.rename``), so a
  reader never observes a torn document.
* :func:`load_json` — tolerant read (missing / torn / mid-rename files
  return the default instead of raising).
* :func:`update_json` — fcntl-locked read-modify-write: takes an update
  function, applies it to the current document *under an exclusive lock
  on a sidecar ``<path>.lock``*, republished atomically.  Concurrent
  updaters serialize; none can drop another's merge.
* :class:`SharedJsonConfig` — the signature-cached reload idiom
  (``(mtime_ns, size)``) for hot paths that must notice other processes'
  updates without re-reading an unchanged file on every call.

Locking is advisory (fcntl) and scoped to hosts sharing the filesystem —
the same contract the broker directory itself relies on.  All helpers
swallow ``OSError`` into best-effort semantics *only* where the caller
asks for it (``strict=False``): shared config is advisory, but DAG state
is correctness-adjacent and uses ``strict=True``.
"""
from __future__ import annotations

import fcntl
import json
import os
import uuid
from typing import Any, Callable, Dict, Optional, Tuple


def save_json(path: str, doc: Dict[str, Any], *, strict: bool = False) -> bool:
    """Atomically publish ``doc`` at ``path`` (temp + rename).

    Returns True on success.  ``strict=True`` re-raises ``OSError``
    instead of degrading to a no-op.
    """
    d = os.path.dirname(os.path.abspath(path))
    tmp = os.path.join(d, f".tmp-json-{uuid.uuid4().hex}")
    try:
        os.makedirs(d, exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.rename(tmp, path)
        return True
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        if strict:
            raise
        return False


def load_json(path: str, default: Any = None) -> Any:
    """Read a JSON document; missing or torn files yield ``default``."""
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return default


def file_signature(path: str) -> Optional[Tuple[int, int]]:
    """The cheap change-detection key: ``(mtime_ns, size)`` or None."""
    try:
        st = os.stat(path)
    except OSError:
        return None
    return (st.st_mtime_ns, st.st_size)


def update_json(path: str, update: Callable[[Dict[str, Any]], Optional[Dict[str, Any]]],
                *, lock_path: Optional[str] = None,
                strict: bool = False) -> Optional[Dict[str, Any]]:
    """Locked read-modify-write: load the current doc, apply ``update``,
    republish atomically — all under an exclusive fcntl lock on
    ``lock_path`` (default ``<path>.lock``), so concurrent updaters from
    any process serialize instead of dropping each other's changes.

    ``update`` may mutate its argument in place (return None) or return a
    replacement dict.  Returns the published document, or None when the
    lock file could not be opened and ``strict`` is False (degraded:
    unlocked update — still atomic, merely unserialized).
    """
    lock_path = lock_path or (path + ".lock")
    lf = None
    try:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        lf = open(lock_path, "w")
        fcntl.flock(lf, fcntl.LOCK_EX)
    except OSError:
        if strict:
            if lf is not None:
                lf.close()
            raise
        lf = None  # degraded: proceed unlocked (atomic, unserialized)
    try:
        doc = load_json(path, default={})
        if not isinstance(doc, dict):
            doc = {}
        out = update(doc)
        doc = doc if out is None else out
        save_json(path, doc, strict=strict)
        return doc
    finally:
        if lf is not None:
            lf.close()  # releases the flock


class SharedJsonConfig:
    """A shared JSON config file with signature-cached reloads.

    The pattern behind ``.vt.json`` / ``.depth.json``: many instances on
    one directory each hold an in-memory view; writers publish through
    :meth:`update` (locked merge); readers call :meth:`load_if_changed`
    on their hot path and get the parsed doc only when the on-disk
    signature moved — an unchanged file costs one ``os.stat``.
    """

    def __init__(self, path: str):
        self.path = path
        self._sig: Optional[Tuple[int, int]] = None

    def load_if_changed(self) -> Optional[Dict[str, Any]]:
        """Parsed doc when the file changed since the last call, else None
        (also None for missing/torn files — nothing to apply)."""
        sig = file_signature(self.path)
        if sig is None or sig == self._sig:
            return None
        doc = load_json(self.path)
        if not isinstance(doc, dict):
            return None
        self._sig = sig
        return doc

    def update(self, fn: Callable[[Dict[str, Any]], Optional[Dict[str, Any]]]) -> Dict[str, Any]:
        """Locked read-modify-write via :func:`update_json`; refreshes the
        cached signature so the writer does not re-apply its own write."""
        doc = update_json(self.path, fn) or {}
        self._sig = file_signature(self.path)
        return doc

    def forget(self) -> None:
        """Drop the signature cache (force the next load to re-read)."""
        self._sig = None
