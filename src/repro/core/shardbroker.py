"""ShardedBroker: queue-name federation over N broker endpoints.

The paper's deployment (Sec. 2.2) funnels every allocation's producers and
surge consumers through ONE RabbitMQ host — exactly the single-server
bottleneck a :class:`~repro.core.netbroker.BrokerServer` becomes once
ensemble throughput outgrows one process.  :class:`ShardedBroker` is the
federation layer: it implements the full
:class:`~repro.core.queue.Broker` protocol over N independent endpoints
by routing **whole queues** to shards.

Routing model (why by queue, not by task):

* Every queue name maps to exactly one shard — ``crc32(queue) % n_shards``
  by default (stable across processes and Python runs, unlike ``hash()``),
  overridable per queue with an explicit ``queue_shards`` map for
  operators who want, say, the simulation queue pinned to the big box.
* Because a queue never spans shards, *all* per-queue semantics the rest
  of the system relies on survive federation unchanged: strict
  ``(priority, seq)`` order within a queue, visibility timeouts, weighted
  fairness inside a shard, lease/ack idempotency.  Global cross-queue
  priority becomes best-effort across shards (as with any federation) —
  exact within each shard.
* ``get_many(queues=...)`` fans out only to the shards that own those
  queues; a subscription that lives entirely on one shard degenerates to
  a single pass-through call (no fan-out tax for pinned workers).

Lease tags are wrapped as ``"<shard-idx>:<epoch>:<backend-tag>"`` so
``ack``, ``ack_many`` (grouped per shard: one call each), and ``nack``
route back to the owning shard without keeping client-side lease state —
a ShardedBroker is as stateless as a NetBroker, so any instance (any
process) can ack any other instance's tags.  The epoch fences failover:
when a shard's primary dies and a replica takes over, the epoch bumps
and tags minted against the old primary are rejected
(:class:`~repro.core.queue.StaleEpochError` for single ack/nack;
silently dropped and counted for ``ack_many``) instead of completing
work the new primary has already redelivered.

Introspection merges the shard views: ``qsize``/``inflight`` sum,
``queue_names`` unions, ``stats`` sums the counters, merges the
per-queue ``consumers`` heartbeat views, and keeps the per-shard
breakdown under ``"shards"``.  ``BrokerFull`` backpressure raised by one
shard propagates to the producer exactly like a local backend's.

Construction: pass broker instances, or URLs (resolved through
:func:`~repro.core.netbroker.make_broker`), or use the ``shard://`` URL
scheme — ``shard://host1:p1,host2:p2`` — or hand ``make_broker`` /
``MerlinRuntime(broker=...)`` a list of ``tcp://`` endpoints directly.
"""
from __future__ import annotations

import json
import os
import time
import zlib
from typing import (Any, Dict, Iterable, List, Optional, Sequence, Tuple,
                    Union)

import threading

from repro.core import jsonstore
from repro.core.queue import (Broker, BrokerUnavailable, Lease,
                              StaleEpochError, Task, _normalize_queues,
                              validate_queue_name)


def shard_index(queue: str, n_shards: int) -> int:
    """The stable default queue->shard hash (crc32, not Python hash())."""
    return zlib.crc32(queue.encode("utf-8")) % n_shards


# ---------------------------------------------------------------------------
# endpoint discovery file
# ---------------------------------------------------------------------------
# ``broker-serve --announce <path>`` publishes each server's bound endpoint
# into ONE shared JSON file; ``make_broker("shard+file://<path>")`` reads it
# and assembles the shard list — launchers stop hand-building URL lists and
# stop caring which server bound which ephemeral port.  Format:
#
#     {"endpoints": {"0": "tcp://h1:p1", "1": "tcp://h2:p2"}, "n": 2}
#
# Keys are shard indices (from ``--shard-of I/N``, which also sets "n", the
# expected federation size discovery waits for) or the URL itself for
# unindexed servers.  Writers merge through jsonstore.update_json (fcntl
# lock sidecar + atomic rename), so concurrent servers on a shared
# filesystem cannot tear or drop each other's entries.

def announce_endpoint(path: str, url: str, index: Optional[int] = None,
                      total: Optional[int] = None) -> None:
    """Merge ``url`` into the announce file at ``path`` (atomic, locked)."""
    def _apply(doc: Dict[str, Any]) -> None:
        eps = doc.setdefault("endpoints", {})
        eps[url if index is None else str(index)] = url
        if total is not None:
            doc["n"] = int(total)
    # strict: a server that cannot announce is invisible to discovery —
    # better to fail its startup loudly than hang join_shards at the client
    jsonstore.update_json(path, _apply, strict=True)


def read_endpoints(path: str) -> Tuple[List[str], Optional[int]]:
    """The announced (ordered) endpoint URLs plus the declared federation
    size, if any.  Indexed entries come first in shard-index order — the
    order MUST be stable across every reader, or the queue->shard hash
    disagrees between producers and consumers."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        return [], None
    eps = doc.get("endpoints", {})
    indexed = sorted((int(k), u) for k, u in eps.items()
                     if k.lstrip("-").isdigit())
    rest = sorted(u for k, u in eps.items() if not k.lstrip("-").isdigit())
    n = doc.get("n")
    return [u for _, u in indexed] + rest, None if n is None else int(n)


def _endpoint_alive(url: str, timeout: float = 1.0) -> bool:
    """Best-effort liveness probe: one raw TCP connect, no protocol, no
    retries (a refused port answers instantly — NetBroker.ping would burn
    its whole reconnect window on it).  Non-tcp URLs — mem://, file:// —
    have no server to probe and count as alive."""
    if not url.startswith("tcp://"):
        return True
    import socket

    from repro.core.netbroker import parse_address
    try:
        sock = socket.create_connection(parse_address(url), timeout=timeout)
    except OSError:
        return False
    try:
        sock.close()
    except OSError:
        pass
    return True


def discover_shards(path: str, expect: Optional[int] = None,
                    timeout: float = 10.0, poll: float = 0.05,
                    settle: float = 0.5,
                    **endpoint_kwargs) -> "ShardedBroker":
    """Build a ShardedBroker from an announce file, waiting (up to
    ``timeout``) until the declared federation size — ``expect`` or the
    file's own "n" — has announced.

    Candidate sets are liveness-probed (dead endpoints dropped) before
    acceptance, not on every poll: entries persist across federation
    restarts (nothing ever un-announces — an indexed restart replaces its
    slot, an unindexed one on a fresh ephemeral port cannot), so without
    the probe a reader racing a relaunch would assemble the PREVIOUS
    run's dead shard list — with a declared "n", a fully-stale file would
    even satisfy the count immediately.

    With NO declared size, membership is inherently ambiguous while
    servers are still announcing: a client reading between two
    announcements would build a smaller federation than one reading after
    — and the crc32(queue) % N routing would split brains.  Discovery
    therefore waits until the file has been *stable* for ``settle``
    seconds before accepting an undeclared set.  Declaring N via
    ``--shard-of`` / ``expect=`` is still the recommended mode: it pins
    membership and the shard ORDER every client must agree on."""
    deadline = time.monotonic() + timeout
    last_sig: Any = ()
    sig_since = time.monotonic()
    while True:
        try:
            st = os.stat(path)
            sig: Any = (st.st_mtime_ns, st.st_size)
        except OSError:
            sig = None
        now = time.monotonic()
        if sig != last_sig:
            last_sig, sig_since = sig, now
        urls, declared = read_endpoints(path)
        want = expect if expect is not None else declared
        settled = want is not None or now - sig_since >= settle
        if urls and settled and (want is None or len(urls) >= want):
            live = [u for u in urls if _endpoint_alive(u)]
            if live and (want is None or len(live) >= want):
                return ShardedBroker(live if want is None else live[:want],
                                     **endpoint_kwargs)
        if time.monotonic() >= deadline:
            raise BrokerUnavailable(
                f"announce file {path!r} published {len(urls)} endpoint(s) "
                f"(live subset insufficient) within {timeout}s "
                f"(wanted {want or 'at least 1, settled'})")
        time.sleep(poll)


class ShardedBroker:
    """Implements the Broker protocol over N shard endpoints.

    ``shards``: Broker instances or broker URLs (``tcp://...`` etc.).
    ``queue_shards``: explicit ``{queue: shard_index}`` overrides; every
    other queue routes by stable hash.
    ``poll_slice``: when a blocking ``get_many`` spans multiple shards,
    the wait rotates across them in slices of this many seconds (one
    shard parks server-side per slice; the others are polled
    non-blocking each rotation).
    """

    def __init__(self, shards: Sequence[Union[Broker, str, Sequence]],
                 queue_shards: Optional[Dict[str, int]] = None,
                 poll_slice: float = 0.05, **endpoint_kwargs):
        if not shards:
            raise ValueError("ShardedBroker needs at least one shard")
        self._endpoint_kwargs = dict(endpoint_kwargs)
        # each shard entry may name REPLICA candidates: a list of
        # brokers/URLs, or a "url1|url2" pipe-string.  The first candidate
        # is the initial primary; on primary death queue ownership fails
        # over to the next live candidate under a bumped per-shard epoch.
        self._candidates: List[List[Union[Broker, str]]] = []
        for s in shards:
            if isinstance(s, str) and "|" in s:
                cands: List[Union[Broker, str]] = \
                    [c for c in s.split("|") if c]
            elif isinstance(s, (list, tuple)):
                cands = list(s)
            else:
                cands = [s]
            if not cands:
                raise ValueError("shard entry names no endpoints")
            self._candidates.append(cands)
        resolved: List[Broker] = []
        for cands in self._candidates:
            primary = self._resolve(cands[0])
            if primary is None:
                raise BrokerUnavailable(
                    f"cannot construct primary endpoint {cands[0]!r}")
            cands[0] = primary  # resolve once; failover reuses the instance
            resolved.append(primary)
        self.shards: List[Broker] = resolved
        self._active_cand = [0] * len(resolved)
        self._epochs = [0] * len(resolved)
        self._fo_lock = threading.Lock()
        self._failovers = 0
        self._stale_acks_rejected = 0
        self.queue_shards = dict(queue_shards or {})
        for q, i in self.queue_shards.items():
            validate_queue_name(q)
            if not 0 <= int(i) < len(self.shards):
                raise ValueError(f"queue_shards[{q!r}] = {i} out of range "
                                 f"for {len(self.shards)} shards")
        self.poll_slice = poll_slice
        self._rr_offset = 0  # rotates blocking waits across shards

    def _resolve(self, cand: Union[Broker, str]) -> Optional[Broker]:
        if not isinstance(cand, str):
            return cand
        from repro.core.netbroker import make_broker
        try:
            return make_broker(cand, **self._endpoint_kwargs)
        except (ValueError, OSError, BrokerUnavailable):
            return None

    # -- failover ------------------------------------------------------------
    def _failover(self, idx: int, seen_epoch: int) -> bool:
        """Swap shard ``idx`` to its next live replica candidate and bump
        the shard epoch (fencing every lease tag minted before the swap).
        Returns True when the shard now points at a (possibly new) live
        endpoint; False when no candidate answered."""
        with self._fo_lock:
            if self._epochs[idx] != seen_epoch:
                return True  # a concurrent caller already failed over
            cands = self._candidates[idx]
            start = self._active_cand[idx]
            for off in range(1, len(cands) + 1):
                j = (start + off) % len(cands)
                cand = cands[j]
                if isinstance(cand, str) and not _endpoint_alive(cand):
                    continue
                broker = self._resolve(cand)
                if broker is None:
                    continue
                if isinstance(cand, str):
                    cands[j] = broker  # cache the client for future cycles
                old = self.shards[idx]
                self.shards[idx] = broker
                self._active_cand[idx] = j
                self._epochs[idx] += 1
                self._failovers += 1
                if old is not broker:
                    close = getattr(old, "close", None)
                    if close is not None:
                        try:
                            close()
                        except Exception:
                            pass
                return True
            return False

    def _call_shard(self, idx: int, fn):
        """Run ``fn(shard)`` with one failover-and-retry on endpoint death."""
        seen = self._epochs[idx]
        try:
            return fn(self.shards[idx])
        except BrokerUnavailable:
            if not self._failover(idx, seen):
                raise
        return fn(self.shards[idx])

    def shard_health(self) -> List[Dict[str, Any]]:
        """Per-shard view for merlin-status: active endpoint, epoch, and a
        liveness probe of every replica candidate."""
        out: List[Dict[str, Any]] = []
        for i, cands in enumerate(self._candidates):
            ents = []
            for j, c in enumerate(cands):
                url = c if isinstance(c, str) else \
                    getattr(c, "address", type(c).__name__)
                ents.append({"endpoint": url,
                             "alive": _endpoint_alive(url)
                             if isinstance(url, str) else True,
                             "active": j == self._active_cand[i]})
            active = self.shards[i]
            out.append({"shard": i, "epoch": self._epochs[i],
                        "endpoint": getattr(active, "address",
                                            type(active).__name__),
                        "candidates": ents})
        return out

    # -- routing -------------------------------------------------------------
    def shard_for(self, queue: str) -> int:
        """The shard index owning ``queue`` (override map, then hash)."""
        idx = self.queue_shards.get(queue)
        if idx is None:
            idx = shard_index(queue, len(self.shards))
        return int(idx)

    def _shard_selectors(self, queues: Optional[Tuple[str, ...]]
                         ) -> Dict[int, Optional[List[str]]]:
        """shard index -> the queue subset it owns (None = all queues)."""
        if queues is None:
            return {i: None for i in range(len(self.shards))}
        sel: Dict[int, List[str]] = {}
        for q in queues:
            sel.setdefault(self.shard_for(q), []).append(q)
        return sel

    def _wrap(self, idx: int, lease: Lease) -> Lease:
        # the shard epoch rides in the tag: after a failover bumps the
        # epoch, tags minted against the dead primary are FENCED — their
        # ack/nack raises StaleEpochError instead of silently completing
        # against a broker that no longer owns the queue
        return Lease(lease.task, f"{idx}:{self._epochs[idx]}:{lease.tag}")

    def _unwrap(self, tag: str) -> Tuple[int, int, str]:
        idx_s, _, rest = tag.partition(":")
        epoch_s, _, inner = rest.partition(":")
        try:
            idx = int(idx_s)
            epoch = int(epoch_s)
            if not 0 <= idx < len(self.shards):
                raise ValueError(tag)
        except ValueError:
            raise ValueError(f"not a sharded lease tag: {tag!r}") from None
        return idx, epoch, inner

    def _check_epoch(self, idx: int, epoch: int, tag: str) -> None:
        if epoch != self._epochs[idx]:
            with self._fo_lock:
                self._stale_acks_rejected += 1
            raise StaleEpochError(
                f"lease tag {tag!r} was minted under shard {idx} epoch "
                f"{epoch}; the shard is now at epoch {self._epochs[idx]} "
                f"(primary failed over) — the task redelivers on the new "
                f"primary")

    # -- producer side -------------------------------------------------------
    def put(self, task: Task) -> None:
        self._call_shard(self.shard_for(task.queue), lambda b: b.put(task))

    def put_many(self, tasks: List[Task]) -> None:
        by_shard: Dict[int, List[Task]] = {}
        for t in tasks:
            by_shard.setdefault(self.shard_for(t.queue), []).append(t)
        # sequential, one batched call per shard; a BrokerFull from one
        # shard propagates after earlier shards were fed — at-least-once
        # delivery makes retrying the whole batch safe
        for idx, ts in by_shard.items():
            self._call_shard(idx, lambda b, ts=ts: b.put_many(ts))

    # -- consumer side -------------------------------------------------------
    def get(self, timeout: Optional[float] = 0.0,
            queues: Optional[Sequence[str]] = None) -> Optional[Lease]:
        leases = self.get_many(1, timeout=timeout, queues=queues)
        return leases[0] if leases else None

    def get_many(self, n: int, timeout: Optional[float] = 0.0,
                 queues: Optional[Sequence[str]] = None) -> List[Lease]:
        """Claim up to ``n`` leases from the shards owning the subscription.

        Single-shard subscriptions pass straight through (the blocking
        wait parks on that shard, server-side for NetBroker shards).
        Multi-shard subscriptions poll every owning shard non-blocking,
        then rotate a ``poll_slice`` blocking wait across them until the
        deadline — so a task appearing on ANY owning shard is claimed
        within one rotation.
        """
        qsel = _normalize_queues(queues)
        sel = self._shard_selectors(qsel)
        if len(sel) == 1:
            idx, qs = next(iter(sel.items()))
            leases = self._call_shard(
                idx, lambda b: b.get_many(n, timeout=timeout, queues=qs))
            return [self._wrap(idx, l) for l in leases]
        deadline = None if timeout is None else time.monotonic() + timeout
        order = sorted(sel)
        out: List[Lease] = []
        while True:
            # fast pass: drain whatever is claimable right now, rotating
            # the start shard so one busy shard cannot monopolize batches
            self._rr_offset = (self._rr_offset + 1) % len(order)
            for k in range(len(order)):
                idx = order[(self._rr_offset + k) % len(order)]
                want = n - len(out)
                got = self._call_shard(
                    idx, lambda b, want=want, qs=sel[idx]:
                    b.get_many(want, timeout=0.0, queues=qs))
                out.extend(self._wrap(idx, l) for l in got)
                if len(out) >= n:
                    return out
            if out:
                return out
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return out
                slice_t = min(self.poll_slice, remaining)
            else:
                slice_t = self.poll_slice
            # blocking slice on one shard; next rotation polls the rest
            idx = order[self._rr_offset % len(order)]
            got = self._call_shard(
                idx, lambda b, qs=sel[idx]:
                b.get_many(n, timeout=slice_t, queues=qs))
            out.extend(self._wrap(idx, l) for l in got)
            if out:
                return out

    def ack(self, tag: str) -> None:
        idx, epoch, inner = self._unwrap(tag)
        self._check_epoch(idx, epoch, tag)
        self._call_shard(idx, lambda b: b.ack(inner))

    def ack_many(self, tags: Iterable[str]) -> None:
        """Batch ack with epoch fencing.  Unlike single ``ack``, stale tags
        are silently DROPPED (and counted in ``stale_acks_rejected``) —
        ack_many is the worker's retried-forever flush path, and a raise
        would wedge every fresh tag in the batch behind one zombie."""
        by_shard: Dict[int, List[str]] = {}
        stale = 0
        for tag in tags:
            idx, epoch, inner = self._unwrap(tag)
            if epoch != self._epochs[idx]:
                stale += 1
                continue
            by_shard.setdefault(idx, []).append(inner)
        if stale:
            with self._fo_lock:
                self._stale_acks_rejected += stale
        for idx, inner_tags in by_shard.items():
            self._call_shard(
                idx, lambda b, ts=inner_tags: b.ack_many(ts))

    def nack(self, tag: str) -> None:
        idx, epoch, inner = self._unwrap(tag)
        self._check_epoch(idx, epoch, tag)
        self._call_shard(idx, lambda b: b.nack(inner))

    # -- introspection (merged views) ----------------------------------------
    def qsize(self, queues: Optional[Sequence[str]] = None) -> int:
        qsel = _normalize_queues(queues)
        return sum(self._call_shard(idx, lambda b, qs=qs: b.qsize(qs))
                   for idx, qs in self._shard_selectors(qsel).items())

    def queue_names(self) -> List[str]:
        names = set()
        for idx in range(len(self.shards)):
            names.update(self._call_shard(idx, lambda b: b.queue_names()))
        return sorted(names)

    def inflight(self) -> int:
        return sum(self._call_shard(idx, lambda b: b.inflight())
                   for idx in range(len(self.shards)))

    def inflight_tasks(self) -> List[Tuple[Task, float]]:
        out: List[Tuple[Task, float]] = []
        for idx in range(len(self.shards)):
            out.extend(self._call_shard(idx, lambda b: b.inflight_tasks()))
        return out

    def idle(self) -> bool:
        return all(self._call_shard(idx, lambda b: b.idle())
                   for idx in range(len(self.shards)))

    def set_visibility_timeout(self, queue: str, timeout: float) -> None:
        self._call_shard(self.shard_for(queue),
                         lambda b: b.set_visibility_timeout(queue, timeout))

    def set_max_queue_depth(self, queue: str, depth: Optional[int]) -> None:
        """Per-queue backpressure bound, applied on the queue's owning
        shard (queues never span shards, so one shard is enough)."""
        self._call_shard(self.shard_for(queue),
                         lambda b: b.set_max_queue_depth(queue, depth))

    def heartbeat(self, consumer_id: str,
                  queues: Optional[Sequence[str]] = None) -> None:
        """Register with every shard the subscription touches (all shards
        for a None subscription), so each shard's ``stats["consumers"]``
        reflects the consumers that can actually drain it."""
        qsel = _normalize_queues(queues)
        for idx, qs in self._shard_selectors(qsel).items():
            self._call_shard(
                idx, lambda b, qs=qs: b.heartbeat(consumer_id, qs))

    @property
    def stats(self) -> Dict[str, Any]:
        """Counters summed across shards; per-queue ``consumers`` views
        merged (max per queue — the same consumer heartbeats every shard
        it subscribes on); dict-of-number counters (``acked_by_queue``)
        summed per key; raw per-shard dicts under ``"shards"``."""
        merged: Dict[str, Any] = {}
        consumers: Dict[str, int] = {}
        per_shard: List[Dict[str, Any]] = []
        for idx in range(len(self.shards)):
            st = dict(self._call_shard(idx, lambda b: b.stats))
            per_shard.append(st)
            for q, c in (st.get("consumers") or {}).items():
                consumers[q] = max(consumers.get(q, 0), int(c))
            for k, v in st.items():
                if k == "consumers":
                    continue
                if isinstance(v, (int, float)):
                    merged[k] = merged.get(k, 0) + v
                elif isinstance(v, dict):
                    # per-queue counter maps: each queue lives on exactly
                    # one shard, but sum anyway (robust to resharding)
                    sub = merged.setdefault(k, {})
                    for q, c in v.items():
                        if isinstance(c, (int, float)):
                            sub[q] = sub.get(q, 0) + c
        merged["consumers"] = consumers
        merged["shards"] = per_shard
        merged["epochs"] = list(self._epochs)
        merged["failovers"] = self._failovers
        merged["stale_acks_rejected"] = self._stale_acks_rejected
        return merged

    def close(self) -> None:
        seen = set()
        for s in list(self.shards) + [c for cands in self._candidates
                                      for c in cands
                                      if not isinstance(c, str)]:
            if id(s) in seen:
                continue
            seen.add(id(s))
            close = getattr(s, "close", None)
            if close is not None:
                close()

    def __enter__(self) -> "ShardedBroker":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
