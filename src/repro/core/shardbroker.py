"""ShardedBroker: queue-name federation over N broker endpoints.

The paper's deployment (Sec. 2.2) funnels every allocation's producers and
surge consumers through ONE RabbitMQ host — exactly the single-server
bottleneck a :class:`~repro.core.netbroker.BrokerServer` becomes once
ensemble throughput outgrows one process.  :class:`ShardedBroker` is the
federation layer: it implements the full
:class:`~repro.core.queue.Broker` protocol over N independent endpoints
by routing **whole queues** to shards.

Routing model (why by queue, not by task):

* Every queue name maps to exactly one shard — resolved on a
  deterministic consistent-hash ring (:mod:`repro.core.hashring`) over
  the member set, overridable per queue with an explicit
  ``queue_shards`` map (or membership ``pins``) for operators who want,
  say, the simulation queue pinned to the big box.  The ring — not
  ``crc32 % N`` — is what makes the federation *elastic*: a member
  joining or leaving moves only ~K/N queues instead of rehashing all of
  them.
* Because a queue never spans shards, *all* per-queue semantics the rest
  of the system relies on survive federation unchanged: strict
  ``(priority, seq)`` order within a queue, visibility timeouts, weighted
  fairness inside a shard, lease/ack idempotency.  Global cross-queue
  priority becomes best-effort across shards (as with any federation) —
  exact within each shard.
* ``get_many(queues=...)`` fans out only to the shards that own those
  queues; a subscription that lives entirely on one shard degenerates to
  a single pass-through call (no fan-out tax for pinned workers).

Lease tags are wrapped as ``"<member-slot>:<epoch>:<backend-tag>"`` so
``ack``, ``ack_many`` (grouped per shard: one call each), and ``nack``
route back to the owning shard without keeping client-side lease state —
a ShardedBroker is as stateless as a NetBroker, so any instance (any
process) can ack any other instance's tags.  For a static federation the
slot IS the shard index; under elastic membership slots are allocated
monotonically and never reused.  The epoch fences replica failover
(PR 7), and the slot generalizes the same fence to membership changes:
tags minted against a member that has since left the ring raise
:class:`~repro.core.queue.StaleEpochError` on ack/nack (silently dropped
and counted for ``ack_many``) instead of completing work another member
has already redelivered.

**Elastic membership**: :meth:`ShardedBroker.from_membership` builds a
client from the versioned membership registry a ``broker-serve --join``
federation maintains in its announce file.  The client re-reads the file
(signature-cached, throttled) and re-resolves routing whenever the
membership *version* bumps — joins/leaves/evictions/pins propagate to
every live client without restarts.  Live queue handoff between members
is the drain-and-forward protocol in :func:`migrate_queue_between`.

Introspection merges the shard views: ``qsize``/``inflight`` sum,
``queue_names`` unions, ``stats`` sums the counters, merges the
per-queue ``consumers`` heartbeat views, and keeps the per-shard
breakdown under ``"shards"``.  ``BrokerFull`` backpressure raised by one
shard propagates to the producer exactly like a local backend's.

Construction: pass broker instances, or URLs (resolved through
:func:`~repro.core.netbroker.make_broker`), or use the ``shard://`` URL
scheme — ``shard://host1:p1,host2:p2`` — or hand ``make_broker`` /
``MerlinRuntime(broker=...)`` a list of ``tcp://`` endpoints directly.
``ring+file://<path>`` builds the elastic (membership-following) client.
"""
from __future__ import annotations

import json
import os
import time
from typing import (Any, Dict, Iterable, List, Optional, Sequence, Tuple,
                    Union)

import threading

from repro.core import jsonstore
from repro.core.hashring import (DEFAULT_VNODES, HashRing, Membership,
                                 join_membership, leave_membership,
                                 read_membership)
from repro.core.queue import (Broker, BrokerUnavailable, Lease,
                              StaleEpochError, Task, _normalize_queues,
                              validate_queue_name)

_DEFAULT_RINGS: Dict[int, HashRing] = {}


def _static_keys(n: int) -> List[str]:
    return [f"shard-{i}" for i in range(n)]


def shard_index(queue: str, n_shards: int) -> int:
    """The stable default queue->shard mapping for a *static* federation
    of ``n_shards`` positional members: owner position on the default
    consistent-hash ring (deterministic across processes and runs, unlike
    Python ``hash()``)."""
    ring = _DEFAULT_RINGS.get(n_shards)
    if ring is None:
        ring = _DEFAULT_RINGS.setdefault(n_shards,
                                         HashRing(_static_keys(n_shards)))
    return int(ring.owner(queue)[len("shard-"):])


# ---------------------------------------------------------------------------
# endpoint discovery file
# ---------------------------------------------------------------------------
# ``broker-serve --announce <path>`` publishes each server's bound endpoint
# into ONE shared JSON file; ``make_broker("shard+file://<path>")`` reads it
# and assembles the shard list — launchers stop hand-building URL lists and
# stop caring which server bound which ephemeral port.  Format:
#
#     {"endpoints": {"0": "tcp://h1:p1", "1": "tcp://h2:p2"}, "n": 2}
#
# Keys are shard indices (from ``--shard-of I/N``, which also sets "n", the
# expected federation size discovery waits for) or the URL itself for
# unindexed servers.  Writers merge through jsonstore.update_json (fcntl
# lock sidecar + atomic rename), so concurrent servers on a shared
# filesystem cannot tear or drop each other's entries.
#
# ``broker-serve --join <path>`` upgrades the same file into the live
# membership registry (see repro.core.hashring): a versioned member set
# with heartbeats, TTL eviction, and per-queue pins, with the legacy
# ``endpoints``/``n`` keys kept mirrored for old readers.

def announce_endpoint(path: str, url: str, index: Optional[int] = None,
                      total: Optional[int] = None) -> None:
    """Merge ``url`` into the announce file at ``path`` (atomic, locked)."""
    def _apply(doc: Dict[str, Any]) -> None:
        eps = doc.setdefault("endpoints", {})
        eps[url if index is None else str(index)] = url
        if total is not None:
            doc["n"] = int(total)
    # strict: a server that cannot announce is invisible to discovery —
    # better to fail its startup loudly than hang join_shards at the client
    jsonstore.update_json(path, _apply, strict=True)


def read_endpoints(path: str) -> Tuple[List[str], Optional[int]]:
    """The announced (ordered) endpoint URLs plus the declared federation
    size, if any.  Indexed entries come first in shard-index order — the
    order MUST be stable across every reader, or the queue->shard hash
    disagrees between producers and consumers."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        return [], None
    eps = doc.get("endpoints", {})
    indexed = sorted((int(k), u) for k, u in eps.items()
                     if k.lstrip("-").isdigit())
    rest = sorted(u for k, u in eps.items() if not k.lstrip("-").isdigit())
    n = doc.get("n")
    return [u for _, u in indexed] + rest, None if n is None else int(n)


def _endpoint_alive(url: str, timeout: float = 1.0) -> bool:
    """Best-effort liveness probe: one raw TCP connect, no protocol, no
    retries (a refused port answers instantly — NetBroker.ping would burn
    its whole reconnect window on it).  Non-tcp URLs — mem://, file:// —
    have no server to probe and count as alive."""
    if not url.startswith("tcp://"):
        return True
    import socket

    from repro.core.netbroker import parse_address
    try:
        sock = socket.create_connection(parse_address(url), timeout=timeout)
    except OSError:
        return False
    try:
        sock.close()
    except OSError:
        pass
    return True


def discover_shards(path: str, expect: Optional[int] = None,
                    timeout: float = 10.0, poll: float = 0.05,
                    settle: float = 0.5,
                    **endpoint_kwargs) -> "ShardedBroker":
    """Build a ShardedBroker from an announce file, waiting (up to
    ``timeout``) until the declared federation size — ``expect`` or the
    file's own "n" — has announced.

    Candidate sets are liveness-probed (dead endpoints dropped) before
    acceptance, not on every poll: entries persist across federation
    restarts (nothing ever un-announces — an indexed restart replaces its
    slot, an unindexed one on a fresh ephemeral port cannot), so without
    the probe a reader racing a relaunch would assemble the PREVIOUS
    run's dead shard list — with a declared "n", a fully-stale file would
    even satisfy the count immediately.

    With NO declared size, membership is inherently ambiguous while
    servers are still announcing: a client reading between two
    announcements would build a smaller federation than one reading after
    — and the queue->shard routing would split brains.  Discovery
    therefore waits until the file has been *stable* for ``settle``
    seconds before accepting an undeclared set.  Declaring N via
    ``--shard-of`` / ``expect=`` is still the recommended mode: it pins
    membership and the shard ORDER every client must agree on."""
    deadline = time.monotonic() + timeout
    last_sig: Any = ()
    sig_since = time.monotonic()
    while True:
        try:
            st = os.stat(path)
            sig: Any = (st.st_mtime_ns, st.st_size)
        except OSError:
            sig = None
        now = time.monotonic()
        if sig != last_sig:
            last_sig, sig_since = sig, now
        urls, declared = read_endpoints(path)
        want = expect if expect is not None else declared
        settled = want is not None or now - sig_since >= settle
        if urls and settled and (want is None or len(urls) >= want):
            live = [u for u in urls if _endpoint_alive(u)]
            if live and (want is None or len(live) >= want):
                return ShardedBroker(live if want is None else live[:want],
                                     **endpoint_kwargs)
        if time.monotonic() >= deadline:
            raise BrokerUnavailable(
                f"announce file {path!r} published {len(urls)} endpoint(s) "
                f"(live subset insufficient) within {timeout}s "
                f"(wanted {want or 'at least 1, settled'})")
        time.sleep(poll)


class ShardedBroker:
    """Implements the Broker protocol over N shard endpoints.

    ``shards``: Broker instances or broker URLs (``tcp://...`` etc.).
    ``queue_shards``: explicit ``{queue: shard_index}`` overrides; every
    other queue routes on the consistent-hash ring.
    ``poll_slice``: when a blocking ``get_many`` spans multiple shards,
    the wait rotates across them in slices of this many seconds (one
    shard parks server-side per slice; the others are polled
    non-blocking each rotation).
    ``ring_vnodes``: virtual nodes per member on the routing ring.
    """

    def __init__(self, shards: Sequence[Union[Broker, str, Sequence]],
                 queue_shards: Optional[Dict[str, int]] = None,
                 poll_slice: float = 0.05,
                 ring_vnodes: int = DEFAULT_VNODES, **endpoint_kwargs):
        if not shards:
            raise ValueError("ShardedBroker needs at least one shard")
        self._endpoint_kwargs = dict(endpoint_kwargs)
        # each shard entry may name REPLICA candidates: a list of
        # brokers/URLs, or a "url1|url2" pipe-string.  The first candidate
        # is the initial primary; on primary death queue ownership fails
        # over to the next live candidate under a bumped per-shard epoch.
        self._candidates: List[List[Union[Broker, str]]] = []
        for s in shards:
            if isinstance(s, str) and "|" in s:
                cands: List[Union[Broker, str]] = \
                    [c for c in s.split("|") if c]
            elif isinstance(s, (list, tuple)):
                cands = list(s)
            else:
                cands = [s]
            if not cands:
                raise ValueError("shard entry names no endpoints")
            self._candidates.append(cands)
        resolved: List[Broker] = []
        for cands in self._candidates:
            primary = self._resolve(cands[0])
            if primary is None:
                raise BrokerUnavailable(
                    f"cannot construct primary endpoint {cands[0]!r}")
            cands[0] = primary  # resolve once; failover reuses the instance
            resolved.append(primary)
        self.shards: List[Broker] = resolved
        self._active_cand = [0] * len(resolved)
        self._epochs = [0] * len(resolved)
        self._fo_lock = threading.Lock()
        self._failovers = 0
        self._stale_acks_rejected = 0
        self.queue_shards = dict(queue_shards or {})
        for q, i in self.queue_shards.items():
            validate_queue_name(q)
            if not 0 <= int(i) < len(self.shards):
                raise ValueError(f"queue_shards[{q!r}] = {i} out of range "
                                 f"for {len(self.shards)} shards")
        self.poll_slice = poll_slice
        self._rr_offset = 0  # rotates blocking waits across shards
        # -- ring routing state.  Static construction: ring keys are the
        # positional "shard-i" names and slot == index, which makes the
        # lease-tag format identical to the pre-elastic one.
        self._ring_vnodes = int(ring_vnodes)
        self._ring_keys: List[str] = _static_keys(len(resolved))
        self._slots: List[int] = list(range(len(resolved)))
        self._slot2idx: Dict[int, int] = {i: i for i in
                                          range(len(resolved))}
        self._retired_slots: Dict[int, str] = {}  # slot -> former member
        self._next_slot = len(resolved)  # membership slot watermark
        self._ring = HashRing(self._ring_keys, vnodes=self._ring_vnodes)
        self._key2idx: Dict[str, int] = {k: i for i, k in
                                         enumerate(self._ring_keys)}
        self._pins: Dict[str, str] = {}  # queue -> member key (elastic)
        self._ring_version = 0
        # elastic membership-following state (None = static federation)
        self._members_conf: Optional[jsonstore.SharedJsonConfig] = None
        self._refresh_interval = 0.25
        self._last_refresh = 0.0

    # -- elastic construction ------------------------------------------------
    @classmethod
    def from_membership(cls, path: str, *,
                        refresh_interval: float = 0.25,
                        ring_vnodes: int = DEFAULT_VNODES,
                        poll_slice: float = 0.05,
                        **endpoint_kwargs) -> "ShardedBroker":
        """Build an elastic client that follows the membership registry at
        ``path``: routing re-resolves whenever the membership version
        bumps (join/leave/eviction/pin), moving only the affected ~K/N
        queues.  Lease tags carry the member *slot*, so a membership
        change fences tags minted against departed members exactly like a
        replica failover fences a dead primary's."""
        m = read_membership(path)
        if m is None or not m.members:
            raise BrokerUnavailable(
                f"membership file {path!r} names no members")
        sb = cls(m.urls(), poll_slice=poll_slice, ring_vnodes=ring_vnodes,
                 **endpoint_kwargs)
        sb._members_conf = jsonstore.SharedJsonConfig(path)
        # prime the signature cache; a write that landed between
        # read_membership and here surfaces in the primed doc
        doc = sb._members_conf.load_if_changed()
        if isinstance(doc, dict) and "membership" in doc:
            m2 = Membership.from_doc(doc["membership"])
            if m2.members:
                m = m2
        sb._refresh_interval = float(refresh_interval)
        with sb._fo_lock:
            sb._install_membership_locked(m)
            # the pre-install static placeholder slots never minted a
            # lease, so retiring them is construction residue, not
            # fencing state (the next_slot watermark still fences any
            # historic membership slot)
            sb._retired_slots.clear()
        return sb

    def _maybe_refresh(self) -> None:
        """Elastic mode: re-read the membership file (throttled, and only
        when its on-disk signature moved) and re-resolve routing on a
        version bump.  Static federations no-op."""
        conf = self._members_conf
        if conf is None:
            return
        now = time.monotonic()
        if now - self._last_refresh < self._refresh_interval:
            return
        self._last_refresh = now
        doc = conf.load_if_changed()
        if doc is None:
            return
        m = Membership.from_doc(doc.get("membership", {})) \
            if isinstance(doc, dict) and "membership" in doc else None
        if m is None or m.version == self._ring_version or not m.members:
            return
        with self._fo_lock:
            if m.version != self._ring_version:
                self._install_membership_locked(m)

    def _install_membership_locked(self, m: Membership) -> None:
        """Swap routing to membership ``m``.  Members carry over their
        broker client, candidates, and failover epoch; departed members'
        slots are retired (their outstanding lease tags fence); new
        members get freshly resolved clients.  The positional lists are
        REPLACED wholesale (not mutated), so an operation that captured
        an index against the old arrays stays internally consistent."""
        old_idx = {k: i for i, k in enumerate(self._ring_keys)}
        urls = m.urls()
        shards: List[Broker] = []
        cands: List[List[Union[Broker, str]]] = []
        active: List[int] = []
        epochs: List[int] = []
        slots: List[int] = []
        keys: List[str] = []
        for url in urls:
            slot = m.slot_of(url)
            i = old_idx.get(url)
            if i is not None and self._slots[i] == slot:
                shards.append(self.shards[i])
                cands.append(self._candidates[i])
                active.append(self._active_cand[i])
                epochs.append(self._epochs[i])
            else:
                b = self._resolve(url)
                if b is None:
                    continue  # unresolvable member: route around it
                shards.append(b)
                cands.append([b])
                active.append(0)
                epochs.append(0)
            slots.append(slot)
            keys.append(url)
        if not shards:
            return  # never swap to an empty federation
        kept = set(keys)
        for i, k in enumerate(self._ring_keys):
            if k not in kept or self._slots[i] not in slots:
                self._retired_slots[self._slots[i]] = k
                if k not in kept:
                    old = self.shards[i]
                    if all(old is not s for s in shards):
                        close = getattr(old, "close", None)
                        if close is not None:
                            try:
                                close()
                            except Exception:
                                pass
        self.shards = shards
        self._candidates = cands
        self._active_cand = active
        self._epochs = epochs
        self._slots = slots
        self._ring_keys = keys
        self._slot2idx = {s: i for i, s in enumerate(slots)}
        self._ring = HashRing(keys, vnodes=self._ring_vnodes)
        self._key2idx = {k: i for i, k in enumerate(keys)}
        self._pins = {q: u for q, u in m.pins.items() if u in self._key2idx}
        self._ring_version = m.version
        self._next_slot = max(self._next_slot, m.next_slot,
                              max(slots) + 1)
        # index pins from the static constructor may now be out of range
        self.queue_shards = {q: i for q, i in self.queue_shards.items()
                             if 0 <= int(i) < len(shards)}

    def _resolve(self, cand: Union[Broker, str]) -> Optional[Broker]:
        if not isinstance(cand, str):
            return cand
        from repro.core.netbroker import make_broker
        try:
            return make_broker(cand, **self._endpoint_kwargs)
        except (ValueError, OSError, BrokerUnavailable):
            return None

    # -- failover ------------------------------------------------------------
    def _failover(self, idx: int, seen_epoch: int) -> bool:
        """Swap shard ``idx`` to its next live replica candidate and bump
        the shard epoch (fencing every lease tag minted before the swap).
        Returns True when the shard now points at a (possibly new) live
        endpoint; False when no candidate answered."""
        with self._fo_lock:
            if idx >= len(self.shards):
                return False
            if self._epochs[idx] != seen_epoch:
                return True  # a concurrent caller already failed over
            cands = self._candidates[idx]
            start = self._active_cand[idx]
            for off in range(1, len(cands) + 1):
                j = (start + off) % len(cands)
                cand = cands[j]
                if isinstance(cand, str) and not _endpoint_alive(cand):
                    continue
                broker = self._resolve(cand)
                if broker is None:
                    continue
                if isinstance(cand, str):
                    cands[j] = broker  # cache the client for future cycles
                old = self.shards[idx]
                self.shards[idx] = broker
                self._active_cand[idx] = j
                self._epochs[idx] += 1
                self._failovers += 1
                if old is not broker:
                    close = getattr(old, "close", None)
                    if close is not None:
                        try:
                            close()
                        except Exception:
                            pass
                return True
            return False

    def _call_shard(self, idx: int, fn):
        """Run ``fn(shard)`` with one failover-and-retry on endpoint death."""
        if idx >= len(self.shards):
            raise BrokerUnavailable(
                f"shard index {idx} no longer exists "
                f"({len(self.shards)} members)")
        seen = self._epochs[idx]
        try:
            return fn(self.shards[idx])
        except BrokerUnavailable:
            if not self._failover(idx, seen):
                raise
        return fn(self.shards[idx])

    def shard_health(self) -> List[Dict[str, Any]]:
        """Per-shard view for merlin-status: active endpoint, epoch, and a
        liveness probe of every replica candidate."""
        out: List[Dict[str, Any]] = []
        for i, cands in enumerate(self._candidates):
            ents = []
            for j, c in enumerate(cands):
                url = c if isinstance(c, str) else \
                    getattr(c, "address", type(c).__name__)
                ents.append({"endpoint": url,
                             "alive": _endpoint_alive(url)
                             if isinstance(url, str) else True,
                             "active": j == self._active_cand[i]})
            active = self.shards[i]
            out.append({"shard": i, "slot": self._slots[i],
                        "member": self._ring_keys[i],
                        "epoch": self._epochs[i],
                        "endpoint": getattr(active, "address",
                                            type(active).__name__),
                        "candidates": ents})
        return out

    def ring_info(self) -> Dict[str, Any]:
        """The merlin-status --ring view: membership version, per-member
        owned-queue counts, in-flight migrations, candidate health."""
        self._maybe_refresh()
        try:
            queues = self.queue_names()
        except BrokerUnavailable:
            queues = []
        owned: Dict[int, List[str]] = {}
        for q in queues:
            owned.setdefault(self.shard_for(q), []).append(q)
        health = self.shard_health()
        members: List[Dict[str, Any]] = []
        for i in range(len(self.shards)):
            migrating: List[str] = []
            try:
                st = self._call_shard(i, lambda b: b.stats)
                migrating = list(st.get("migrating", []))
            except BrokerUnavailable:
                pass
            members.append({**health[i],
                            "queues_owned": len(owned.get(i, [])),
                            "queues": sorted(owned.get(i, [])),
                            "migrating": migrating})
        return {"version": self._ring_version,
                "vnodes": self._ring_vnodes,
                "elastic": self._members_conf is not None,
                "members": members,
                "pins": dict(self._pins),
                "queue_pins": dict(self.queue_shards),
                "retired_slots": dict(self._retired_slots)}

    # -- routing -------------------------------------------------------------
    def shard_for(self, queue: str) -> int:
        """The shard index owning ``queue`` (index override map, then
        membership pins, then the consistent-hash ring)."""
        self._maybe_refresh()
        idx = self.queue_shards.get(queue)
        if idx is not None:
            return int(idx)
        pin = self._pins.get(queue)
        if pin is not None:
            hit = self._key2idx.get(pin)
            if hit is not None:
                return hit
        return self._key2idx[self._ring.owner(queue)]

    def _shard_selectors(self, queues: Optional[Tuple[str, ...]]
                         ) -> Dict[int, Optional[List[str]]]:
        """shard index -> the queue subset it owns (None = all queues)."""
        if queues is None:
            return {i: None for i in range(len(self.shards))}
        sel: Dict[int, List[str]] = {}
        for q in queues:
            sel.setdefault(self.shard_for(q), []).append(q)
        return sel

    def _wrap(self, idx: int, lease: Lease) -> Lease:
        # the member slot + shard epoch ride in the tag: after a failover
        # (epoch bump) or a membership change (slot retired), tags minted
        # against the previous owner are FENCED — their ack/nack raises
        # StaleEpochError instead of silently completing against a broker
        # that no longer owns the queue
        return Lease(lease.task,
                     f"{self._slots[idx]}:{self._epochs[idx]}:{lease.tag}")

    def _unwrap(self, tag: str) -> Tuple[int, int, str]:
        slot_s, _, rest = tag.partition(":")
        epoch_s, _, inner = rest.partition(":")
        try:
            slot = int(slot_s)
            epoch = int(epoch_s)
        except ValueError:
            raise ValueError(f"not a sharded lease tag: {tag!r}") from None
        return slot, epoch, inner

    def _idx_for_slot(self, slot: int, tag: str) -> Optional[int]:
        """Map a tag's member slot to the current shard index.  None =
        the slot was retired by a membership change (the caller fences);
        a slot this federation never allocated is a malformed tag.
        Slots below the membership's monotonic watermark fence even when
        this instance never saw them active — a rebuilt client must
        fence a historic tag, not reject it as malformed."""
        idx = self._slot2idx.get(slot)
        if idx is not None:
            return idx
        if slot in self._retired_slots or 0 <= slot < self._next_slot:
            return None
        raise ValueError(f"not a sharded lease tag: {tag!r}")

    def _fence(self, tag: str, why: str) -> None:
        with self._fo_lock:
            self._stale_acks_rejected += 1
        raise StaleEpochError(
            f"lease tag {tag!r} {why} — the task redelivers on the "
            f"current owner")

    def _check_epoch(self, idx: int, epoch: int, tag: str) -> None:
        if epoch != self._epochs[idx]:
            self._fence(tag, f"was minted under epoch {epoch}; the shard "
                             f"is now at epoch {self._epochs[idx]} "
                             f"(primary failed over)")

    # -- producer side -------------------------------------------------------
    def put(self, task: Task) -> None:
        self._call_shard(self.shard_for(task.queue), lambda b: b.put(task))

    def put_many(self, tasks: List[Task]) -> None:
        by_shard: Dict[int, List[Task]] = {}
        for t in tasks:
            by_shard.setdefault(self.shard_for(t.queue), []).append(t)
        # sequential, one batched call per shard; a BrokerFull from one
        # shard propagates after earlier shards were fed — at-least-once
        # delivery makes retrying the whole batch safe
        for idx, ts in by_shard.items():
            self._call_shard(idx, lambda b, ts=ts: b.put_many(ts))

    # -- consumer side -------------------------------------------------------
    def get(self, timeout: Optional[float] = 0.0,
            queues: Optional[Sequence[str]] = None) -> Optional[Lease]:
        leases = self.get_many(1, timeout=timeout, queues=queues)
        return leases[0] if leases else None

    def get_many(self, n: int, timeout: Optional[float] = 0.0,
                 queues: Optional[Sequence[str]] = None) -> List[Lease]:
        """Claim up to ``n`` leases from the shards owning the subscription.

        Single-shard subscriptions on a *static* federation pass straight
        through (the blocking wait parks on that shard, server-side for
        NetBroker shards).  Multi-shard subscriptions — and every elastic
        subscription — poll the owning shards non-blocking, then rotate a
        ``poll_slice`` blocking wait across them until the deadline; the
        elastic loop re-resolves membership between rotations, so a queue
        that migrates mid-wait is claimed from its NEW owner within one
        rotation instead of parking on the old one until timeout.
        """
        qsel = _normalize_queues(queues)
        self._maybe_refresh()
        elastic = self._members_conf is not None
        sel = self._shard_selectors(qsel)
        if len(sel) == 1 and not elastic:
            idx, qs = next(iter(sel.items()))
            leases = self._call_shard(
                idx, lambda b: b.get_many(n, timeout=timeout, queues=qs))
            return [self._wrap(idx, l) for l in leases]
        deadline = None if timeout is None else time.monotonic() + timeout
        out: List[Lease] = []
        while True:
            # fast pass: drain whatever is claimable right now, rotating
            # the start shard so one busy shard cannot monopolize batches
            order = sorted(sel)
            self._rr_offset = (self._rr_offset + 1) % max(len(order), 1)
            for k in range(len(order)):
                idx = order[(self._rr_offset + k) % len(order)]
                want = n - len(out)
                got = self._call_shard(
                    idx, lambda b, want=want, qs=sel[idx]:
                    b.get_many(want, timeout=0.0, queues=qs))
                out.extend(self._wrap(idx, l) for l in got)
                if len(out) >= n:
                    return out
            if out:
                return out
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return out
                slice_t = min(self.poll_slice, remaining)
            else:
                slice_t = self.poll_slice
            # blocking slice on one shard; next rotation polls the rest
            idx = order[self._rr_offset % len(order)]
            got = self._call_shard(
                idx, lambda b, qs=sel[idx]:
                b.get_many(n, timeout=slice_t, queues=qs))
            out.extend(self._wrap(idx, l) for l in got)
            if out:
                return out
            if elastic:
                self._maybe_refresh()
                sel = self._shard_selectors(qsel)

    def ack(self, tag: str) -> None:
        slot, epoch, inner = self._unwrap(tag)
        idx = self._idx_for_slot(slot, tag)
        if idx is None:
            self._fence(tag, f"was minted against member slot {slot}, "
                             f"which has left the ring")
        self._check_epoch(idx, epoch, tag)
        self._call_shard(idx, lambda b: b.ack(inner))

    def ack_many(self, tags: Iterable[str]) -> None:
        """Batch ack with slot + epoch fencing.  Unlike single ``ack``,
        stale tags are silently DROPPED (and counted in
        ``stale_acks_rejected``) — ack_many is the worker's
        retried-forever flush path, and a raise would wedge every fresh
        tag in the batch behind one zombie."""
        by_shard: Dict[int, List[str]] = {}
        stale = 0
        for tag in tags:
            slot, epoch, inner = self._unwrap(tag)
            idx = self._idx_for_slot(slot, tag)
            if idx is None or epoch != self._epochs[idx]:
                stale += 1
                continue
            by_shard.setdefault(idx, []).append(inner)
        if stale:
            with self._fo_lock:
                self._stale_acks_rejected += stale
        for idx, inner_tags in by_shard.items():
            self._call_shard(
                idx, lambda b, ts=inner_tags: b.ack_many(ts))

    def nack(self, tag: str) -> None:
        slot, epoch, inner = self._unwrap(tag)
        idx = self._idx_for_slot(slot, tag)
        if idx is None:
            self._fence(tag, f"was minted against member slot {slot}, "
                             f"which has left the ring")
        self._check_epoch(idx, epoch, tag)
        self._call_shard(idx, lambda b: b.nack(inner))

    # -- migration (drain-and-forward protocol ops) --------------------------
    def migrate_queue(self, queue: str, target: Optional[str]) -> None:
        """Mark/clear ``queue`` migrating on its owning shard (see
        :func:`migrate_queue_between` for the full handoff)."""
        self._call_shard(self.shard_for(queue),
                         lambda b: b.migrate_queue(queue, target))

    def export_queue(self, queue: str, max_n: int = 256) -> List[Dict]:
        return self._call_shard(
            self.shard_for(queue), lambda b: b.export_queue(queue, max_n))

    def import_tasks(self, tasks: List[Dict]) -> None:
        by_shard: Dict[int, List[Dict]] = {}
        for t in tasks:
            by_shard.setdefault(self.shard_for(t["queue"]), []).append(t)
        for idx, ts in by_shard.items():
            self._call_shard(idx, lambda b, ts=ts: b.import_tasks(ts))

    # -- introspection (merged views) ----------------------------------------
    def qsize(self, queues: Optional[Sequence[str]] = None) -> int:
        qsel = _normalize_queues(queues)
        self._maybe_refresh()
        return sum(self._call_shard(idx, lambda b, qs=qs: b.qsize(qs))
                   for idx, qs in self._shard_selectors(qsel).items())

    def queue_names(self) -> List[str]:
        self._maybe_refresh()
        names = set()
        for idx in range(len(self.shards)):
            names.update(self._call_shard(idx, lambda b: b.queue_names()))
        return sorted(names)

    def inflight(self) -> int:
        return sum(self._call_shard(idx, lambda b: b.inflight())
                   for idx in range(len(self.shards)))

    def inflight_tasks(self) -> List[Tuple[Task, float]]:
        out: List[Tuple[Task, float]] = []
        for idx in range(len(self.shards)):
            out.extend(self._call_shard(idx, lambda b: b.inflight_tasks()))
        return out

    def idle(self) -> bool:
        self._maybe_refresh()
        return all(self._call_shard(idx, lambda b: b.idle())
                   for idx in range(len(self.shards)))

    def set_visibility_timeout(self, queue: str, timeout: float) -> None:
        self._call_shard(self.shard_for(queue),
                         lambda b: b.set_visibility_timeout(queue, timeout))

    def set_max_queue_depth(self, queue: str, depth: Optional[int]) -> None:
        """Per-queue backpressure bound, applied on the queue's owning
        shard (queues never span shards, so one shard is enough)."""
        self._call_shard(self.shard_for(queue),
                         lambda b: b.set_max_queue_depth(queue, depth))

    def heartbeat(self, consumer_id: str,
                  queues: Optional[Sequence[str]] = None) -> None:
        """Register with every shard the subscription touches (all shards
        for a None subscription), so each shard's ``stats["consumers"]``
        reflects the consumers that can actually drain it."""
        qsel = _normalize_queues(queues)
        self._maybe_refresh()
        for idx, qs in self._shard_selectors(qsel).items():
            self._call_shard(
                idx, lambda b, qs=qs: b.heartbeat(consumer_id, qs))

    @property
    def stats(self) -> Dict[str, Any]:
        """Counters summed across shards; per-queue ``consumers`` views
        merged (max per queue — the same consumer heartbeats every shard
        it subscribes on); dict-of-number counters (``acked_by_queue``)
        summed per key; raw per-shard dicts under ``"shards"``."""
        merged: Dict[str, Any] = {}
        consumers: Dict[str, int] = {}
        per_shard: List[Dict[str, Any]] = []
        for idx in range(len(self.shards)):
            st = dict(self._call_shard(idx, lambda b: b.stats))
            per_shard.append(st)
            for q, c in (st.get("consumers") or {}).items():
                consumers[q] = max(consumers.get(q, 0), int(c))
            for k, v in st.items():
                if k == "consumers":
                    continue
                if isinstance(v, (int, float)):
                    merged[k] = merged.get(k, 0) + v
                elif isinstance(v, dict):
                    # per-queue counter maps: each queue lives on exactly
                    # one shard, but sum anyway (robust to resharding)
                    sub = merged.setdefault(k, {})
                    for q, c in v.items():
                        if isinstance(c, (int, float)):
                            sub[q] = sub.get(q, 0) + c
        merged["consumers"] = consumers
        merged["shards"] = per_shard
        merged["epochs"] = list(self._epochs)
        merged["failovers"] = self._failovers
        merged["stale_acks_rejected"] = self._stale_acks_rejected
        merged["ring_version"] = self._ring_version
        return merged

    def close(self) -> None:
        seen = set()
        for s in list(self.shards) + [c for cands in self._candidates
                                      for c in cands
                                      if not isinstance(c, str)]:
            if id(s) in seen:
                continue
            seen.add(id(s))
            close = getattr(s, "close", None)
            if close is not None:
                close()

    def __enter__(self) -> "ShardedBroker":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# live queue migration (drain-and-forward) + federation join/leave
# ---------------------------------------------------------------------------

def _queue_inflight(broker: Broker, queue: str) -> int:
    try:
        return sum(1 for t, _ in broker.inflight_tasks()
                   if t.queue == queue)
    except BrokerUnavailable:
        return 0


def migrate_queue_between(src: Broker, dst: Broker, queue: str,
                          dst_url: Optional[str] = None, *,
                          batch: int = 256, drain_timeout: float = 30.0,
                          poll: float = 0.05) -> Dict[str, Any]:
    """Drain-and-forward handoff of one queue from ``src`` to ``dst``.

    Protocol: ``src`` marks the queue *migrating* — its consumers see an
    empty queue, new puts arriving at ``src`` (from producers still on
    the old membership version) forward to ``dst_url`` — then pending
    tasks are exported/imported in batches while in-flight leases drain
    in place under the old owner's epoch (their acks still land on
    ``src``; expiry/nack redelivery re-enters pending and is exported on
    the next sweep).  When the queue is empty and quiet, the mark clears.
    Exactly-once is preserved by the existing once-marker/ack-idempotency
    machinery; task *loss* cannot occur because every task is either
    exported+imported, forwarded, or still leased on ``src``.
    """
    moved = 0
    src.migrate_queue(queue, dst_url)
    deadline = time.monotonic() + drain_timeout
    while True:
        tasks = src.export_queue(queue, batch)
        if tasks:
            dst.import_tasks(tasks)
            moved += len(tasks)
            continue
        if _queue_inflight(src, queue) == 0:
            break
        if time.monotonic() >= deadline:
            break
        time.sleep(poll)
    # final sweep (a lease may have expired between the last export and
    # the inflight check), then clear the mark
    tasks = src.export_queue(queue, batch)
    while tasks:
        dst.import_tasks(tasks)
        moved += len(tasks)
        tasks = src.export_queue(queue, batch)
    src.migrate_queue(queue, None)
    return {"queue": queue, "moved": moved}


def _owner_url(m: Membership, ring: HashRing, queue: str) -> str:
    pin = m.pins.get(queue)
    if pin is not None and pin in m.members:
        return pin
    return ring.owner(queue)


def join_federation(path: str, url: str, *,
                    vnodes: int = DEFAULT_VNODES, batch: int = 256,
                    drain_timeout: float = 30.0,
                    **endpoint_kwargs) -> Dict[str, Any]:
    """Add ``url`` to the federation at ``path`` and rebalance: pull the
    queues the new ring assigns to ``url`` from their previous owners
    (drain-and-forward), and push out any queues parked on ``url`` that
    belong elsewhere — the latter is what lets a replacement server adopt
    a dead member's durable root and re-home its stranded queues.
    Returns ``{"version", "moved": [...]}``."""
    from repro.core.netbroker import make_broker
    before = read_membership(path)
    m = join_membership(path, url)
    ring = m.ring(vnodes)
    moved: List[str] = []
    clients: Dict[str, Broker] = {}

    def client(u: str) -> Broker:
        if u not in clients:
            clients[u] = make_broker(u, **endpoint_kwargs)
        return clients[u]

    try:
        others = [u for u in m.urls() if u != url]
        was_member = bool(before and url in before.members)
        if others and not was_member:
            dst = client(url)
            for owner in others:
                try:
                    src = client(owner)
                    queues = src.queue_names()
                except BrokerUnavailable:
                    continue  # dead member: sweep_membership evicts it
                for q in sorted(queues):
                    if _owner_url(m, ring, q) == url:
                        migrate_queue_between(
                            src, dst, q, url, batch=batch,
                            drain_timeout=drain_timeout)
                        moved.append(q)
            # push out stranded queues (adopted root) owned by others
            for q in sorted(dst.queue_names()):
                target = _owner_url(m, ring, q)
                if target != url:
                    migrate_queue_between(
                        dst, client(target), q, target, batch=batch,
                        drain_timeout=drain_timeout)
                    moved.append(q)
    finally:
        for c in clients.values():
            close = getattr(c, "close", None)
            if close is not None:
                try:
                    close()
                except Exception:
                    pass
    return {"version": m.version, "moved": moved}


def leave_federation(path: str, url: str, *,
                     vnodes: int = DEFAULT_VNODES, batch: int = 256,
                     drain_timeout: float = 30.0,
                     **endpoint_kwargs) -> Dict[str, Any]:
    """Remove ``url`` from the federation at ``path`` after migrating
    every queue it owns to the post-leave ring owner.  The membership
    version bumps (the ownership flip) only AFTER the drain — in-flight
    leases complete in place under the old epoch; leases still open at
    the flip are fenced on ack and redeliver on the new owner."""
    from repro.core.netbroker import make_broker
    m = read_membership(path)
    if m is None or url not in m.members:
        return {"version": m.version if m else 0, "moved": []}
    others = [u for u in m.urls() if u != url]
    moved: List[str] = []
    if others:
        ring_after = HashRing(others, vnodes=vnodes)
        clients: Dict[str, Broker] = {}
        try:
            src = make_broker(url, **endpoint_kwargs)
            clients[url] = src
            for q in sorted(src.queue_names()):
                pin = m.pins.get(q)
                target = pin if pin in others else ring_after.owner(q)
                if target not in clients:
                    clients[target] = make_broker(target, **endpoint_kwargs)
                migrate_queue_between(src, clients[target], q, target,
                                      batch=batch,
                                      drain_timeout=drain_timeout)
                moved.append(q)
        finally:
            for c in clients.values():
                close = getattr(c, "close", None)
                if close is not None:
                    try:
                        close()
                    except Exception:
                        pass
    m = leave_membership(path, url)
    return {"version": m.version, "moved": moved}
