# Merlin's contribution in JAX-native form: hierarchical task generation,
# producer-consumer brokers (in-memory, shared-directory, and networked),
# parameter x sample DAG layering, device-fused ensemble execution,
# bundling/aggregation, and crawl-resubmit resilience.
from repro.core.queue import (Broker, BrokerError, BrokerFull,  # noqa
                              BrokerUnavailable, StaleEpochError,
                              InMemoryBroker, FileBroker, Task, new_task,
                              PRIORITY_REAL, PRIORITY_GEN,
                              dlq_queue_name, is_dlq, original_queue)
from repro.core.netbroker import BrokerServer, NetBroker, make_broker  # noqa
from repro.core.shardbroker import (ShardedBroker,  # noqa
                                    migrate_queue_between,
                                    join_federation, leave_federation)
from repro.core.hashring import (HashRing, Membership,  # noqa
                                 read_membership, join_membership,
                                 leave_membership, heartbeat_membership,
                                 sweep_membership, pin_queue)
from repro.core.autoscale import Autoscaler, AutoscalePolicy  # noqa
from repro.core.hierarchy import HierarchyCfg, root_task, expand  # noqa
from repro.core.spec import StudySpec, Step, SpecError  # noqa
from repro.core.dag import TaskDag, DagNode, DagEdge, compile_dag  # noqa
from repro.core.handlers import (ExecutionHandler, FnStepHandler,  # noqa
                                 SubprocessHandler, SchedulerJobHandler,
                                 MockScheduler, HandlerError)
from repro.core.runtime import MerlinRuntime  # noqa
from repro.core.worker import Worker, WorkerPool  # noqa
from repro.core.bundler import Bundler, missing_samples  # noqa
from repro.core.ensemble import EnsembleExecutor  # noqa
from repro.core.resilience import (RetryPolicy, BackoffPolicy,  # noqa
                                   CircuitBreaker)
from repro.core.chaos import ChaosBroker, FlakyFn  # noqa
