"""Stats-driven autoscaling for worker pools and federation shards.

Merlin's premise is that ensemble capacity flexes with the workload —
producers, workers, and brokers scale independently.  The brokers
already export everything a policy needs (per-queue depth, in-flight
leases, live consumers from the heartbeat registry, and the execution
engine's busy fraction); this module closes the loop:

* :class:`AutoscalePolicy` — the knobs: backlog-per-worker thresholds,
  pool sizing bounds, idle windows, cooldowns, and the total-backlog
  watermarks that trigger *shard-level* recommendations.

* :class:`Autoscaler` — a deterministic policy loop.  ``plan()`` samples
  the broker and produces a :class:`ScalePlan` (worker actions the loop
  can take itself + advisory shard join/leave recommendations);
  ``apply()`` executes the worker actions through a caller-supplied pool
  factory and sweeps dead members out of the federation membership file;
  ``step()`` is plan-then-apply.  All time flows through an injectable
  clock, so tests drive idle windows and cooldowns without sleeping.

Worker scale-*up* creates a new pool via ``pool_factory(n)``; scale-
*down* shuts down the most recently created pool (``WorkerPool.scale``
only grows, so the pool SET is the unit of elasticity).  Shard-level
actions are never taken autonomously — starting a broker server is a
deployment decision — they surface as recommendations that
``merlin-scale`` prints and an operator (or launcher script) acts on.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

__all__ = ["AutoscalePolicy", "ScaleAction", "ScalePlan", "Autoscaler"]


@dataclass(frozen=True)
class AutoscalePolicy:
    """Autoscaling thresholds (all advisory rates are per *poll*).

    Worker-level (the loop acts on these itself):

    * ``up_backlog_per_worker`` — scale up when pending tasks per unit of
      drain capacity exceed this.
    * ``pool_size`` — workers added per scale-up action (one new pool).
    * ``min_workers`` / ``max_workers`` — bounds on the worker count this
      autoscaler manages (externally-started workers are observed via
      consumer heartbeats but never touched).
    * ``down_idle_s`` — the broker must be continuously empty (no pending,
      no inflight) this long before a pool is retired.
    * ``cooldown_s`` — minimum spacing between applied worker actions, so
      a burst doesn't thrash pools up and down.
    * ``engine_busy_high`` — engine busy-fraction above which scale-down
      is vetoed and a non-empty backlog biases toward scale-up (the
      engine, not the workers, is the bottleneck signal).

    Shard-level (recommendations only):

    * ``shard_up_depth`` — total backlog above this recommends joining a
      shard to the federation.
    * ``shard_down_depth`` — total backlog at/below this (and nothing in
      flight) with more than one member recommends draining one out.
    * ``membership_ttl`` — heartbeat age past which ``apply()`` evicts a
      member from the membership file (dead-shard cleanup).
    """
    up_backlog_per_worker: float = 8.0
    pool_size: int = 2
    min_workers: int = 0
    max_workers: int = 16
    down_idle_s: float = 10.0
    cooldown_s: float = 5.0
    engine_busy_high: float = 0.85
    shard_up_depth: int = 5000
    shard_down_depth: int = 0
    membership_ttl: float = 15.0


@dataclass
class ScaleAction:
    """One planned action: ``workers_up``/``workers_down`` (actionable)
    or ``shard_join``/``shard_leave`` (advisory)."""
    kind: str
    n: int = 0
    reason: str = ""

    def to_doc(self) -> Dict[str, Any]:
        return {"kind": self.kind, "n": self.n, "reason": self.reason}


@dataclass
class ScalePlan:
    """The output of one policy evaluation: the observation snapshot it
    was derived from, the worker actions ``apply()`` would take, and the
    shard-level recommendations it would print."""
    at: float
    observed: Dict[str, Any]
    actions: List[ScaleAction] = field(default_factory=list)
    recommendations: List[ScaleAction] = field(default_factory=list)

    def to_doc(self) -> Dict[str, Any]:
        return {"observed": self.observed,
                "actions": [a.to_doc() for a in self.actions],
                "recommendations": [a.to_doc()
                                    for a in self.recommendations]}


class Autoscaler:
    """The policy loop: sample broker stats, plan, (optionally) apply.

    ``pool_factory(n)`` must return an object with ``shutdown()`` —
    typically ``lambda n: WorkerPool(runtime, n_workers=n, ...)``.
    Without a factory the loop still plans (``merlin-scale --plan``
    against a remote broker) but worker actions are reported, not taken.

    ``engine_stats`` is an optional zero-arg callable returning the
    execution-engine stats dict (the ``"utilization"`` busy fraction);
    ``membership_path`` points at the federation membership file so
    ``apply()`` can evict heartbeat-expired members and plan() can size
    shard recommendations against the live member count.
    """

    def __init__(self, broker, policy: Optional[AutoscalePolicy] = None,
                 pool_factory: Optional[Callable[[int], Any]] = None,
                 membership_path: Optional[str] = None,
                 engine_stats: Optional[Callable[[], Dict[str, Any]]] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.broker = broker
        self.policy = policy or AutoscalePolicy()
        self.pool_factory = pool_factory
        self.membership_path = membership_path
        self.engine_stats = engine_stats
        self._clock = clock
        self.pools: List[Any] = []  # newest last; scale-down pops the tail
        self._pool_sizes: List[int] = []
        self._idle_since: Optional[float] = None
        self._last_action_at: Optional[float] = None

    # -- observation ---------------------------------------------------------
    def workers(self) -> int:
        """Workers under THIS autoscaler's management."""
        return sum(self._pool_sizes)

    def observe(self) -> Dict[str, Any]:
        """One stats sample, flattened to what the policy consumes."""
        stats = dict(self.broker.stats)
        consumers = {q: int(c) for q, c
                     in (stats.get("consumers") or {}).items()}
        queues = sorted(self.broker.queue_names())
        depth_by_q = {q: self.broker.qsize((q,)) for q in queues}
        obs: Dict[str, Any] = {
            "depth": sum(depth_by_q.values()),
            "depth_by_queue": depth_by_q,
            "inflight": self.broker.inflight(),
            "consumers": sum(consumers.values()),
            "consumers_by_queue": consumers,
            "managed_workers": self.workers(),
            "pools": len(self.pools),
            "utilization": 0.0,
            "members": None,
            "migrating": list(stats.get("migrating") or ()),
        }
        if self.engine_stats is not None:
            try:
                obs["utilization"] = float(
                    (self.engine_stats() or {}).get("utilization", 0.0))
            except Exception:
                pass  # a dead engine must not kill the scaling loop
        if self.membership_path is not None:
            from repro.core.hashring import read_membership
            m = read_membership(self.membership_path)
            if m is not None:
                obs["members"] = len(m.members)
                obs["ring_version"] = m.version
        return obs

    # -- planning ------------------------------------------------------------
    def _cooled_down(self, now: float) -> bool:
        return (self._last_action_at is None
                or now - self._last_action_at >= self.policy.cooldown_s)

    def plan(self) -> ScalePlan:
        """Evaluate the policy against one observation (no side effects
        beyond the idle-window tracker)."""
        p = self.policy
        now = self._clock()
        obs = self.observe()
        plan = ScalePlan(at=now, observed=obs)

        depth, inflight = obs["depth"], obs["inflight"]
        util = obs["utilization"]
        busy = depth > 0 or inflight > 0
        if busy:
            self._idle_since = None
        elif self._idle_since is None:
            self._idle_since = now
        obs["idle_s"] = 0.0 if self._idle_since is None \
            else round(now - self._idle_since, 3)

        # drain capacity: managed workers, or live external consumers
        # when we manage none yet (don't double-provision a federation
        # that already has workers attached elsewhere)
        managed = self.workers()
        capacity = max(1, managed if managed > 0 else obs["consumers"])
        per_worker = depth / capacity
        obs["backlog_per_worker"] = round(per_worker, 3)

        want_up = (per_worker > p.up_backlog_per_worker
                   or (depth > 0 and util >= p.engine_busy_high))
        if want_up and managed < p.max_workers and self._cooled_down(now):
            n = min(p.pool_size, p.max_workers - managed)
            if n > 0:
                why = (f"backlog/worker {per_worker:.1f} > "
                       f"{p.up_backlog_per_worker:g}"
                       if per_worker > p.up_backlog_per_worker
                       else f"engine busy {util:.2f} >= "
                            f"{p.engine_busy_high:g}")
                plan.actions.append(
                    ScaleAction("workers_up", n=n, reason=why))

        idle_long = (self._idle_since is not None
                     and now - self._idle_since >= p.down_idle_s)
        if (not plan.actions and idle_long and self.pools
                and managed > p.min_workers
                and util < p.engine_busy_high
                and self._cooled_down(now)):
            n = min(self._pool_sizes[-1], managed - p.min_workers)
            if n > 0:
                plan.actions.append(ScaleAction(
                    "workers_down", n=n,
                    reason=f"idle {now - self._idle_since:.1f}s >= "
                           f"{p.down_idle_s:g}s"))

        # shard-level: advisory only — starting/stopping broker servers
        # is a deployment action the operator takes (broker-serve --join)
        members = obs.get("members")
        if depth > p.shard_up_depth:
            plan.recommendations.append(ScaleAction(
                "shard_join", n=1,
                reason=f"total backlog {depth} > {p.shard_up_depth}"))
        elif (members is not None and members > 1
              and depth <= p.shard_down_depth and inflight == 0):
            plan.recommendations.append(ScaleAction(
                "shard_leave", n=1,
                reason=f"backlog {depth} <= {p.shard_down_depth} "
                       f"across {members} members"))
        return plan

    # -- application ---------------------------------------------------------
    def apply(self, plan: ScalePlan) -> Dict[str, Any]:
        """Execute the plan's worker actions (needs ``pool_factory``) and
        sweep heartbeat-expired members from the membership file."""
        applied: List[ScaleAction] = []
        for a in plan.actions:
            if a.kind == "workers_up":
                if self.pool_factory is None:
                    continue
                pool = self.pool_factory(a.n)
                self.pools.append(pool)
                self._pool_sizes.append(a.n)
            elif a.kind == "workers_down":
                if not self.pools:
                    continue
                pool = self.pools.pop()
                self._pool_sizes.pop()
                pool.shutdown()
            else:
                continue
            self._last_action_at = plan.at
            applied.append(a)

        evicted: List[str] = []
        if self.membership_path is not None:
            from repro.core.hashring import sweep_membership
            try:
                _, evicted = sweep_membership(self.membership_path,
                                              self.policy.membership_ttl)
            except OSError:
                pass  # registry briefly unavailable; next tick retries
        return {"applied": applied, "evicted": evicted}

    def step(self) -> ScalePlan:
        """One loop iteration: plan, apply, return the (annotated) plan."""
        plan = self.plan()
        result = self.apply(plan)
        plan.observed["applied"] = [a.to_doc() for a in result["applied"]]
        if result["evicted"]:
            plan.observed["evicted_members"] = result["evicted"]
        return plan

    def shutdown(self) -> None:
        """Retire every managed pool (reverse creation order)."""
        while self.pools:
            self.pools.pop().shutdown()
            self._pool_sizes.pop()
