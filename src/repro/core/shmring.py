"""Same-host shared-memory broker transport (``shm://``) and bundle ring.

The paper's producers and consumers often land on the SAME node — a
WorkerPool and a learner sharing one allocation — yet until now their
traffic still crossed either the filesystem (FileBroker / bundle files)
or the TCP loopback (NetBroker).  This module gives co-resident peers a
zero-syscall-per-byte path: fixed shared-memory segments
(:mod:`multiprocessing.shared_memory`) carrying the same bin1-encoded
frames the TCP wire speaks (core/wirecodec.py), coordinated by a JSON
registry file managed with the repo's one locked-JSON implementation
(core/jsonstore.py — slot directory + epoch live there, not in a new
ad-hoc path).

Pieces:

* :class:`ShmRing` — a single-producer/single-consumer byte ring in one
  segment.  Header = two little-endian u64 cursors (head: reader-owned,
  tail: writer-owned); records are ``u32 length + payload`` written
  contiguously (a ``0xFFFFFFFF`` wrap marker skips the tail fragment).
  The payload is fully written *before* the tail cursor is published,
  which is the whole visibility story on x86/CPython — no locks on the
  cross-process path.  A process-local mutex serializes producers in
  the same process; multiple producer *processes* on one ring are not
  supported.
* :class:`ShmListener` — server side.  ``BrokerServer(...,
  shm_path=REG)`` starts one: it bumps the registry epoch (disowning
  any channels a dead predecessor left behind, unlinking their
  segments best-effort), then watches the registry for client channels
  and serves each with its own thread — the exact per-connection
  threading model of the TCP wire, so a blocking ``get_many`` parks
  one channel, not the transport.
* :class:`ShmBroker` — client side (``make_broker("shm://REG")``).
  Each calling thread registers its own channel (a req ring + a resp
  ring it creates and owns), mirroring NetBroker's
  connection-per-thread rule and keeping every ring strictly SPSC.
  Requests are serial per channel, so responses match requests by
  position — no correlation ids.
* :class:`BundleRing` — the Bundler's pluggable write sink: fused
  ``sub_ranges`` bundles ride the ring to a same-host consumer as raw
  ndarray bytes.  ``push_bundle`` never blocks — a full ring drops the
  handoff because the bundle FILE remains the durable source of truth
  (and of ``load_since`` cursors); the ring is a latency optimization,
  not a durability layer.

Durability caveats versus ``file://``: segments are RAM, scoped to the
host, and vanish on reboot; a crashed client leaks its segments until
the next server start reclaims them via the epoch bump.  Anything that
must survive belongs in the FileBroker directory or bundle files.

Python 3.10 wart: attaching to an existing segment registers it with
the resource tracker, which would unlink it when the *attaching*
process exits (no ``track=False`` until 3.13) — :func:`_untrack`
undoes that immediately after every attach.
"""
from __future__ import annotations

import os
import select
import socket
import struct
import threading
import time
import uuid
from multiprocessing import shared_memory
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import jsonstore
from repro.core.queue import (BrokerError, BrokerUnavailable, Lease, Task,
                              _normalize_queues, task_to_wire)
from repro.core.netbroker import _ERROR_TYPES
from repro.core.wirecodec import BIN_CODEC, CodecError

_HDR = 16                    # u64 head + u64 tail
_WRAP = 0xFFFFFFFF           # length marker: skip to start of ring
_REQ_CAPACITY = 1 << 20      # 1 MiB per client->server ring
_RESP_CAPACITY = 1 << 22     # 4 MiB: lease batches are the fat direction
# wait strategy: a few sched_yield passes (fast path when the peer is
# runnable on another core), then fixed short sleeps.  Tunable because
# the right point depends brutally on core count: on an oversubscribed
# single-CPU host every spinning waiter steals cycles from the peer it
# is waiting FOR, so fewer spins and a coarser sleep win; on a roomy
# multi-core node more spinning cuts latency.  (repro/env.py records
# the host; these read the environment once at import.)
_SPINS = int(os.environ.get("REPRO_SHM_SPINS", "50"))
_POLL_S = float(os.environ.get("REPRO_SHM_POLL_US", "200")) * 1e-6
# default consumer-prefetch pipeline depth (see ShmBroker docstring)
_PREFETCH = int(os.environ.get("REPRO_SHM_PREFETCH", "2"))


# segment names THIS process created: their tracker registration is the
# legitimate one (balanced by unlink's unregister), so an attach in the
# same process must not strip it — the tracker cache is a set, and a
# second register from the attach dedups into the creator's entry
_created_here: set = set()


def _untrack(shm: shared_memory.SharedMemory) -> None:
    """Keep the resource tracker from unlinking a segment we merely
    attached to (3.10 registers attaches too; see module docstring)."""
    if shm._name in _created_here:
        return
    try:
        from multiprocessing import resource_tracker
        resource_tracker.unregister(shm._name, "shared_memory")
    except Exception:
        pass


def _unlink_segment(name: str) -> None:
    try:
        shm = shared_memory.SharedMemory(name=name)
    except (FileNotFoundError, OSError):
        return
    try:
        shm.unlink()
    except (FileNotFoundError, OSError):
        pass
    finally:
        try:
            shm.close()
        except (OSError, BufferError):
            pass


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(int(pid), 0)
    except ProcessLookupError:
        return False
    except (PermissionError, OSError, TypeError, ValueError):
        pass  # exists but not ours / unknowable: assume alive
    return True


class ShmRing:
    """SPSC byte ring over one shared-memory segment.

    ``create=True`` allocates a fresh segment (``capacity`` data bytes);
    ``name=`` attaches to an existing one.  ``try_push``/``try_pop`` are
    non-blocking; ``push``/``pop`` poll with a short spin-then-sleep
    escalation.  Records must fit the ring (``len + 4 <= capacity``) or
    ``push`` raises ValueError so callers can fall back to a durable
    path instead of deadlocking on an impossible write.
    """

    def __init__(self, name: Optional[str] = None, capacity: int = _REQ_CAPACITY,
                 create: bool = False):
        if create:
            self._shm = shared_memory.SharedMemory(create=True,
                                                   size=_HDR + int(capacity))
            self._shm.buf[:_HDR] = b"\x00" * _HDR
            _created_here.add(self._shm._name)
        else:
            if not name:
                raise ValueError("attaching to a ring needs its segment name")
            self._shm = shared_memory.SharedMemory(name=name)
            _untrack(self._shm)
        self._buf = self._shm.buf
        self._cap = self._shm.size - _HDR
        self._push_lock = threading.Lock()  # intra-process producer guard
        self._closed = False
        # True when the last try_push found the consumer fully caught up
        # (it may be about to block): the producer must ring its wakeup
        # doorbell.  False means unconsumed records predate ours, and the
        # byte that announced the empty->non-empty transition is still
        # un-consumed — a wakeup is already guaranteed, skip the syscall.
        self.consumer_was_caught_up = True

    @property
    def name(self) -> str:
        return self._shm.name

    @property
    def capacity(self) -> int:
        return self._cap

    def _cursor(self, off: int) -> int:
        return struct.unpack_from("<Q", self._buf, off)[0]

    def _publish(self, off: int, v: int) -> None:
        struct.pack_into("<Q", self._buf, off, v)

    def try_push(self, data: bytes) -> bool:
        n = len(data)
        if n + 4 > self._cap:
            raise ValueError(f"record of {n} bytes exceeds ring capacity "
                             f"{self._cap}")
        with self._push_lock:
            if self._closed:
                raise BrokerError("ring is closed")
            head = self._cursor(0)
            tail = self._cursor(8)
            pos = tail % self._cap
            contig = self._cap - pos
            pad = contig if contig < n + 4 else 0
            if self._cap - (tail - head) < pad + n + 4:
                return False
            if pad:
                if contig >= 4:
                    struct.pack_into("<I", self._buf, _HDR + pos, _WRAP)
                tail += pad
                pos = 0
            base = _HDR + pos
            self._buf[base + 4:base + 4 + n] = data   # payload first,
            struct.pack_into("<I", self._buf, base, n)
            start = tail - pad  # tail as the consumer last saw it
            self._publish(8, tail + 4 + n)            # cursor last
            # Re-read head *after* publishing: if the consumer has drained
            # everything that preceded this record it may be blocking (or
            # about to), so the producer must ring the doorbell.  Otherwise
            # older records — whose empty->non-empty transition already sent
            # a byte that is still unconsumed — guarantee a wakeup.
            self.consumer_was_caught_up = self._cursor(0) >= start
            return True

    def try_peek(self) -> bool:
        """True if a record is (probably) available: a cheap cursor
        compare with no side effects, for spin-wait loops."""
        if self._closed:
            raise BrokerError("ring is closed")
        return self._cursor(0) != self._cursor(8)

    def try_pop(self) -> Optional[bytes]:
        if self._closed:
            raise BrokerError("ring is closed")
        head = self._cursor(0)
        tail = self._cursor(8)
        while head != tail:
            pos = head % self._cap
            contig = self._cap - pos
            if contig >= 4:
                (n,) = struct.unpack_from("<I", self._buf, _HDR + pos)
                if n != _WRAP:
                    data = bytes(self._buf[_HDR + pos + 4:
                                           _HDR + pos + 4 + n])
                    self._publish(0, head + 4 + n)
                    return data
            head += contig  # tail fragment (padded or too small): skip
            self._publish(0, head)
        return None

    def _poll(self, step: Callable[[], Optional[Any]],
              timeout: float) -> Optional[Any]:
        deadline = time.monotonic() + timeout
        spins = 0
        while True:
            out = step()
            if out is not None:
                return out
            if time.monotonic() >= deadline:
                return None
            if spins < _SPINS:
                os.sched_yield()
            else:
                time.sleep(_POLL_S)
            spins += 1

    def push(self, data: bytes, timeout: float = 0.0) -> bool:
        if self.try_push(data):  # uncontended fast path: no _poll setup
            return True
        return bool(self._poll(
            lambda: True if self.try_push(data) else None, timeout))

    def pop(self, timeout: float = 0.0) -> Optional[bytes]:
        out = self.try_pop()
        if out is not None:
            return out
        return self._poll(self.try_pop, timeout)

    def close(self) -> None:
        with self._push_lock:
            if self._closed:
                return
            self._closed = True
        self._buf = None
        try:
            self._shm.close()
        except (OSError, BufferError):
            pass

    def unlink(self) -> None:
        _created_here.discard(self._shm._name)
        try:
            self._shm.unlink()
        except (FileNotFoundError, OSError):
            pass


# ---------------------------------------------------------------------------
# server side
# ---------------------------------------------------------------------------

class _ServedChannel:
    """Server-side per-channel state: rings, doorbell, worker thread."""

    __slots__ = ("cid", "req", "resp", "thread", "dead", "retired",
                 "doorbell")

    def __init__(self, cid: str, req: ShmRing, resp: ShmRing):
        self.cid = cid
        self.req = req
        self.resp = resp
        self.thread: Optional[threading.Thread] = None
        self.dead = False
        self.retired = False
        self.doorbell: Optional[socket.socket] = None


class ShmListener:
    """Serve a broker backend over shared-memory channels.

    ``dispatch`` is the server's request handler
    (:meth:`BrokerServer._dispatch`): channels carry the same op dicts
    as the TCP wire, always bin1-encoded (both ends are this codebase —
    there is no legacy shm peer to stay compatible with, so no
    negotiation).  Starting the listener bumps the registry epoch:
    channels registered under an older epoch belong to a dead server's
    clients and their segments are reclaimed.

    Threading: each channel gets a worker thread that blocks in
    ``recv`` on a per-channel unix-domain *doorbell* socket (payloads
    never touch it — each side writes a single wakeup byte after
    pushing to a ring, so the data plane stays in shared memory while
    waiting happens in the kernel, exactly like a blocked TCP
    ``recv``).  On wakeup the worker drains its request ring with
    ``try_pop`` and answers each frame.  A single poller thread only
    accepts doorbell connections, reads the ``<cid>\\n`` hello line,
    and rescans the registry for new channels.  Two earlier designs
    lost to loopback TCP on an oversubscribed host and are worth
    recording: thread-per-channel *spin-polling* its own ring
    serialized N pollers' Python bytecode on the GIL against the one
    handler doing real work (~2x drop at 4 channels), and a central
    poller feeding worker inboxes added a thread hop (select wakeup ->
    queue put -> worker wakeup) to every request, which on one CPU is
    an extra GIL handoff per op.  The doorbell keeps the rings as the
    source of truth — bytes are level-style wakeup hints, spurious or
    coalesced ones are harmless, and a channel whose hello has not
    arrived yet degrades to timeout polling.  Blocking backend ops (a
    ``get_many`` long-poll) only park that channel's worker.
    """

    def __init__(self, path: str, dispatch: Callable[[dict], Optional[dict]],
                 max_block_s: float = 10.0,
                 req_capacity: int = _REQ_CAPACITY,
                 resp_capacity: int = _RESP_CAPACITY,
                 scan_interval: float = 0.05):
        self.path = path
        self.dispatch = dispatch
        self.max_block_s = max_block_s
        self.req_capacity = int(req_capacity)
        self.resp_capacity = int(resp_capacity)
        self.scan_interval = scan_interval
        self.epoch: Optional[int] = None
        self.stats = {"channels": 0, "requests": 0, "errors": 0,
                      "codec_errors": 0}
        self._stopping = threading.Event()
        self._poll_thread: Optional[threading.Thread] = None
        self._served: Dict[str, "_ServedChannel"] = {}

    def start(self) -> "ShmListener":
        stale: List[str] = []
        sock_path = self.path + ".sock"

        def init(doc: dict) -> None:
            for ch in (doc.get("channels") or {}).values():
                stale.extend(n for n in (ch.get("req"), ch.get("resp")) if n)
            doc["epoch"] = int(doc.get("epoch", 0)) + 1
            doc["channels"] = {}
            doc["server"] = {"pid": os.getpid()}
            doc["capacity"] = {"req": self.req_capacity,
                               "resp": self.resp_capacity}
            doc["doorbell"] = sock_path

        doc = jsonstore.update_json(self.path, init, strict=True)
        self.epoch = int(doc["epoch"])
        for name in stale:
            _unlink_segment(name)
        try:
            os.unlink(sock_path)  # a dead predecessor's socket file
        except OSError:
            pass
        self._listener_sock = socket.socket(socket.AF_UNIX,
                                            socket.SOCK_STREAM)
        self._listener_sock.bind(sock_path)
        self._listener_sock.listen(64)
        self._listener_sock.setblocking(False)
        self._sock_path = sock_path
        self._hello: Dict[str, socket.socket] = {}
        self._greeting: Dict[socket.socket, bytes] = {}  # cid not read yet
        self._cfg = jsonstore.SharedJsonConfig(self.path)
        self._poll_thread = threading.Thread(
            target=self._poll_loop, daemon=True,
            name=f"shmbroker-poll-{os.path.basename(self.path)}")
        self._poll_thread.start()
        return self

    def stop(self) -> None:
        self._stopping.set()
        if self._poll_thread is not None:
            self._poll_thread.join(timeout=2.0)
        for ch in list(self._served.values()):
            self._retire(ch)
            ch.thread.join(timeout=2.0)
        for s in ([self._listener_sock] + list(self._greeting)
                  + list(self._hello.values())):
            try:
                s.close()
            except OSError:
                pass
        try:
            os.unlink(self._sock_path)
        except OSError:
            pass

    def _rescan(self) -> None:
        doc = self._cfg.load_if_changed()
        if doc is None:
            return
        channels = doc.get("channels") or {}
        # deregistered channels: wake the worker so it closes its rings
        for cid in set(self._served) - set(channels):
            self._retire(self._served[cid])
        for cid, ch in channels.items():
            if cid in self._served or ch.get("epoch") != self.epoch:
                continue
            try:
                req = ShmRing(name=ch["req"])
                resp = ShmRing(name=ch["resp"])
            except (KeyError, FileNotFoundError, OSError):
                continue  # client vanished between register/attach
            served = _ServedChannel(cid, req, resp)
            served.doorbell = self._hello.pop(cid, None)
            served.thread = threading.Thread(
                target=self._serve_channel, args=(served,), daemon=True,
                name=f"shmbroker-chan-{cid}")
            self._served[cid] = served
            self.stats["channels"] += 1
            served.thread.start()

    def _retire(self, served: "_ServedChannel") -> None:
        """Ask a worker to exit: flag it and shut its doorbell down so a
        blocked ``recv`` returns EOF immediately."""
        self._served.pop(served.cid, None)
        served.retired = True
        db = served.doorbell
        if db is not None:
            try:
                db.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass

    def _on_readable(self, s: socket.socket) -> None:
        if s is self._listener_sock:
            while True:
                try:
                    conn, _ = self._listener_sock.accept()
                except (BlockingIOError, OSError):
                    return
                conn.setblocking(False)
                self._greeting[conn] = b""
            return
        if s in self._greeting:  # awaiting the "<cid>\n" hello line
            try:
                data = s.recv(256)
            except (BlockingIOError, InterruptedError):
                return
            except OSError:
                data = b""
            if not data:
                del self._greeting[s]
                s.close()
                return
            buf = self._greeting[s] + data
            if b"\n" not in buf:
                self._greeting[s] = buf
                return
            del self._greeting[s]
            cid = buf.split(b"\n", 1)[0].decode("ascii", "replace")
            served = self._served.get(cid)
            if served is not None:
                served.doorbell = s  # worker picks it up next iteration
            else:
                self._hello[cid] = s
                self._rescan()  # the client registered before connecting

    def _poll_loop(self) -> None:
        """Accept doorbell connections and track registry changes.

        The data path never goes through here — workers block on their
        own doorbell sockets — so this loop wakes only for new
        connections and the periodic registry rescan."""
        last_scan = 0.0
        while not self._stopping.is_set():
            socks = [self._listener_sock] + list(self._greeting)
            try:
                readable, _, _ = select.select(socks, [], [],
                                               self.scan_interval)
            except (OSError, ValueError):  # a socket died mid-select
                readable = []
            for s in readable:
                self._on_readable(s)
            now = time.monotonic()
            if now - last_scan >= self.scan_interval:
                self._rescan()
                # reap workers that exited on their own (client EOF)
                for cid, ch in list(self._served.items()):
                    if ch.dead:
                        del self._served[cid]
                last_scan = now

    def _handle_frame(self, served: "_ServedChannel", raw: bytes) -> bool:
        """Answer one request frame; False means abandon the channel.

        Frames flagged ``_noreply`` (the client's pipelined acks) get NO
        reply on success — the ring is reliable, in-order shared memory
        and the ops are idempotent, so a success reply would only cost
        both sides encode/push/wakeup/decode work.  Their *failures*
        still travel back, marked ``oob`` (out-of-band) so the client
        can tell them apart from the strict FIFO replies of synchronous
        ops.  A frame that does not even decode also answers ``oob``
        (its FIFO position is unknowable), which keeps the quarantine
        contract: a corrupt record yields a typed error, not a dead
        channel."""
        self.stats["requests"] += 1
        noreply = False
        resp: Optional[dict]
        try:
            request = BIN_CODEC.decode(raw)
            if not isinstance(request, dict):
                raise CodecError("frame is not a request object")
        except CodecError as e:
            self.stats["codec_errors"] += 1
            resp = {"ok": False, "oob": "frame", "error_type": "CodecError",
                    "error": f"CodecError: {e}"}
        else:
            noreply = bool(request.pop("_noreply", False))
            try:
                resp = {"ok": True, **(self.dispatch(request) or {})}
            except Exception as e:
                self.stats["errors"] += 1
                resp = {"ok": False,
                        "error_type": type(e).__name__,
                        "error": f"{type(e).__name__}: {e}"}
                if noreply:
                    resp["oob"] = str(request.get("op") or "op")
            if noreply and resp.get("ok"):
                return True  # reply elided
        try:
            payload = BIN_CODEC.encode(resp)
        except (TypeError, ValueError) as e:
            payload = BIN_CODEC.encode(
                {"ok": False, "error_type": "BrokerError",
                 "error": f"BrokerError: unencodable reply: {e}"})
        try:
            pushed = served.resp.push(payload, timeout=self.max_block_s)
        except ValueError:
            # reply bigger than the response ring (a huge lease batch):
            # a typed error beats a dead channel — the leases time out
            # and requeue on the backend as usual
            pushed = served.resp.push(BIN_CODEC.encode(
                {"ok": False, "error_type": "BrokerError",
                 "error": f"BrokerError: reply of {len(payload)} bytes "
                          "exceeds the shm response ring; request a "
                          "smaller batch"}), timeout=self.max_block_s)
        if not pushed:
            return False  # consumer gone or wedged: abandon the channel
        db = served.doorbell
        if db is not None and served.resp.consumer_was_caught_up:
            # Same elision as the client side: unconsumed earlier replies
            # imply an unconsumed wakeup byte, and the client drains the
            # ring to empty before blocking on the doorbell.
            try:
                db.send(b"\x01")
            except (BlockingIOError, socket.timeout):
                pass  # unread wakeups queued: client will wake anyway
            except OSError:
                return False  # client gone
        return True

    def _serve_channel(self, served: "_ServedChannel") -> None:
        try:
            while not self._stopping.is_set() and not served.retired:
                drained = False
                while True:
                    try:
                        raw = served.req.try_pop()
                    except BrokerError:
                        return  # ring closed under us
                    if raw is None:
                        break
                    drained = True
                    if not self._handle_frame(served, raw):
                        return
                if drained:
                    continue  # more may have landed while we worked
                db = served.doorbell
                if db is None:
                    # hello not in yet: fall back to polling the ring
                    try:
                        raw = served.req.pop(timeout=self.scan_interval)
                    except BrokerError:
                        return
                    if raw is not None and not self._handle_frame(served,
                                                                  raw):
                        return
                    continue
                try:
                    db.settimeout(0.2)  # bounded so retire/stop is seen
                    data = db.recv(4096)
                    if not data:
                        return  # client closed its doorbell: channel dead
                except socket.timeout:
                    continue
                except OSError:
                    return
        except BrokerError:
            pass  # ring closed under us
        finally:
            served.dead = True  # poller reaps the entry on its next scan
            served.req.close()
            served.resp.close()


# ---------------------------------------------------------------------------
# client side
# ---------------------------------------------------------------------------

class _Channel:
    def __init__(self, cid: str, req: ShmRing, resp: ShmRing, epoch: int):
        self.cid = cid
        self.req = req
        self.resp = resp
        self.epoch = epoch
        # sync ops whose replies we abandoned after an out-of-band error
        # raise; the next call discards them to stay in FIFO step
        self.pending: List[str] = []
        self.doorbell: Optional[socket.socket] = None
        self.db_timeout: Optional[float] = None  # cached settimeout value
        # consumer prefetch state: ``prefetch_n`` speculative get_many
        # requests are in flight, all for the selector ``prefetch_key``;
        # ``stash`` is (key, wire-lease dicts) already received but not
        # yet claimed by a caller
        self.prefetch_n: int = 0
        self.prefetch_key: Optional[Tuple] = None
        self.prefetch_frame: Optional[Tuple[Tuple, bytes]] = None
        self.stash: Optional[Tuple[Tuple, List[dict]]] = None


class ShmBroker:
    """Same-host Broker client over shared-memory channels.

    Mirrors NetBroker's contract: full Broker protocol, per-thread
    channels (one blocking ``get_many`` never serializes another
    thread's acks), server-held lease state, chunked blocking gets, and
    typed error relay.  A channel that stops answering (server restart)
    is torn down and re-registered once before ``BrokerUnavailable``.

    One deliberate divergence (``pipeline_acks=True``, the default):
    ``ack``/``ack_many``/``nack`` are fire-and-forget — the request is
    pushed with a ``_noreply`` flag and the call returns immediately.
    The server elides the reply entirely when the op succeeds (the ring
    is reliable, in-order shared memory and acks are idempotent, so a
    success reply would be pure overhead: encode + push + wakeup on one
    side, pop + decode on the other).  The claim+ack drain loop then
    pays one round trip per batch instead of two, which on an
    oversubscribed host is the difference between the shm path beating
    loopback TCP and losing to it.  Consequence: a *rejected* ack (e.g.
    :class:`StaleEpochError` after a shard failover) comes back as an
    out-of-band error frame and raises its typed error from the NEXT
    synchronous call on the same thread, one op late.  Delivery is
    at-least-once, so correctness is unaffected — an ack lost to a torn
    channel just means redelivery.  Pass ``pipeline_acks=False`` for
    strict call-site errors.

    The second divergence (``prefetch``, default 2) is AMQP-style
    consumer prefetch with a pipeline depth: while a drain loop is hot
    (non-empty batches coming back), the client keeps up to ``prefetch``
    speculative ``get_many`` requests in flight for the same queue
    selector (each with a zero timeout hint, so the server never parks
    on one and frames queued behind it — acks — are not delayed).  The
    point on an oversubscribed host is not overlap but *wakeup
    batching*: when the client finally blocks, the server wakes once
    and answers every queued request, and the client then claims a
    window of batches with local ring pops — the context-switch pair
    is amortized over ``prefetch`` batches instead of paid per batch,
    which request-reply TCP cannot do.  Prefetched leases the caller
    never claims (selector change, clean close) are nacked back — or,
    after a crash, redelivered by the visibility timeout like any dead
    consumer's leases.  Per-lease delivery stays at-least-once; a lease
    simply spends a little of its visibility window in the client-side
    stash, so keep ``prefetch * batch * per-task-seconds`` well under
    the queue's visibility timeout (the same sizing rule as AMQP
    ``basic.qos``).  ``prefetch=0`` disables speculation entirely.
    """

    def __init__(self, path: str, connect_timeout: float = 5.0,
                 request_grace: float = 10.0, block_chunk: float = 5.0,
                 pipeline_acks: bool = True, prefetch: int = _PREFETCH):
        self.path = path
        self.connect_timeout = connect_timeout
        self.request_grace = request_grace
        self.block_chunk = block_chunk
        self.pipeline_acks = pipeline_acks
        self.prefetch = int(prefetch)  # bool compat: True -> depth 1
        self._tls = threading.local()
        self._channels: Dict[str, _Channel] = {}
        self._lock = threading.Lock()
        self._closed = False

    @property
    def address(self) -> str:
        return f"shm://{self.path}"

    # -- channel management ---------------------------------------------------
    def _channel(self) -> _Channel:
        ch = getattr(self._tls, "ch", None)
        if ch is not None:
            return ch
        deadline = time.monotonic() + self.connect_timeout
        while True:
            doc = jsonstore.load_json(self.path, default=None)
            if (isinstance(doc, dict) and "epoch" in doc
                    and _pid_alive((doc.get("server") or {}).get("pid", -1))):
                break
            if time.monotonic() >= deadline:
                raise BrokerUnavailable(
                    f"no live shm broker server behind {self.path}")
            time.sleep(0.02)
        cap = doc.get("capacity") or {}
        req = ShmRing(create=True,
                      capacity=int(cap.get("req", _REQ_CAPACITY)))
        resp = ShmRing(create=True,
                       capacity=int(cap.get("resp", _RESP_CAPACITY)))
        cid = uuid.uuid4().hex[:12]
        epoch = int(doc["epoch"])

        def register(d: dict) -> None:
            d.setdefault("channels", {})[cid] = {
                "req": req.name, "resp": resp.name,
                "epoch": epoch, "pid": os.getpid()}

        jsonstore.update_json(self.path, register, strict=True)
        ch = _Channel(cid, req, resp, epoch)
        sock_path = doc.get("doorbell")
        if sock_path:
            try:
                db = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                db.settimeout(self.connect_timeout)
                db.connect(sock_path)
                db.sendall(cid.encode("ascii") + b"\n")
                ch.doorbell = db
            except OSError:
                ch.doorbell = None  # degrade to timeout polling
        self._tls.ch = ch
        with self._lock:
            self._channels[cid] = ch
        return ch

    def _drop_channel(self) -> None:
        ch = getattr(self._tls, "ch", None)
        if ch is None:
            return
        self._tls.ch = None
        with self._lock:
            self._channels.pop(ch.cid, None)

        def deregister(d: dict) -> None:
            (d.get("channels") or {}).pop(ch.cid, None)

        try:
            jsonstore.update_json(self.path, deregister)
        except OSError:
            pass
        if ch.doorbell is not None:
            try:
                ch.doorbell.close()
            except OSError:
                pass
        for ring in (ch.req, ch.resp):
            ring.close()
            ring.unlink()  # we created these segments; reclaim the RAM

    def close(self) -> None:
        self._closed = True
        with self._lock:
            channels, self._channels = list(self._channels.values()), {}
        if channels:
            cids = {c.cid for c in channels}

            def deregister(d: dict) -> None:
                chs = d.get("channels") or {}
                for cid in cids:
                    chs.pop(cid, None)

            try:
                jsonstore.update_json(self.path, deregister)
            except OSError:
                pass
        for ch in channels:
            if ch.prefetch_n:
                # settle in-flight speculative get_manys into the stash
                # (bounded: the server answers timeout-0 gets promptly) so
                # their leases are handed back below rather than waiting
                # out the visibility timeout
                try:
                    self._settle_all(ch)
                except (BrokerError, ValueError, OSError):
                    pass
            if ch.stash is not None:
                # best-effort: hand unclaimed speculative leases back now
                # instead of waiting out their visibility timeout
                _key, wires = ch.stash
                ch.stash = None
                for d in wires:
                    try:
                        self._push_req(ch, BIN_CODEC.encode(
                            {"op": "nack", "tag": d["tag"],
                             "_noreply": True}))
                    except (BrokerError, ValueError, OSError):
                        break
            if ch.doorbell is not None:
                try:
                    ch.doorbell.close()
                except OSError:
                    pass
            for ring in (ch.req, ch.resp):
                ring.close()
                ring.unlink()

    def __enter__(self) -> "ShmBroker":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- RPC core ------------------------------------------------------------
    def _push_req(self, ch: _Channel, frame: bytes) -> bool:
        """Push a request and ring the doorbell; False = channel dead."""
        if not ch.req.push(frame, timeout=1.0):
            return False  # server not draining: assume dead
        if ch.doorbell is not None and ch.req.consumer_was_caught_up:
            # Ring only when the server had drained everything ahead of this
            # frame (it may be parked in recv); otherwise the byte for the
            # earlier frames is still pending and will wake it — the server
            # drains the ring to empty per wakeup, so frames pushed while it
            # is awake are picked up in the same sweep.
            try:
                if ch.db_timeout != 1.0:  # settimeout is a syscall; cache
                    ch.doorbell.settimeout(1.0)
                    ch.db_timeout = 1.0
                ch.doorbell.sendall(b"\x01")
            except OSError:
                return False  # server gone (fast failure detection)
        return True

    def _pop_resp(self, ch: _Channel, timeout: float) -> Optional[bytes]:
        """Wait for a response record, blocking on the doorbell socket
        (zero CPU) rather than polling; the ring stays the source of
        truth — doorbell bytes are only wakeup hints.  (A yield-spin
        fast path was tried here and made latency 15x WORSE on a
        one-CPU host: two spinning peers hand the CPU back and forth in
        scheduler-quantum steps instead of parking one of them.)"""
        if ch.doorbell is None:
            return ch.resp.pop(timeout=timeout)
        deadline = time.monotonic() + timeout
        while True:
            raw = ch.resp.try_pop()
            if raw is not None:
                return raw
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return None
            try:
                t = remaining if remaining < 0.2 else 0.2
                if ch.db_timeout != t:
                    ch.doorbell.settimeout(t)
                    ch.db_timeout = t
                data = ch.doorbell.recv(4096)
                if not data:
                    return None  # server closed the doorbell
            except socket.timeout:
                continue  # re-check the ring, keep waiting
            except OSError:
                return None

    def _next_reply(self, ch: _Channel, timeout: float) -> Optional[dict]:
        """Pop + decode the next reply; None means timeout or garbage
        (both leave the channel unusable: the caller drops it)."""
        raw = self._pop_resp(ch, timeout)
        if raw is None:
            return None
        try:
            resp = BIN_CODEC.decode(raw)
            if not isinstance(resp, dict):
                raise CodecError("response frame is not an object")
        except CodecError:
            return None
        return resp

    @staticmethod
    def _raise_oob(resp: dict) -> None:
        exc = _ERROR_TYPES.get(resp.get("error_type"), BrokerError)
        raise exc(f"deferred {resp.get('oob')} reply: "
                  + resp.get("error", "remote broker error"))

    def _read_reply(self, ch: _Channel, op: str,
                    timeout: float) -> Optional[dict]:
        """Read the next reply owed to ``op``, first discarding replies
        owed to sync ops abandoned after an earlier out-of-band raise —
        they precede ours in FIFO order, and their callers already saw
        an error.  None = timeout or garbage: the channel is desynced
        and the caller must drop it."""
        while ch.pending:
            dresp = self._next_reply(ch, self.request_grace)
            if dresp is None:
                return None
            if dresp.get("oob"):
                ch.pending.append(op)  # op's reply is now owed too
                self._raise_oob(dresp)
            ch.pending.pop(0)
        resp = self._next_reply(ch, timeout)
        if resp is None:
            return None
        if resp.get("oob"):
            ch.pending.append(op)  # op's own reply is still in flight
            self._raise_oob(resp)
        return resp

    def _settle_prefetch(self, ch: _Channel) -> bool:
        """Read ONE in-flight speculative get_many's reply into the
        stash, then opportunistically settle any further replies
        already sitting in the ring (no extra waits).  True = channel
        in FIFO sync (or nothing to settle); False = desynced, caller
        drops the channel (the speculative leases then redeliver via
        their visibility timeout).  An out-of-band error raise
        propagates to the sync caller per the pipelined-ack contract;
        _read_reply has already recorded that the speculative reply is
        still owed."""
        while ch.prefetch_n:
            ch.prefetch_n -= 1
            resp = self._read_reply(ch, "get_many", self.request_grace)
            if resp is None:
                return False
            if resp.get("ok") and resp.get("leases"):
                if ch.stash is not None:
                    ch.stash[1].extend(resp["leases"])
                else:
                    ch.stash = (ch.prefetch_key, list(resp["leases"]))
            # a failed speculative get leased nothing: nothing to keep.
            # only block for the FIRST settle; drain the rest for free
            if not (ch.prefetch_n and not ch.pending
                    and ch.resp.try_peek()):
                break
        return True

    def _settle_all(self, ch: _Channel) -> bool:
        while ch.prefetch_n:
            if not self._settle_prefetch(ch):
                return False
        return True

    def _claim_stash(self, ch: _Channel, qkey: Tuple,
                     n: int) -> List[Lease]:
        """Hand out stashed speculative leases matching the caller's
        queue selector; on a selector mismatch (the consumer
        re-subscribed) nack them back to the server instead."""
        if ch.stash is None:
            return []
        skey, wires = ch.stash
        if skey != qkey:
            ch.stash = None
            for d in wires:
                self._call("nack", tag=d["tag"], _defer=True)
            return []
        take, rest = wires[:n], wires[n:]
        ch.stash = (skey, rest) if rest else None
        return [Lease(Task(**d["task"]), d["tag"]) for d in take]

    def _maybe_prefetch(self, n: int, qlist: Optional[List[str]],
                        qkey: Tuple) -> None:
        """Top the speculative-get_many pipeline up to ``prefetch``
        deep for the selector we just drained from.  Zero server-side
        timeout on each: the server must never park on one, or acks
        queued behind it in the ring would stall.  Best-effort — a
        push failure just means the channel is dying and the next sync
        op will rebuild it."""
        if self.prefetch <= 0:
            return
        ch = getattr(self._tls, "ch", None)
        if ch is None or (ch.prefetch_n and ch.prefetch_key != qkey):
            return
        if ch.prefetch_frame is None or ch.prefetch_frame[0] != (n, qkey):
            ch.prefetch_frame = ((n, qkey), BIN_CODEC.encode(
                {"op": "get_many", "n": n, "timeout": 0.0, "queues": qlist}))
        frame = ch.prefetch_frame[1]
        while ch.prefetch_n < self.prefetch:
            if not self._push_req(ch, frame):
                return
            ch.prefetch_n += 1
            ch.prefetch_key = qkey

    def _call(self, op: str, _timeout_hint: float = 0.0,
              _defer: bool = False, **payload) -> dict:
        if self._closed:
            raise BrokerError("ShmBroker is closed")
        msg = {"op": op, **payload}
        if _defer:
            msg["_noreply"] = True
        frame = BIN_CODEC.encode(msg)
        for _attempt in range(2):  # second pass = one fresh channel
            ch = self._channel()
            # a sync op reads a reply, so outstanding speculative
            # get_manys must be settled first to stay in FIFO step;
            # deferred ops read nothing and skip straight to the push
            if not _defer and not self._settle_all(ch):
                self._drop_channel()
                continue
            if not self._push_req(ch, frame):
                self._drop_channel()
                continue
            if _defer:
                # fire-and-forget: the server elides the reply on
                # success; a failure comes back marked ``oob`` and is
                # raised by the next synchronous call on this thread
                return {}
            resp = self._read_reply(ch, op, _timeout_hint
                                    + self.request_grace)
            if resp is None:
                self._drop_channel()  # timed out or desynced: rebuild
                continue
            if not resp.get("ok"):
                exc = _ERROR_TYPES.get(resp.get("error_type"), BrokerError)
                raise exc(resp.get("error", "remote broker error"))
            return resp
        raise BrokerUnavailable(f"shm broker behind {self.path} "
                                "not responding")

    def ping(self) -> bool:
        try:
            self._call("ping")
            return True
        except BrokerUnavailable:
            return False

    def wait_ready(self, timeout: float = 30.0) -> bool:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.ping():
                return True
            time.sleep(0.05)
        return False

    # -- Broker protocol ------------------------------------------------------
    def put(self, task: Task) -> None:
        # via _put_many_wire for its oversized-frame translation: a task
        # that cannot fit the ring raises BrokerError, not a raw ValueError
        task.enqueued_at = time.time()
        self._put_many_wire([task_to_wire(task)])

    def put_many(self, tasks: List[Task]) -> None:
        now = time.time()
        for t in tasks:
            t.enqueued_at = now
        self._put_many_wire([task_to_wire(t) for t in tasks])

    def _put_many_wire(self, wires: List[Dict[str, Any]]) -> None:
        """put_many with bisection on ring overflow: a batch whose frame
        exceeds the request ring splits in half until chunks fit (TCP
        has no such limit, so NetBroker callers never see this)."""
        if not wires:
            return
        try:
            self._call("put_many", tasks=wires)
        except CodecError:
            raise
        except ValueError:  # frame exceeds ring capacity
            if len(wires) == 1:
                raise BrokerError(
                    "task too large for the shm request ring; use a "
                    "tcp:// or file:// broker for payloads this big")
            mid = len(wires) // 2
            self._put_many_wire(wires[:mid])
            self._put_many_wire(wires[mid:])

    def get(self, timeout: Optional[float] = 0.0,
            queues: Optional[Sequence[str]] = None) -> Optional[Lease]:
        leases = self.get_many(1, timeout=timeout, queues=queues)
        return leases[0] if leases else None

    def get_many(self, n: int, timeout: Optional[float] = 0.0,
                 queues: Optional[Sequence[str]] = None) -> List[Lease]:
        qsel = _normalize_queues(queues)
        qlist = None if qsel is None else list(qsel)
        qkey: Tuple = ("*",) if qlist is None else tuple(qlist)
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            # the prefetch pipeline first: a hot drain loop usually
            # finds its next batch already sitting in the response ring
            ch = self._channel()
            if ch.prefetch_n or ch.stash is not None:
                while ch.stash is None and ch.prefetch_n:
                    if not self._settle_prefetch(ch):
                        self._drop_channel()
                        break
                if getattr(self._tls, "ch", None) is not ch:
                    continue  # desynced mid-settle: fresh channel
                leases = self._claim_stash(ch, qkey, n)
                if leases:
                    self._maybe_prefetch(n, qlist, qkey)
                    return leases
            if deadline is None:
                chunk = self.block_chunk
            else:
                chunk = max(0.0, min(self.block_chunk,
                                     deadline - time.monotonic()))
            resp = self._call("get_many", _timeout_hint=chunk, n=n,
                              timeout=chunk, queues=qlist)
            leases = [Lease(Task(**d["task"]), d["tag"])
                      for d in resp["leases"]]
            if leases:
                self._maybe_prefetch(n, qlist, qkey)
                return leases
            if deadline is not None and time.monotonic() >= deadline:
                return []

    def ack(self, tag: str) -> None:
        self._call("ack", tag=tag, _defer=self.pipeline_acks)

    def ack_many(self, tags: Iterable[str]) -> None:
        tags = list(tags)
        if tags:
            self._call("ack_many", tags=tags, _defer=self.pipeline_acks)

    def nack(self, tag: str) -> None:
        self._call("nack", tag=tag, _defer=self.pipeline_acks)

    def qsize(self, queues: Optional[Sequence[str]] = None) -> int:
        qsel = _normalize_queues(queues)
        return int(self._call(
            "qsize", queues=None if qsel is None else list(qsel))["n"])

    def queue_names(self) -> List[str]:
        return list(self._call("queue_names")["names"])

    def inflight(self) -> int:
        return int(self._call("inflight")["n"])

    def idle(self) -> bool:
        return bool(self._call("idle")["idle"])

    def set_visibility_timeout(self, queue: str, timeout: float) -> None:
        self._call("set_visibility_timeout", queue=queue,
                   timeout=float(timeout))

    def set_max_queue_depth(self, queue: str, depth: Optional[int]) -> None:
        self._call("set_max_queue_depth", queue=queue,
                   depth=None if depth is None else int(depth))

    def heartbeat(self, consumer_id: str,
                  queues: Optional[Sequence[str]] = None) -> None:
        qsel = _normalize_queues(queues)
        self._call("heartbeat", consumer_id=consumer_id,
                   queues=None if qsel is None else list(qsel))

    def inflight_tasks(self) -> List[Tuple[Task, float]]:
        return [(Task(**d), float(age))
                for d, age in self._call("inflight_tasks")["tasks"]]

    @property
    def stats(self) -> Dict[str, Any]:
        s = dict(self._call("stats")["stats"])
        s["wire_codec"] = BIN_CODEC.name
        s["transport"] = "shm"
        return s


# ---------------------------------------------------------------------------
# bundle handoff ring (the Bundler's pluggable sink)
# ---------------------------------------------------------------------------

class BundleRing:
    """Same-host bundle handoff: fused result bundles as raw ndarray bytes.

    The consumer (learner side) creates the ring and owns its lifetime;
    producers attach by registry path and push with
    :meth:`push_bundle`, which NEVER blocks — when the consumer lags and
    the ring fills, the handoff is simply dropped because the bundle
    file just written by the Bundler remains the durable record (and the
    ``load_since`` cursor source).  One producer process at a time
    (SPSC ring); threads within that process are serialized by the
    ring's producer lock.
    """

    def __init__(self, path: str, capacity: int = 1 << 24,
                 create: bool = False, connect_timeout: float = 5.0):
        self.path = path
        if create:
            self._ring = ShmRing(create=True, capacity=int(capacity))
            self._owner = True
            seg = self._ring.name

            def init(doc: dict) -> None:
                doc["segment"] = seg
                doc["capacity"] = int(capacity)
                doc["epoch"] = int(doc.get("epoch", 0)) + 1
                doc["pid"] = os.getpid()

            jsonstore.update_json(self.path, init, strict=True)
        else:
            deadline = time.monotonic() + connect_timeout
            while True:
                doc = jsonstore.load_json(self.path, default=None)
                if isinstance(doc, dict) and doc.get("segment"):
                    break
                if time.monotonic() >= deadline:
                    raise BrokerUnavailable(
                        f"no bundle ring registry at {self.path}")
                time.sleep(0.02)
            self._ring = ShmRing(name=doc["segment"])
            self._owner = False

    def push_bundle(self, lo: int, hi: int,
                    results: Dict[str, Any]) -> bool:
        """Non-blocking handoff; False when the ring is full or the
        bundle exceeds its capacity (the file write already happened)."""
        frame = BIN_CODEC.encode(
            {"lo": int(lo), "hi": int(hi),
             "arrays": {k: np.asarray(v) for k, v in results.items()}})
        try:
            return self._ring.try_push(frame)
        except ValueError:
            return False  # bundle bigger than the ring: file-only handoff

    def pop_bundle(self, timeout: float = 0.0
                   ) -> Optional[Tuple[int, int, Dict[str, np.ndarray]]]:
        raw = self._ring.pop(timeout=timeout)
        if raw is None:
            return None
        doc = BIN_CODEC.decode(raw)
        return int(doc["lo"]), int(doc["hi"]), dict(doc["arrays"])

    def drain(self) -> List[Tuple[int, int, Dict[str, np.ndarray]]]:
        out = []
        while True:
            item = self.pop_bundle(timeout=0.0)
            if item is None:
                return out
            out.append(item)

    def close(self) -> None:
        self._ring.close()
        if self._owner:
            self._ring.unlink()

    def __enter__(self) -> "BundleRing":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
