"""The cascading calibrate->forecast archetype (paper Sec. 3.3) — as ONE
declarative DAG.

Per metro area (a DAG *parameter*, Fig. 1): a pre-ensemble of epidemic
simulations over sampled parameter sets (*samples*) runs against observed
case data; a per-metro selection step scores the fits and keeps the best
draws (an ABC-style posterior); the posterior feeds per-(metro, scenario)
forecast ensembles whose results a packaging step reduces to quantile
bands.

What used to be "phase 2" — a nested ``runtime.run()`` launched from
inside the selection worker — is now an ordinary pair of graph edges:

    presim[METRO] ──→ select[METRO] ──→ forecast[METRO, SCENARIO]
                                              │
                                              └──→ package[METRO, SCENARIO]

``select`` publishes its posterior as a named sample set scoped to its
metro (``ctx.publish_samples("posterior", ...)``); the ``forecast`` nodes
declare ``sample_set="posterior"`` and expand over the extra SCENARIO
parameter, so the DAG compiler's edge matching fans one select instance
out to all of its metro's scenario forecasts.  Parameters (metro x
scenario) stay in the DAG; draws stay samples — the layering that made
this workflow "both intuitive and scalable".
"""
from __future__ import annotations

import json
import os
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.bundler import Bundler
from repro.core.ensemble import EnsembleExecutor
from repro.core.runtime import MerlinRuntime
from repro.core.spec import Step, StudySpec


class CalibrationCascade:
    def __init__(self, runtime: MerlinRuntime, simulator: Callable,
                 observed: Dict[str, np.ndarray], n_calib: int = 64,
                 n_posterior: int = 16, scenarios: Optional[Dict[str, Dict]] = None,
                 seed: int = 0):
        """observed: metro -> daily case curve to calibrate against."""
        self.rt = runtime
        self.sim = simulator
        self.observed = observed
        self.n_calib = n_calib
        self.n_post = n_posterior
        self.scenarios = scenarios or {
            "baseline": {"compliance": 0.0},
            "moderate_npi": {"compliance": 0.45},
            "strong_npi": {"compliance": 0.75},
        }
        self.seed = seed
        self.results: Dict[str, Dict] = {}
        self.bundlers: Dict[str, Bundler] = {}
        runtime.register("epi_calibrate", self._calib_sim_step)
        runtime.register("epi_select", self._select_step)
        runtime.register("epi_forecast", self._forecast_sim_step)
        runtime.register("epi_package", self._package_step)

    def spec(self) -> StudySpec:
        """The whole cascade as one multi-stage DAG spec."""
        return StudySpec(
            name="covid-cascade",
            steps=[
                Step(name="presim", fn="epi_calibrate", params=("METRO",)),
                Step(name="select", fn="epi_select", depends=("presim",),
                     over_samples=False, params=("METRO",)),
                Step(name="forecast", fn="epi_forecast", depends=("select",),
                     params=("METRO", "SCENARIO"), sample_set="posterior"),
                Step(name="package", fn="epi_package", depends=("forecast",),
                     over_samples=False, params=("METRO", "SCENARIO")),
            ],
            parameters={"METRO": sorted(self.observed),
                        "SCENARIO": sorted(self.scenarios)})

    def start(self) -> str:
        rng = np.random.default_rng(self.seed)
        samples = rng.uniform(0, 1, (self.n_calib, 6)).astype(np.float32)
        return self.rt.run(self.spec(), samples)

    def _bundler(self, phase: str, metro: str) -> Bundler:
        key = f"{phase}/{metro}"
        if key not in self.bundlers:
            self.bundlers[key] = Bundler(
                os.path.join(self.rt.workspace, "epi", phase, metro))
        return self.bundlers[key]

    def _calib_sim_step(self, ctx) -> None:
        metro = ctx.combo["METRO"]
        # fresh executor objects are cheap: compiled simulators live in the
        # process-wide cache, so per-step construction reuses XLA programs
        ex = EnsembleExecutor(self.sim, self._bundler("calib", metro))
        ex.run_bundle(ctx.lo, ctx.hi, ctx.sample_block,
                      sub_ranges=ctx.sub_ranges)

    def _select_step(self, ctx) -> None:
        """ABC selection; publishing the posterior IS the phase-2 launch —
        completion of this node unlocks the forecast edges, which iterate
        the published set."""
        metro = ctx.combo["METRO"]
        data = self._bundler("calib", metro).load_all()
        obs = self.observed[metro]
        err = np.mean((data["daily_cases"] - obs[None, :]) ** 2, axis=1)
        keep = np.argsort(err)[: self.n_post]
        posterior = data["inputs"][keep]
        self.results.setdefault(metro, {})["posterior_rmse"] = float(
            np.sqrt(err[keep].mean()))
        ctx.publish_samples("posterior", posterior.astype(np.float32))

    def _forecast_sim_step(self, ctx) -> None:
        metro = ctx.combo["METRO"]
        scen = ctx.combo["SCENARIO"]
        block = np.array(ctx.sample_block)
        comp = self.scenarios[scen]["compliance"]
        block[:, 4] = comp / 0.8  # overwrite compliance dim (rescaled [0,0.8])
        ex = EnsembleExecutor(self.sim, self._bundler(f"fc_{scen}", metro))
        ex.run_bundle(ctx.lo, ctx.hi, block, sub_ranges=ctx.sub_ranges)

    def _package_step(self, ctx) -> None:
        metro = ctx.combo["METRO"]
        scen = ctx.combo["SCENARIO"]
        data = self._bundler(f"fc_{scen}", metro).load_all()
        daily = data["daily_cases"]
        qs = np.quantile(daily, [0.1, 0.5, 0.9], axis=0)
        out = {"metro": metro, "scenario": scen,
               "peak_median": float(np.median(data["peak_cases"])),
               "attack_median": float(np.median(data["attack_rate"]))}
        self.results.setdefault(metro, {})[scen] = out
        path = os.path.join(ctx.workspace, "forecast.json")
        with open(path, "w") as f:
            json.dump({**out, "q10": qs[0].tolist(), "q50": qs[1].tolist(),
                       "q90": qs[2].tolist()}, f)
