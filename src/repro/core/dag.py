"""Task-graph IR — what a :class:`~repro.core.spec.StudySpec` compiles to.

The spec surface stays Maestro-flavored YAML/dataclasses; *this* module is
the explicit graph the runtime executes.  ``compile_dag`` turns the
step list into :class:`DagNode` s with arbitrary fan-in/fan-out:

* **Chain fusion** — maximal linear runs of sample-parallel steps with a
  single plain edge between them and identical routing (queue, handler,
  sample set, params) collapse into ONE node.  A fused node executes all
  its steps back-to-back per sample bundle, which is exactly the old
  linear planner's "parallel stage" behavior — ``sim → post`` costs one
  task per bundle, not two.
* **Instances** — each node expands over the study parameters *projected*
  onto its ``params`` subset (ordered dedup; ``params: []`` → a single
  instance, ``params: None`` → every combo).  The instance index is what
  the wire payloads call ``combo``.
* **Edges** — resolved to the instance level.  A plain edge matches
  parent/child instances on the parameter keys both sides share (same
  combo when they share everything, broadcast fan-out/fan-in when the
  child adds or drops keys, all-to-all when they share nothing); a
  ``_*`` edge funnels every parent instance into every child instance.

Diamonds, fan-in, fan-out, and per-node queue/handler annotations all
fall out of this representation; the old ``plan_stages`` list could
express none of them.  Validation raises :class:`~repro.core.spec.SpecError`
with real messages — never a bare assert.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .spec import SpecError, Step, StudySpec, expand_parameters, strip_zip, topo_order

NodeInst = Tuple[int, int]  # (node index, instance index)


@dataclasses.dataclass
class DagEdge:
    src: int               # parent node index
    dst: int               # child node index
    funnel: bool = False   # True for "parent_*": all parent instances


@dataclasses.dataclass
class DagNode:
    idx: int
    steps: List[Step]                      # ≥1; >1 when chain-fused
    kind: str                              # "parallel" (per-bundle) | "single"
    params: Optional[Tuple[str, ...]]      # projected param keys; None = all
    sample_set: str
    queue: Optional[str]
    handler: str
    max_retries: int
    resources: Dict[str, Any]
    timeout: Optional[float] = None        # per-execution wall clock
    on_failure: str = "retry"              # action at retry exhaustion
    instances: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    in_edges: List[DagEdge] = dataclasses.field(default_factory=list)
    out_edges: List[DagEdge] = dataclasses.field(default_factory=list)

    @property
    def name(self) -> str:
        return "+".join(s.name for s in self.steps)

    def param_keys(self, all_keys: Sequence[str]) -> Tuple[str, ...]:
        return tuple(all_keys) if self.params is None else self.params


@dataclasses.dataclass
class TaskDag:
    spec: StudySpec
    nodes: List[DagNode]
    combos: List[Dict[str, Any]]           # full study-level expansion
    node_of_step: Dict[str, int]

    # -- instance-level graph -------------------------------------------------

    def instance_parents(self, nidx: int, iidx: int) -> List[NodeInst]:
        """Every (node, instance) that must complete before (nidx, iidx)."""
        node = self.nodes[nidx]
        child = node.instances[iidx]
        out: List[NodeInst] = []
        for e in node.in_edges:
            parent = self.nodes[e.src]
            if e.funnel:
                out.extend((e.src, i) for i in range(len(parent.instances)))
                continue
            all_keys = self._all_keys()
            shared = set(parent.param_keys(all_keys)) & set(node.param_keys(all_keys))
            for i, pinst in enumerate(parent.instances):
                if all(pinst[k] == child[k] for k in shared):
                    out.append((e.src, i))
        return out

    def instance_children(self, nidx: int, iidx: int) -> List[NodeInst]:
        """Every (node, instance) that waits on (nidx, iidx) — the out-edge
        set a completing worker must consider unlocking."""
        out: List[NodeInst] = []
        for e in self.nodes[nidx].out_edges:
            child = self.nodes[e.dst]
            for j in range(len(child.instances)):
                if (nidx, iidx) in self.instance_parents(e.dst, j):
                    out.append((e.dst, j))
        return out

    def indegree(self, nidx: int, iidx: int) -> int:
        return len(self.instance_parents(nidx, iidx))

    def roots(self) -> List[NodeInst]:
        return [(n.idx, i) for n in self.nodes
                for i in range(len(n.instances)) if not n.in_edges]

    def all_instances(self) -> List[NodeInst]:
        return [(n.idx, i) for n in self.nodes
                for i in range(len(n.instances))]

    def kinds(self) -> List[str]:
        return [n.kind for n in self.nodes]

    def _all_keys(self) -> Tuple[str, ...]:
        return tuple(sorted(strip_zip(k) for k in self.spec.parameters))

    # -- persistence ----------------------------------------------------------

    def to_doc(self) -> Dict[str, Any]:
        """A JSON-able structural summary for the persisted state file —
        enough for ``merlin-status`` / ``attach`` to name nodes without
        re-deserializing the spec."""
        return {
            "study": self.spec.name,
            "nodes": [{
                "idx": n.idx,
                "name": n.name,
                "steps": [s.name for s in n.steps],
                "kind": n.kind,
                "handler": n.handler,
                "queue": n.queue,
                "sample_set": n.sample_set,
                "max_retries": n.max_retries,
                "timeout": n.timeout,
                "on_failure": n.on_failure,
                "n_instances": len(n.instances),
                "in": [[e.src, e.funnel] for e in n.in_edges],
                "out": [[e.dst, e.funnel] for e in n.out_edges],
            } for n in self.nodes],
        }


def _project(combos: List[Dict[str, Any]],
             keys: Optional[Tuple[str, ...]]) -> List[Dict[str, Any]]:
    """Ordered-dedup projection of the full combo list onto ``keys``."""
    if keys is None:
        return [dict(c) for c in combos]
    seen = set()
    out: List[Dict[str, Any]] = []
    for c in combos:
        proj = {k: c[k] for k in keys}
        sig = tuple(proj[k] for k in keys)
        if sig not in seen:
            seen.add(sig)
            out.append(proj)
    return out


def _fuse_key(s: Step) -> Tuple:
    # timeout/on_failure are part of the key: steps with different failure
    # policies must not chain-fuse into one node (a fused node has exactly
    # one policy).
    return (s.queue, s.handler_name(), s.sample_set, s.params,
            tuple(sorted(s.resources.items())), s.timeout, s.on_failure)


def compile_dag(spec: StudySpec,
                combos: Optional[List[Dict[str, Any]]] = None) -> TaskDag:
    """Validate ``spec`` and lower it to a :class:`TaskDag`.

    Raises :class:`~repro.core.spec.SpecError` on any structural problem;
    the message names the offending step and rule.
    """
    spec.validate()
    order = topo_order(spec)
    by_name = {s.name: s for s in order}

    # step-level edge lists (dep name, funnel flag)
    step_parents: Dict[str, List[Tuple[str, bool]]] = {}
    out_degree: Dict[str, int] = {s.name: 0 for s in order}
    for s in order:
        plist: List[Tuple[str, bool]] = []
        seen_dep = set()
        for d in s.depends:
            funnel = d.endswith("_*")
            base = d[:-2] if funnel else d
            if base in seen_dep:
                raise SpecError(
                    f"step '{s.name}': duplicate dependency on '{base}'")
            seen_dep.add(base)
            plist.append((base, funnel))
            out_degree[base] += 1
        step_parents[s.name] = plist

    # -- chain fusion: append step to its single plain parent's node when the
    # parent is that node's tail, has out-degree 1, and routing matches.
    nodes: List[DagNode] = []
    node_of_step: Dict[str, int] = {}
    for s in order:
        plist = step_parents[s.name]
        fused = False
        if (s.over_samples and len(plist) == 1 and not plist[0][1]
                and out_degree[plist[0][0]] == 1):
            pname = plist[0][0]
            parent_step = by_name[pname]
            pnode = nodes[node_of_step[pname]]
            if (parent_step.over_samples
                    and pnode.steps[-1].name == pname
                    and _fuse_key(parent_step) == _fuse_key(s)):
                pnode.steps.append(s)
                pnode.max_retries = max(pnode.max_retries, s.max_retries)
                node_of_step[s.name] = pnode.idx
                fused = True
        if not fused:
            nodes.append(DagNode(
                idx=len(nodes),
                steps=[s],
                kind="parallel" if s.over_samples else "single",
                params=s.params,
                sample_set=s.sample_set,
                queue=s.queue,
                handler=s.handler_name(),
                max_retries=s.max_retries,
                resources=dict(s.resources),
                timeout=s.timeout,
                on_failure=s.on_failure,
            ))
            node_of_step[s.name] = nodes[-1].idx

    # -- node-level edges (skip intra-node chain edges, dedup parallel edges)
    edge_seen: Dict[Tuple[int, int], DagEdge] = {}
    for s in order:
        dst = node_of_step[s.name]
        for base, funnel in step_parents[s.name]:
            src = node_of_step[base]
            if src == dst:
                continue  # fused chain edge
            key = (src, dst)
            if key in edge_seen:
                # funnel wins: it is the weaker (superset) wait
                edge_seen[key].funnel = edge_seen[key].funnel or funnel
                continue
            e = DagEdge(src=src, dst=dst, funnel=funnel)
            edge_seen[key] = e
            nodes[src].out_edges.append(e)
            nodes[dst].in_edges.append(e)

    combos = expand_parameters(spec) if combos is None else combos
    for n in nodes:
        n.instances = _project(combos, n.params)
        if not n.instances:
            n.instances = [{}]

    dag = TaskDag(spec=spec, nodes=nodes, combos=combos,
                  node_of_step=node_of_step)

    # -- arity validation: every non-root instance must have ≥1 parent
    # instance, or it would deadlock forever.
    for n in nodes:
        if not n.in_edges:
            continue
        for i in range(len(n.instances)):
            if not dag.instance_parents(n.idx, i):
                raise SpecError(
                    f"step '{n.name}' instance {n.instances[i]!r} matches no "
                    f"parent instance on its dependency edges — it would "
                    f"never unlock (check 'params' subsets or use a "
                    f"'_*' funnel)")
    return dag
