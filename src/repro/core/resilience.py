"""Resilience: crawl-and-resubmit, retry policy, straggler mitigation.

Paper Sec. 3.1: the 100M-simulation run initially completed ~70% (node and
filesystem failures); a pass that crawled the directory tree and resubmitted
missing simulations to the Rabbit queue raised it to 85%, a final pass to
99.755%.  ``crawl_and_resubmit`` is that pass: diff the bundler's on-disk
truth against the expected index space and enqueue only the missing ranges
(at real-task priority — recovery work drains first).

Straggler mitigation: ``SpeculativeReissuer`` duplicates tasks that have
been in flight longer than ``dup_after`` (the backup-task trick); the
runtime's once-markers make duplicated execution a no-op, so first-finisher
wins without coordination.

All of this works against ANY Broker — including a remote NetBroker: the
crawler only needs ``put`` and the reissuer uses the protocol's
``inflight_tasks()`` snapshot instead of poking backend internals, so the
recovery pass can run from a machine that shares neither the queue
directory nor the broker process.

``CursorCrawler`` is the incremental variant of ``crawl_and_resubmit``: it
delta-reads the archive via ``Bundler.load_since(cursor)`` so a sweep costs
only the bundles that appeared since the previous sweep, not a full
re-walk + decompress of the tree.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Dict, Optional, Set, Tuple

from repro.core.bundler import Bundler, missing_samples
from repro.core.queue import (PRIORITY_REAL, BrokerUnavailable, Task,
                              new_task)


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 3
    backoff_s: float = 0.0

    def should_retry(self, task: Task) -> bool:
        return task.retries < self.max_retries


@dataclasses.dataclass
class BackoffPolicy:
    """Jittered exponential backoff: ``delay(attempt)`` for attempt 0, 1, ...

    ``base * multiplier**attempt`` capped at ``cap``, then multiplied by a
    uniform factor in ``[1 - jitter, 1]`` so a fleet of workers that failed
    together doesn't retry in lockstep.  The one home for retry cadence —
    worker broker-error loops and NetBroker reconnects both use it instead
    of hand-rolled constants.
    """
    base: float = 0.05
    cap: float = 1.0
    multiplier: float = 2.0
    jitter: float = 0.25
    rng: Optional[random.Random] = None

    def delay(self, attempt: int) -> float:
        d = min(self.cap, self.base * self.multiplier ** max(0, attempt))
        if self.jitter > 0:
            r = self.rng.random() if self.rng is not None else random.random()
            d *= 1.0 - self.jitter * r
        return d


class CircuitBreaker:
    """Per-endpoint circuit breaker: closed → open → half-open.

    ``failure_threshold`` consecutive hard failures open the circuit;
    while open, ``allow()`` returns False (callers fail fast instead of
    burning their full reconnect window against a dead endpoint).  After
    ``reset_timeout`` seconds one probe call is let through (half-open):
    its ``record_success`` closes the circuit, its ``record_failure``
    re-opens it for another window.  Thread-safe.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, failure_threshold: int = 3,
                 reset_timeout: float = 1.0):
        self.failure_threshold = max(1, failure_threshold)
        self.reset_timeout = reset_timeout
        self._lock = threading.Lock()
        self._state = self.CLOSED
        self._failures = 0
        self._opened_at = 0.0

    @property
    def state(self) -> str:
        with self._lock:
            self._maybe_half_open()
            return self._state

    def _maybe_half_open(self) -> None:
        if self._state == self.OPEN and \
                time.monotonic() - self._opened_at >= self.reset_timeout:
            self._state = self.HALF_OPEN

    def allow(self) -> bool:
        """May a call proceed right now?  In half-open, lets probes through
        (their outcome decides whether the circuit closes or re-opens)."""
        with self._lock:
            self._maybe_half_open()
            return self._state != self.OPEN

    def record_success(self) -> None:
        with self._lock:
            self._state = self.CLOSED
            self._failures = 0

    def record_failure(self) -> None:
        with self._lock:
            self._maybe_half_open()
            if self._state == self.HALF_OPEN:
                self._state = self.OPEN
                self._opened_at = time.monotonic()
                return
            self._failures += 1
            if self._failures >= self.failure_threshold:
                self._state = self.OPEN
                self._opened_at = time.monotonic()


def crawl_and_resubmit(bundler: Bundler, expected_n: int, broker,
                       task_template: dict, bundle: int,
                       queue: Optional[str] = None) -> Tuple[int, int]:
    """Diff disk vs expectation; enqueue missing ranges. Returns
    (n_missing_samples, n_tasks_enqueued).

    Recovery tasks are routed onto the study's real-task queue (from the
    template's ``real_queue`` key unless ``queue`` overrides it), so a
    deployment whose simulation workers subscribe to a named queue actually
    receives the resubmissions.
    """
    if queue is None:
        queue = task_template.get("real_queue", "default")
    present, corrupt = bundler.crawl()
    # corrupt files count as missing: drop their ids
    for path in corrupt:
        pass  # ids unreadable; covered by the expected-set diff below
    ranges = missing_samples(expected_n, present)
    return (sum(hi - lo for lo, hi in ranges),
            _enqueue_ranges(broker, ranges, task_template, bundle, queue))


def _iter_bundle_chunks(ranges, bundle: int):
    """Split [lo, hi) ranges into (s, e) chunks — the ONE place chunking
    lives, so resubmission granularity (and CursorCrawler's cooldown keys)
    can never diverge from what gets enqueued.

    Chunk boundaries snap to the absolute ``bundle`` grid (matching the
    hierarchy's leaf layout) rather than running from each range's lo:
    grid chunks are STABLE as a hole shrinks from either end, so the
    crawler's per-chunk cooldown keys keep matching across sweeps instead
    of being reminted every time part of a range completes."""
    for lo, hi in ranges:
        s = lo
        while s < hi:
            e = min(hi, (s // bundle + 1) * bundle)
            yield s, e
            s = e


def _enqueue_ranges(broker, ranges, task_template: dict, bundle: int,
                    queue: str) -> int:
    """Enqueue missing ranges as bundle-sized real tasks (bundle-sized so
    redelivery granularity is unchanged)."""
    n_tasks = 0
    for s, e in _iter_bundle_chunks(ranges, bundle):
        broker.put(new_task("real", {**task_template, "samples": [s, e]},
                            priority=PRIORITY_REAL, queue=queue))
        n_tasks += 1
    return n_tasks


class CursorCrawler:
    """Incremental crawl-and-resubmit for a long-running recovery loop.

    ``crawl_and_resubmit`` re-walks and re-reads the whole archive on every
    call — fine for a one-shot pass, quadratic for a periodic sweeper.
    This crawler holds a ``Bundler.load_since`` cursor: each ``sweep()``
    decompresses only bundles that appeared since the last sweep, folds
    their sample ids into the running ``present`` set, and enqueues what is
    still missing.

    A range already resubmitted is not re-enqueued until it has stayed
    missing for ``resubmit_after`` further sweeps (duplicates are *safe* —
    once-markers — just wasteful).
    """

    def __init__(self, bundler: Bundler, expected_n: int,
                 resubmit_after: int = 2):
        self.bundler = bundler
        self.expected_n = expected_n
        self.resubmit_after = max(1, resubmit_after)
        self._cursor = None
        self._present: Set[int] = set()
        self._submitted: Dict[Tuple[int, int], int] = {}
        self._sweep_i = 0

    @property
    def present(self) -> Set[int]:
        return set(self._present)

    def sweep(self, broker, task_template: dict, bundle: int,
              queue: Optional[str] = None) -> Tuple[int, int]:
        """Delta-read the archive, resubmit missing ranges.

        Returns ``(n_missing_samples, n_tasks_enqueued)``."""
        self._sweep_i += 1
        data, self._cursor = self.bundler.load_since(self._cursor)
        ids = data.get("_sample_ids")
        if ids is not None:
            self._present.update(int(i) for i in ids)
        ranges = missing_samples(self.expected_n, self._present)
        n_missing = sum(hi - lo for lo, hi in ranges)
        if queue is None:
            queue = task_template.get("real_queue", "default")
        n_tasks = 0
        still_missing: Dict[Tuple[int, int], int] = {}
        for s, e in _iter_bundle_chunks(ranges, bundle):
            last = self._submitted.get((s, e))
            if last is None or self._sweep_i - last >= self.resubmit_after:
                broker.put(new_task(
                    "real", {**task_template, "samples": [s, e]},
                    priority=PRIORITY_REAL, queue=queue))
                last = self._sweep_i
                n_tasks += 1
            still_missing[(s, e)] = last
        # completed chunks never go missing again (present only grows):
        # keeping only still-missing keys bounds the cooldown map
        self._submitted = still_missing
        return n_missing, n_tasks


class SpeculativeReissuer:
    """Duplicate-issue tasks stuck in flight (straggler mitigation).

    Uses the Broker protocol's ``inflight_tasks()`` snapshot, so it works
    identically against every backend — including a remote NetBroker.
    Execution idempotency (runtime once-markers) makes duplicates safe.
    """

    def __init__(self, broker, dup_after: float = 5.0, max_dups: int = 1):
        self.broker = broker
        self.dup_after = dup_after
        self.max_dups = max_dups
        self._dups: dict = {}

    def scan_once(self) -> int:
        try:
            items = self.broker.inflight_tasks()
        except BrokerUnavailable:
            return 0  # broker briefly down: reissue on the next scan
        n = 0
        for task, age in items:
            if age > self.dup_after and \
                    self._dups.get(task.id, 0) < self.max_dups:
                dup = new_task(task.kind, dict(task.payload),
                               priority=task.priority, queue=task.queue)
                self.broker.put(dup)
                self._dups[task.id] = self._dups.get(task.id, 0) + 1
                n += 1
        return n
