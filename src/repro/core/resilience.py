"""Resilience: crawl-and-resubmit, retry policy, straggler mitigation.

Paper Sec. 3.1: the 100M-simulation run initially completed ~70% (node and
filesystem failures); a pass that crawled the directory tree and resubmitted
missing simulations to the Rabbit queue raised it to 85%, a final pass to
99.755%.  ``crawl_and_resubmit`` is that pass: diff the bundler's on-disk
truth against the expected index space and enqueue only the missing ranges
(at real-task priority — recovery work drains first).

Straggler mitigation: ``SpeculativeReissuer`` duplicates tasks that have
been in flight longer than ``dup_after`` (the backup-task trick); the
runtime's once-markers make duplicated execution a no-op, so first-finisher
wins without coordination.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, List, Optional, Set, Tuple

from repro.core.bundler import Bundler, missing_samples
from repro.core.queue import PRIORITY_REAL, Task, new_task


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 3
    backoff_s: float = 0.0

    def should_retry(self, task: Task) -> bool:
        return task.retries < self.max_retries


def crawl_and_resubmit(bundler: Bundler, expected_n: int, broker,
                       task_template: dict, bundle: int,
                       queue: Optional[str] = None) -> Tuple[int, int]:
    """Diff disk vs expectation; enqueue missing ranges. Returns
    (n_missing_samples, n_tasks_enqueued).

    Recovery tasks are routed onto the study's real-task queue (from the
    template's ``real_queue`` key unless ``queue`` overrides it), so a
    deployment whose simulation workers subscribe to a named queue actually
    receives the resubmissions.
    """
    if queue is None:
        queue = task_template.get("real_queue", "default")
    present, corrupt = bundler.crawl()
    # corrupt files count as missing: drop their ids
    for path in corrupt:
        pass  # ids unreadable; covered by the expected-set diff below
    ranges = missing_samples(expected_n, present)
    n_missing = sum(hi - lo for lo, hi in ranges)
    n_tasks = 0
    for lo, hi in ranges:
        # split to bundle-sized tasks so redelivery granularity is unchanged
        s = lo
        while s < hi:
            e = min(s + bundle, hi)
            broker.put(new_task("real", {**task_template, "samples": [s, e]},
                                priority=PRIORITY_REAL, queue=queue))
            n_tasks += 1
            s = e
    return n_missing, n_tasks


class SpeculativeReissuer:
    """Duplicate-issue tasks stuck in flight (straggler mitigation).

    Works with InMemoryBroker: inspects the leased table and re-enqueues
    copies of tasks leased longer than ``dup_after`` seconds.  Execution
    idempotency (runtime once-markers) makes the duplicate safe.
    """

    def __init__(self, broker, dup_after: float = 5.0, max_dups: int = 1):
        self.broker = broker
        self.dup_after = dup_after
        self.max_dups = max_dups
        self._dups: dict = {}

    def scan_once(self) -> int:
        n = 0
        leased = getattr(self.broker, "_leased", None)
        if leased is None:
            return 0
        now = time.monotonic()
        with self.broker._lock:
            items = list(leased.items())
        for tag, (task, deadline) in items:
            vt = getattr(self.broker, "_vt", 60.0)
            leased_at = deadline - vt
            if now - leased_at > self.dup_after and \
                    self._dups.get(task.id, 0) < self.max_dups:
                dup = new_task(task.kind, dict(task.payload),
                               priority=task.priority, queue=task.queue)
                self.broker.put(dup)
                self._dups[task.id] = self._dups.get(task.id, 0) + 1
                n += 1
        return n
