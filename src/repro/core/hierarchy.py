"""The hierarchical task-generation algorithm (paper Sec. 2.2, Fig. 2).

``merlin run`` enqueues ONE root generation task holding only the metadata
needed to create its children; consumers recursively expand the bounded-
fanout tree until the leaves — the real sample bundles — are enqueued.
Because real tasks outrank generation tasks (PRIORITY_REAL < PRIORITY_GEN,
lower drains first — the paper prioritizes *draining* the queue over
*filling* it), the queue self-throttles: simulations start as soon as the
first leaf exists (Fig. 4) and the server never holds more than
O(fanout · depth · workers) undone generation messages (the "server
stability" property of Sec. 2.2).

The same index-space hierarchy is reused on-device: a leaf's [start, stop)
range becomes the batch slice of a vmapped simulator bundle
(core/ensemble.py) — the TPU adaptation documented in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterator, List, Tuple

from repro.core.queue import PRIORITY_GEN, PRIORITY_REAL, Task, new_task


@dataclasses.dataclass(frozen=True)
class HierarchyCfg:
    max_fanout: int = 16      # max children per generation task
    bundle: int = 1           # samples per leaf (real) task


def depth_for(n_leaves: int, fanout: int) -> int:
    if n_leaves <= 1:
        return 0
    return max(1, math.ceil(math.log(n_leaves, fanout)))


def n_gen_tasks(n_samples: int, cfg: HierarchyCfg) -> int:
    """Total generation (non-leaf) tasks the hierarchy will create."""
    leaves = math.ceil(n_samples / cfg.bundle)
    total = 0
    level = leaves
    while level > 1:
        level = math.ceil(level / cfg.max_fanout)
        total += level
    return max(total, 1 if leaves > 1 else 0)


def _queues(payload: dict) -> Tuple[str, str]:
    """Named-queue routing carried in the payload (set by the runtime).

    ``real_queue``/``gen_queue`` keys propagate through every level of the
    hierarchy so leaves land on the simulation queue and interior generator
    tasks on the generation queue (paper Sec. 2.2 routing-key semantics).
    """
    return (payload.get("real_queue", "default"),
            payload.get("gen_queue", "default"))


def root_task(study: str, step: str, n_samples: int, cfg: HierarchyCfg,
              extra: dict | None = None) -> Task:
    """The single message `merlin run` sends (metadata only)."""
    payload = {"study": study, "step": step, "lo": 0, "hi": n_samples,
               "fanout": cfg.max_fanout, "bundle": cfg.bundle,
               **(extra or {})}
    real_q, gen_q = _queues(payload)
    n_leaves = math.ceil(n_samples / cfg.bundle)
    if n_leaves <= 1:
        return new_task("real", {**payload, "samples": [0, n_samples]},
                        priority=PRIORITY_REAL, queue=real_q)
    return new_task("gen", payload, priority=PRIORITY_GEN, queue=gen_q)


def expand(task: Task) -> List[Task]:
    """Expand one generation task into its children (executed by a worker).

    Children covering more than one bundle are generation tasks; children
    covering a single bundle are real tasks.
    """
    p = task.payload
    lo, hi, fanout, bundle = p["lo"], p["hi"], p["fanout"], p["bundle"]
    real_q, gen_q = _queues(p)
    n_leaves = math.ceil((hi - lo) / bundle)
    extra = {k: v for k, v in p.items()
             if k not in ("lo", "hi", "fanout", "bundle", "samples")}
    children: List[Task] = []
    if n_leaves <= fanout:
        # bottom of the tree: enqueue the real sample bundles
        for i in range(n_leaves):
            s_lo = lo + i * bundle
            s_hi = min(lo + (i + 1) * bundle, hi)
            children.append(new_task(
                "real", {**extra, "fanout": fanout, "bundle": bundle,
                         "samples": [s_lo, s_hi]},
                priority=PRIORITY_REAL, queue=real_q))
        return children
    # split into <= fanout contiguous child ranges, each spanning a whole
    # power-of-fanout number of leaves: children at every level then carry
    # full fanout-sized subtrees (bottom generators emit `fanout` real
    # tasks), keeping total generation-task count ~ n_leaves/(fanout-1) —
    # the paper's "hierarchical grouping of multiple levels" (Fig. 2).
    # Integer arithmetic: float log rounds up on exact powers, which would
    # make leaves_per_child == n_leaves (a self-identical child -> loop).
    leaves_per_child = 1
    while leaves_per_child * fanout < n_leaves:
        leaves_per_child *= fanout
    span = leaves_per_child * bundle
    start = lo
    while start < hi:
        stop = min(start + span, hi)
        children.append(new_task(
            "gen", {**extra, "lo": start, "hi": stop, "fanout": fanout,
                    "bundle": bundle},
            priority=PRIORITY_GEN, queue=gen_q))
        start = stop
    return children


def iter_leaves(n_samples: int, cfg: HierarchyCfg) -> Iterator[Tuple[int, int]]:
    """All leaf (lo, hi) sample ranges, in order (for verification/crawling)."""
    for i in range(math.ceil(n_samples / cfg.bundle)):
        yield i * cfg.bundle, min((i + 1) * cfg.bundle, n_samples)
