"""Process-level runtime environment, applied once at entry.

Every perf-sensitive entrypoint (``benchmarks/run.py``, ``broker-serve``,
the drain/worker subprocess mains) used to inherit whatever environment
it was launched with: JAX picking its own x64/platform defaults, BLAS
and XLA each spawning their idea of a thread pool, allocator choice
unrecorded.  On an HPC node that's both a throughput problem (thread
oversubscription on shared cores) and a reproducibility problem — two
"identical" benchmark runs on differently-tuned shells are not
comparable, and nothing in the artifact said so.

:func:`configure` is the one place this is decided.  It is intentionally
boring: read ``REPRO_*`` environment overrides, apply deterministic
defaults, record everything it did, and never do it twice.  The returned
snapshot is embedded in ``BENCH_*.json`` meta so every committed number
carries the environment that produced it.

Knobs (call argument > ``REPRO_*`` env var > default):

========================  =======================  =========================
argument                  env var                  effect
========================  =======================  =========================
``x64``                   ``REPRO_X64``            ``JAX_ENABLE_X64`` (or
                                                   ``jax.config`` when jax
                                                   is already imported)
``platform``              ``REPRO_PLATFORM``       ``JAX_PLATFORMS``
``host_device_count``     ``REPRO_HOST_DEVICES``   ``--xla_force_host_``
                                                   ``platform_device_count``
                                                   in ``XLA_FLAGS``
``threads``               ``REPRO_THREADS``        OMP/OpenBLAS/MKL/numexpr
                                                   thread counts (default:
                                                   physical ``cpu_count``)
``extra_xla_flags``       ``REPRO_XLA_FLAGS``      appended to ``XLA_FLAGS``
``debug_nans``            ``REPRO_DEBUG_NANS``     ``JAX_DEBUG_NANS``
========================  =======================  =========================

Thread pinning uses ``setdefault``: an operator who already exported
``OMP_NUM_THREADS=4`` wins over our default, but an unpinned shell gets
a deterministic count instead of library roulette.  XLA/JAX env flags
only take effect when set *before* ``import jax`` — when jax is already
imported, :func:`configure` falls back to ``jax.config.update`` for the
knobs that support it and records ``"jax_preimported": true`` so a
late application is visible in the artifact rather than silently
ineffective.  tcmalloc is detect-only (we never dlopen): if the
launcher preloaded it (the classic ``LD_PRELOAD=libtcmalloc.so.4``
HPC idiom), the snapshot says so and the large-alloc report threshold
is defaulted to keep it quiet.

This module must stay importable without jax — ``broker-serve`` and the
drain workers are jax-free processes.
"""
from __future__ import annotations

import os
import sys
from typing import Any, Dict, Optional

_THREAD_VARS = ("OMP_NUM_THREADS", "OPENBLAS_NUM_THREADS",
                "MKL_NUM_THREADS", "NUMEXPR_NUM_THREADS")
_DEVICE_FLAG = "--xla_force_host_platform_device_count"

_applied: Optional[Dict[str, Any]] = None


def _env_bool(name: str) -> Optional[bool]:
    v = os.environ.get(name)
    if v is None or v == "":
        return None
    return v.strip().lower() not in ("0", "false", "no", "off")


def _env_int(name: str) -> Optional[int]:
    v = os.environ.get(name)
    if v is None or v == "":
        return None
    try:
        return int(v)
    except ValueError:
        return None


def _tcmalloc_loaded() -> bool:
    if "tcmalloc" in os.environ.get("LD_PRELOAD", ""):
        return True
    try:
        with open("/proc/self/maps") as f:
            return any("tcmalloc" in line for line in f)
    except OSError:
        return False


def configure(x64: Optional[bool] = None, platform: Optional[str] = None,
              host_device_count: Optional[int] = None,
              threads: Optional[int] = None,
              extra_xla_flags: Optional[str] = None,
              debug_nans: Optional[bool] = None) -> Dict[str, Any]:
    """Apply the runtime environment once; return the recorded snapshot.

    Idempotent: the second and later calls in a process return the
    first call's snapshot unchanged (entrypoints can all call it without
    coordinating about who runs first).
    """
    global _applied
    if _applied is not None:
        return dict(_applied)

    jax_preimported = "jax" in sys.modules
    if x64 is None:
        x64 = _env_bool("REPRO_X64")
    if platform is None:
        platform = os.environ.get("REPRO_PLATFORM") or None
    if host_device_count is None:
        host_device_count = _env_int("REPRO_HOST_DEVICES")
    if threads is None:
        threads = _env_int("REPRO_THREADS")
    if threads is None:
        threads = os.cpu_count() or 1
    if extra_xla_flags is None:
        extra_xla_flags = os.environ.get("REPRO_XLA_FLAGS") or None
    if debug_nans is None:
        debug_nans = _env_bool("REPRO_DEBUG_NANS")

    # deterministic thread pinning: an explicit operator export wins,
    # an unpinned shell gets one recorded count everywhere
    pinned: Dict[str, str] = {}
    for var in _THREAD_VARS:
        os.environ.setdefault(var, str(threads))
        pinned[var] = os.environ[var]

    xla_parts = [f for f in os.environ.get("XLA_FLAGS", "").split() if f]
    if host_device_count is not None and not jax_preimported \
            and not any(p.startswith(_DEVICE_FLAG) for p in xla_parts):
        xla_parts.append(f"{_DEVICE_FLAG}={int(host_device_count)}")
    if extra_xla_flags and not jax_preimported:
        xla_parts.extend(f for f in extra_xla_flags.split()
                         if f not in xla_parts)
    if xla_parts:
        os.environ["XLA_FLAGS"] = " ".join(xla_parts)

    if not jax_preimported:
        if x64 is not None:
            os.environ["JAX_ENABLE_X64"] = "1" if x64 else "0"
        if platform:
            os.environ["JAX_PLATFORMS"] = platform
        if debug_nans is not None:
            os.environ["JAX_DEBUG_NANS"] = "1" if debug_nans else "0"
    else:
        # too late for env/XLA flags; apply what jax.config still honors
        import jax
        if x64 is not None:
            jax.config.update("jax_enable_x64", bool(x64))
        if debug_nans is not None:
            jax.config.update("jax_debug_nans", bool(debug_nans))

    tcmalloc = _tcmalloc_loaded()
    if tcmalloc:
        # silence per-allocation report spam on big arrays (128 GiB bar)
        os.environ.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                              str(128 << 30))

    _applied = {
        "x64": x64,
        "platform": platform,
        "host_device_count": host_device_count,
        "threads": int(threads),
        "thread_pins": pinned,
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "debug_nans": debug_nans,
        "tcmalloc": tcmalloc,
        "jax_preimported": jax_preimported,
    }
    return dict(_applied)


def snapshot() -> Dict[str, Any]:
    """The applied environment (configuring with defaults on first use),
    for embedding in benchmark artifacts."""
    return configure()


def _reset_for_tests() -> None:
    global _applied
    _applied = None
