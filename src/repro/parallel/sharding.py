"""Logical-axis sharding rules.

Arrays are annotated with *logical* axis names; a rule table maps those to
physical mesh axes.  Mapping is divisibility-aware: a logical axis whose
dimension does not divide by the physical axis size falls back to
replication (this is what lets phi4's 24 heads / whisper's 6 heads /
granite's 49155-vocab compile on a 16-way `model` axis without special
cases — the projections stay sharded on their flat dims and GSPMD inserts
the resharding).

Use :func:`activation_rules` as a context (thread-local) inside jitted
functions; :func:`constrain` is a no-op outside of it, so single-device
smoke tests run the same code path.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_TLS = threading.local()

# default logical -> physical axis mapping (production mesh)
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "tensor": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "mlp": ("model",),
    "kv_seq": ("model",),
    "seq": (),
    "dp_only": ("pod", "data", "model"),  # whisper-style pure-DP batch
}


class ShardCtx:
    def __init__(self, mesh: Mesh, rules: Dict[str, Tuple[str, ...]]):
        self.mesh = mesh
        self.rules = rules


def current() -> Optional[ShardCtx]:
    return getattr(_TLS, "ctx", None)


@contextlib.contextmanager
def activation_rules(mesh: Optional[Mesh], rules: Optional[Dict] = None):
    prev = getattr(_TLS, "ctx", None)
    if mesh is None:
        _TLS.ctx = None
    else:
        r = dict(DEFAULT_RULES)
        if rules:
            r.update(rules)
        # drop mesh axes that don't exist (single-pod mesh has no "pod")
        r = {k: tuple(a for a in v if a in mesh.shape) for k, v in r.items()}
        _TLS.ctx = ShardCtx(mesh, r)
    try:
        yield
    finally:
        _TLS.ctx = prev


def _fit_axes(dim: int, phys: Tuple[str, ...], mesh: Mesh) -> Tuple[str, ...]:
    """Largest prefix of `phys` whose product divides `dim`."""
    out = []
    size = 1
    for a in phys:
        s = mesh.shape[a]
        if dim % (size * s) == 0:
            out.append(a)
            size *= s
        else:
            break
    return tuple(out)


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
             mesh: Mesh, rules: Dict[str, Tuple[str, ...]]) -> P:
    assert len(shape) == len(logical), (shape, logical)
    parts = []
    used = set()
    for dim, name in zip(shape, logical):
        if name is None:
            parts.append(None)
            continue
        phys = tuple(a for a in rules.get(name, ()) if a in mesh.shape and a not in used)
        phys = _fit_axes(dim, phys, mesh)
        used.update(phys)
        parts.append(phys if len(phys) != 1 else phys[0])
        if not phys:
            parts[-1] = None
    return P(*parts)


def constrain(x, *logical: Optional[str]):
    """Apply a logical sharding constraint if a mesh context is active."""
    ctx = current()
    if ctx is None:
        return x
    spec = spec_for(x.shape, logical, ctx.mesh, ctx.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding by leaf path
# ---------------------------------------------------------------------------

# last-path-component -> logical axes by rank (applied right-aligned)
_PARAM_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # vocab-sharded only: fsdp-sharding the d_model dim makes GSPMD fully
    # rematerialize the token gather (measured: +18 GB temp on multi-pod);
    # worst case replicated-dim cost is 295 MB/chip (gemma2)
    "embed": ("vocab", None),
    "lm_head": ("fsdp", "vocab"),
    "pos_embed": (None, "fsdp"),
    # (in, out)-shaped projections
    "wq": ("fsdp", "tensor"), "wk": ("fsdp", "tensor"), "wv": ("fsdp", "tensor"),
    "wi": ("fsdp", "tensor"), "wg": ("fsdp", "tensor"), "wr": ("fsdp", "tensor"),
    "wkv_a": ("fsdp", "tensor"), "wk_rope": ("fsdp", None),
    "wk_b": ("fsdp", "tensor"), "wv_b": ("fsdp", "tensor"),
    "w_in": ("fsdp", "tensor"), "ck": ("fsdp", "tensor"),
    "cr": ("fsdp", "tensor"), "w_router": ("fsdp", None),
    "w_lora_a": ("fsdp", None), "wg_gate": ("fsdp", "tensor"),
    "w_img": ("fsdp", "tensor"),
    # (out, in)-shaped projections
    "wo": ("tensor", "fsdp"), "cv": ("tensor", "fsdp"),
    "w_out": ("tensor", "fsdp"), "w_lora_b": (None, "fsdp"),
    # experts
    "experts_wi": ("experts", "fsdp", None),
    "experts_wg": ("experts", "fsdp", None),
    "experts_wo": ("experts", None, "fsdp"),
    # conv / small
    "conv_w": (None, "tensor"),
    "u": (None, None),
}


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh,
               rules: Dict[str, Tuple[str, ...]]) -> P:
    name = path[-1]
    logical = _PARAM_RULES.get(name)
    if logical is None:
        logical = (None,) * len(shape)  # norms, scalars, biases: replicate
    # scanned stacks have a leading layer dim
    extra = len(shape) - len(logical)
    if extra > 0:
        logical = (None,) * extra + tuple(logical)
    elif extra < 0:
        logical = logical[-len(shape):] if len(shape) else ()
    return spec_for(shape, logical, mesh, rules)


def param_spec_tree(params, mesh: Mesh, rules: Optional[Dict] = None):
    r = dict(DEFAULT_RULES)
    if rules:
        r.update(rules)
    r = {k: tuple(a for a in v if a in mesh.shape) for k, v in r.items()}

    def f(path, leaf):
        keys = tuple(getattr(k, "key", getattr(k, "idx", str(k))) for k in path)
        keys = tuple(str(k) for k in keys)
        return NamedSharding(mesh, param_spec(keys, leaf.shape, mesh, r))

    return jax.tree_util.tree_map_with_path(f, params)
