"""Collective helpers: wire-level gradient compression via shard_map.

``int8_psum`` is the mechanism behind the EF-int8 optimizer wrapper
(train/optimizer.ef_compress): each shard quantizes its contribution to
int8 with a shared absmax scale, the all-reduce moves int8+scale payloads
(4x fewer wire bytes than fp32; the sum itself is widened to int32 to
avoid overflow, which ring implementations keep at int8 per hop), and the
result is dequantized locally.  On this CPU host it is validated for
*semantics* on a forced multi-device mesh (tests/test_collectives.py);
on a real pod the same code shrinks the cross-pod DCI gradient traffic,
which is the collective-roofline lever for multi-pod data parallelism.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def _quantize(x: jnp.ndarray, qmax: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def int8_psum(x: jnp.ndarray, axis_name: str, bits: int = 8) -> jnp.ndarray:
    """Inside shard_map: all-reduce `x` over `axis_name` with int8 payloads.

    Scales are all-reduced first (max), so every shard quantizes against the
    same scale and the integer sum is exact up to quantization.
    """
    qmax = float(2 ** (bits - 1) - 1)
    gmax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.maximum(gmax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(x.dtype) * scale


def compressed_grad_allreduce(grads, mesh: Mesh, axis: str = "data",
                              bits: int = 8):
    """All-reduce a replicated-per-shard gradient pytree with int8 payloads.

    Grads enter sharded over `axis` on their leading dim (per-shard partial
    gradients); leave fully reduced and replicated.
    """
    def one(g):
        @functools.partial(
            shard_map, mesh=mesh, in_specs=P(axis),
            out_specs=P(), check_rep=False)
        def reduce_fn(gs):
            return int8_psum(gs.sum(axis=0), axis, bits=bits)

        return reduce_fn(g)

    return jax.tree.map(one, grads)
