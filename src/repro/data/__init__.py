from repro.data.pipeline import SyntheticTokens, ensemble_token_stream, regression_dataset  # noqa
