"""Data pipelines.

* :class:`SyntheticTokens` — deterministic, step-indexed token stream
  (splitmix-style integer hashing: batch for step k is a pure function of
  (seed, k, host_shard), so a restarted/rescaled job replays identical data
  — the data-side requirement of checkpoint-restart fault tolerance).

* :func:`ensemble_token_stream` — the ML-readiness step of the paper: turn
  the bundler's simulation archives into LM training batches by quantizing
  each record's (inputs, scalars) into vocab bins — the "tokenized
  simulation record" format used to train the jag-surrogate.

* :func:`regression_dataset` — (features, targets) arrays for the
  surrogate-regression path used by the optimization-loop example.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np


class SyntheticTokens:
    def __init__(self, batch: int, seq: int, vocab: int, seed: int = 0,
                 n_hosts: int = 1, host_id: int = 0, extras: Optional[Dict] = None):
        self.batch, self.seq, self.vocab = batch, seq, vocab
        self.seed, self.n_hosts, self.host_id = seed, n_hosts, host_id
        self.extras = extras or {}
        self._step = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        n = self.batch * (self.seq + 1)
        mix = (step * 0x9E3779B97F4A7C15 + self.seed * 0xBF58476D1CE4E5B9
               + self.host_id) % (1 << 64)
        base = np.arange(n, dtype=np.uint64) + np.uint64(mix)
        z = base
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        z = z ^ (z >> np.uint64(31))
        toks = (z % np.uint64(self.vocab)).astype(np.int32)
        toks = toks.reshape(self.batch, self.seq + 1)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        for k, (shape, dtype) in self.extras.items():
            rng = np.random.default_rng(step * 1000 + self.seed)
            out[k] = (rng.standard_normal((self.batch,) + tuple(shape[1:]))
                      * 0.02).astype(dtype)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self._step)
        self._step += 1
        return b


def quantize_records(inputs: np.ndarray, scalars: np.ndarray, vocab: int,
                     bins_per_field: int = 256) -> np.ndarray:
    """Batched record quantization: (n, ...) inputs + (n, k) scalars ->
    (n, nf) token matrix in one vectorized op (no per-record Python loop).

    Each record's fields are binned into ``bins_per_field`` levels with
    per-field offsets so fields occupy disjoint vocab ranges."""
    inputs = np.asarray(inputs)
    scalars = np.asarray(scalars)
    n = len(inputs)
    fields = np.concatenate([inputs.reshape(n, -1), scalars.reshape(n, -1)],
                            axis=1)
    nf = fields.shape[1]
    assert nf * bins_per_field <= vocab, (nf, bins_per_field, vocab)
    q = np.clip((fields * bins_per_field).astype(np.int64), 0,
                bins_per_field - 1)
    return (q + np.arange(nf) * bins_per_field).astype(np.int32)


def quantize_record(inputs: np.ndarray, scalars: np.ndarray, vocab: int,
                    bins_per_field: int = 256) -> np.ndarray:
    """One simulation record -> token sequence: [field0_bin, field1_bin, ...]
    (single-record view of :func:`quantize_records`)."""
    return quantize_records(np.asarray(inputs)[None], np.asarray(scalars)[None],
                            vocab, bins_per_field)[0]


def tokenize_archive(data: Dict[str, np.ndarray], scalar_keys: Sequence[str],
                     vocab: int, bins_per_field: int = 256) -> np.ndarray:
    """Tokenize a whole loaded archive once: normalization and quantization
    each run exactly one vectorized pass over the stacked fields (the seed
    called ``quantize_record`` n times and re-derived normalization state on
    every stream construction)."""
    scal = np.stack([_normalize(data[k]) for k in scalar_keys], axis=1)
    return quantize_records(data["inputs"], scal, vocab, bins_per_field)


def ensemble_token_stream(data: Dict[str, np.ndarray], scalar_keys: Sequence[str],
                          batch: int, vocab: int, seed: int = 0
                          ) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite stream of LM batches built from a loaded ensemble archive.

    The archive is tokenized once up front (:func:`tokenize_archive`); each
    yielded batch is a pure gather from the precomputed token matrix."""
    records = tokenize_archive(data, scalar_keys, vocab)
    n = len(records)
    rng = np.random.default_rng(seed)
    while True:
        idx = rng.integers(0, n, size=batch)
        toks = records[idx]
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def regression_dataset(data: Dict[str, np.ndarray], target: str = "yield",
                       drop_failed: bool = True):
    X = np.asarray(data["inputs"], np.float32)
    y = np.asarray(data[target], np.float32)
    if drop_failed:
        ok = np.isfinite(y)
        if "failed" in data:
            ok &= data["failed"] < 0.5
        X, y = X[ok], y[ok]
    return X, _normalize(y)


def _normalize(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, np.float32)
    lo, hi = np.nanmin(x), np.nanmax(x)
    if hi - lo < 1e-12:
        return np.zeros_like(x)
    return (x - lo) / (hi - lo)
