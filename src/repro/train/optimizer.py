"""Optimizers (no optax offline): AdamW and Adafactor, pytree-based.

Optimizer state mirrors the parameter pytree, so the parameter sharding
specs apply leaf-for-leaf — with FSDP-sharded params this IS ZeRO-style
optimizer-state sharding (each data shard owns the moments of its parameter
shard; XLA's SPMD partitioner keeps the update local).

Adafactor (factored second moments) is the default for arctic-480b — the
memory math is in DESIGN.md.  ``ef_compress`` wraps any optimizer with
int8 error-feedback gradient compression (the residual is carried in the
state; see parallel/collectives.py for the wire-level shard_map variant).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


def _tree_zeros_like(tree, dtype=None):
    return jax.tree.map(lambda a: jnp.zeros(a.shape, dtype or a.dtype), tree)


def adamw(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, wd: float = 0.01, clip_norm: float = 1.0):
    def init(params):
        return {"m": _tree_zeros_like(params, jnp.float32),
                "v": _tree_zeros_like(params, jnp.float32),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        grads = _clip_by_global_norm(grads, clip_norm)
        b1t = 1 - b1 ** step.astype(jnp.float32)
        b2t = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / b1t
            vh = v / b2t
            delta = mh / (jnp.sqrt(vh) + eps) + wd * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "step": step}

    return Optimizer(init, update)


def adafactor(lr: float = 1e-3, decay: float = 0.99, eps: float = 1e-30,
              clip_norm: float = 1.0, min_dim_factored: int = 2):
    """Factored second moments for >=2D leaves; O(rows+cols) state."""

    def init(params):
        def leaf_state(p):
            if p.ndim >= min_dim_factored:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(leaf_state, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        grads = _clip_by_global_norm(grads, clip_norm)

        def upd(g, fs, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if p.ndim >= min_dim_factored:
                vr = decay * fs["vr"] + (1 - decay) * g2.mean(-1)
                vc = decay * fs["vc"] + (1 - decay) * g2.mean(-2)
                denom = (vr[..., :, None] * vc[..., None, :]) / \
                    jnp.maximum(vr.mean(-1)[..., None, None], eps)
                pre = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
                nfs = {"vr": vr, "vc": vc}
            else:
                v = decay * fs["v"] + (1 - decay) * g2
                pre = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                nfs = {"v": v}
            # update clipping (RMS <= 1), Shazeer & Stern
            rms = jnp.sqrt(jnp.mean(jnp.square(pre)) + 1e-12)
            pre = pre / jnp.maximum(1.0, rms)
            return (p.astype(jnp.float32) - lr * pre).astype(p.dtype), nfs

        is_fs = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        out = jax.tree.map(upd, grads, state["f"], params,
                           is_leaf=lambda x: False)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_f = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"f": new_f, "step": step}

    return Optimizer(init, update)


def ef_compress(base: Optimizer, bits: int = 8):
    """Int8 error-feedback gradient compression wrapper.

    Quantizes gradients (per-leaf absmax scale) before the optimizer and
    carries the quantization residual to the next step — 1-bit/8-bit EF-SGD
    convergence behaviour.  On the wire this corresponds to int8 all-reduce
    payloads (see parallel/collectives.int8_psum for the shard_map
    mechanism); here the quantization is applied at the math level so the
    convergence effect is testable on any backend.
    """
    qmax = float(2 ** (bits - 1) - 1)

    def init(params):
        return {"base": base.init(params),
                "ef": _tree_zeros_like(params, jnp.float32)}

    def update(grads, state, params):
        def q(g, e):
            g = g.astype(jnp.float32) + e
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / qmax
            qg = jnp.round(g / scale).clip(-qmax, qmax) * scale
            return qg, g - qg
        out = jax.tree.map(q, grads, state["ef"])
        qgrads = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        ef = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_p, new_base = base.update(qgrads, state["base"], params)
        return new_p, {"base": new_base, "ef": ef}

    return Optimizer(init, update)


def make_optimizer(name: str, lr: float = 1e-3, compress: bool = False,
                   **kw) -> Optimizer:
    opt = adafactor(lr=lr, **kw) if name == "adafactor" else adamw(lr=lr, **kw)
    return ef_compress(opt) if compress else opt


def _clip_by_global_norm(grads, max_norm):
    if max_norm is None:
        return grads
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * factor).astype(g.dtype),
                        grads)
