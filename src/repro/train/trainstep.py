"""The jitted training step: microbatched gradient accumulation, remat,
sharding-aware — the function the multi-pod dry-run lowers.

``global_batch`` is split into ``cfg.microbatch`` accumulation slices and
scanned; each slice's forward/backward runs under the activation sharding
rules, XLA overlapping the per-layer reduce-scatters/all-gathers of the
FSDP parameters with the scan's compute (latency hiding across microbatch
iterations).  Parameters stay fp32 (master); compute casts to bf16 inside
the model.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.parallel.sharding import activation_rules
from repro.train.optimizer import Optimizer, make_optimizer


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jnp.ndarray


def init_state(rng, cfg: ModelConfig, optimizer: Optional[Optimizer] = None
               ) -> TrainState:
    optimizer = optimizer or make_optimizer(cfg.optimizer)
    params = lm.init_params(rng, cfg)
    return TrainState(params, optimizer.init(params), jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, optimizer: Optional[Optimizer] = None,
                    mesh=None, rules=None, donate: bool = True):
    optimizer = optimizer or make_optimizer(cfg.optimizer)
    n_mb = max(1, cfg.microbatch)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        with activation_rules(mesh, rules):
            def split(x):  # (B, ...) -> (n_mb, B/n_mb, ...)
                return x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:])

            mbs = jax.tree.map(split, batch)

            acc_dt = jnp.dtype(cfg.accum_dtype)

            def mb_grad(carry, mb):
                gsum, lsum = carry
                (loss, metrics), g = jax.value_and_grad(
                    lm.loss_fn, has_aux=True)(state.params, mb, cfg)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), gsum, g)
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt),
                              state.params)
            (gsum, lsum), _ = jax.lax.scan(mb_grad, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / n_mb, gsum)
            new_params, new_opt = optimizer.update(grads, state.opt,
                                                   state.params)
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                                 for g in jax.tree.leaves(grads)))
            metrics = {"loss": lsum / n_mb, "grad_norm": gnorm,
                       "step": state.step + 1}
            return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_eval_step(cfg: ModelConfig, mesh=None, rules=None):
    def eval_step(params, batch):
        with activation_rules(mesh, rules):
            loss, metrics = lm.loss_fn(params, batch, cfg)
        return metrics
    return eval_step
