"""Host-side training loop with checkpoint/restart fault tolerance.

The loop is crash-safe: state is checkpointed every ``ckpt_every`` steps
(async, atomic); ``Trainer.restore_or_init`` resumes from the latest
checkpoint — kill the process at any step and relaunch, and training
continues (tests/test_trainer.py does exactly that).  Per-step wall times
are journaled; steps slower than ``straggler_factor``x the running median
are counted and surfaced (on real fleets this feeds the reissue policy).
"""
from __future__ import annotations

import os
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.train.optimizer import make_optimizer
from repro.train.trainstep import TrainState, init_state, make_train_step


class Trainer:
    def __init__(self, cfg: ModelConfig, workdir: str, data: Iterator,
                 mesh=None, rules=None, lr: float = 3e-4,
                 ckpt_every: int = 20, keep: int = 3,
                 straggler_factor: float = 3.0, seed: int = 0):
        self.cfg = cfg
        self.workdir = workdir
        os.makedirs(workdir, exist_ok=True)
        self.data = data
        self.optimizer = make_optimizer(cfg.optimizer, lr=lr)
        self.step_fn = jax.jit(make_train_step(cfg, self.optimizer, mesh, rules))
        self.ckpt = CheckpointManager(os.path.join(workdir, "ckpt"), keep=keep)
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.seed = seed
        self.step_times: list = []
        self.stragglers = 0
        self.history: list = []

    def restore_or_init(self) -> TrainState:
        latest = self.ckpt.latest_step()
        template = jax.eval_shape(
            lambda: init_state(jax.random.PRNGKey(self.seed), self.cfg,
                               self.optimizer))
        if latest is not None:
            state = self.ckpt.restore(latest, like=template)
            return TrainState(*state)
        return init_state(jax.random.PRNGKey(self.seed), self.cfg,
                          self.optimizer)

    def train(self, num_steps: int, state: Optional[TrainState] = None
              ) -> TrainState:
        state = state if state is not None else self.restore_or_init()
        start = int(state.step)
        for i in range(start, num_steps):
            batch = next(self.data)
            t0 = time.monotonic()
            state, metrics = self.step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            self.step_times.append(dt)
            med = float(np.median(self.step_times[-50:]))
            if len(self.step_times) > 5 and dt > self.straggler_factor * med:
                self.stragglers += 1
            self.history.append({"step": i + 1, "loss": loss, "dt": dt})
            if (i + 1) % self.ckpt_every == 0 or (i + 1) == num_steps:
                self.ckpt.save(i + 1, tuple(state))
        self.ckpt.wait()
        return state
