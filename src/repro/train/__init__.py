from repro.train.optimizer import adamw, adafactor, make_optimizer  # noqa
from repro.train.trainstep import TrainState, make_train_step, init_state  # noqa
from repro.train.trainer import Trainer  # noqa
