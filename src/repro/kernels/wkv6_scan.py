"""Pallas TPU kernel: RWKV6 (Finch) WKV chunked scan.

Grid (batch, head, time-chunk), chunk innermost; the (D x D) linear-attention
state is carried in VMEM scratch.  Per-channel data-dependent decays make the
intra-chunk term a 3-tensor (t, s, d) contraction; with chunk=64 and D=64 the
(t,s,d) working set is 1 MB fp32 — tiled to fit VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, s_scr, *, chunk):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, :, 0, :].astype(jnp.float32)   # (L, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    lw = lw_ref[0, :, 0, :].astype(jnp.float32)  # log-decay, <= 0
    u = u_ref[0].astype(jnp.float32)             # (D,)

    ecl = jnp.cumsum(lw, axis=0) - lw            # exclusive cumsum (L, D)
    cl = ecl + lw
    L = chunk
    # intra-chunk: att[t,s] = sum_d r[t,d] exp(ecl_t - cl_s) k[s,d],  s < t
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    expo = ecl[:, None, :] - cl[None, :, :]      # (t, s, D)
    expo = jnp.where(tri[:, :, None], expo, -jnp.inf)
    att = jnp.einsum("td,tsd,sd->ts", r, jnp.exp(expo), k)
    y = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())))  # (t, D)
    # bonus for the current token
    bonus = ((r * u[None, :]) * k).sum(axis=1, keepdims=True)  # (t, 1)
    y += bonus * v
    # inter-chunk: y += (r_t * exp(ecl_t)) @ state
    s = s_scr[...]
    y += jax.lax.dot_general(r * jnp.exp(ecl), s, (((1,), (0,)), ((), ())))
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state <- diag(exp(cl_L)) state + sum_s exp(cl_L - cl_s) k_s v_s^T
    tailw = jnp.exp(cl[-1:, :] - cl)             # (L, D)
    G = jax.lax.dot_general(k * tailw, v, (((0,), (0,)), ((), ())))  # (D, D)
    s_scr[...] = s * jnp.exp(cl[-1])[:, None] + G


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_scan(r, k, v, w, u, *, chunk=64, interpret=False):
    """r,k,v,w: (B,S,H,D); u: (H,D) -> (B,S,H,D)."""
    B, S, H, D = r.shape
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    lw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-12, 1.0))
    grid = (B, H, nc)
    spec = pl.BlockSpec((1, chunk, 1, D), lambda b, h, c: (b, c, h, 0))
    y = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, D), lambda b, h, c: (h, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, S, H, D), r.dtype),
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u)
    return y
