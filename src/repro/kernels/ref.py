"""Pure-jnp oracles for every Pallas kernel.

``flash_attention_ref`` is also the production fallback path on non-TPU
backends (and the dry-run lowering path): it is chunked over KV blocks with
an online softmax, so its memory behaviour is flash-like (O(S·block) rather
than O(S^2)) — important for the 32k/500k assigned shapes.

``naive_attention`` is the tiny-scale golden oracle used by kernel tests.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _apply_softcap(logits, cap):
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def naive_attention(q, k, v, *, causal=True, scale=None, softcap_val=None,
                    window=None, q_pos0=0):
    """O(S^2)-memory oracle. q: (B,S,H,D), k/v: (B,T,KV,D)."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qf = q.reshape(B, S, KV, g, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    logits = jnp.einsum("bskgd,btkd->bkgst", qf, kf) * scale
    logits = _apply_softcap(logits, softcap_val)
    qpos = (jnp.arange(S) + q_pos0)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "softcap_val", "window", "q_pos0", "block_k"))
def flash_attention_ref(q, k, v, *, causal=True, scale=None, softcap_val=None,
                        window=None, q_pos0=0, block_k=1024):
    """Flash-style chunked attention (online softmax over KV blocks)."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    bk = min(block_k, T)
    n_blocks = (T + bk - 1) // bk
    Tp = n_blocks * bk
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    kb = k.reshape(B, n_blocks, bk, KV, D)
    vb = v.reshape(B, n_blocks, bk, KV, D)
    qf = (q.reshape(B, S, KV, g, D) * scale).astype(jnp.float32)
    qpos = (jnp.arange(S) + q_pos0)[:, None]

    def body(carry, blk):
        m, l, acc = carry
        kc, vc, start = blk
        logits = jnp.einsum("bskgd,btkd->bkgst", qf, kc.astype(jnp.float32))
        logits = _apply_softcap(logits, softcap_val)
        kpos = start + jnp.arange(bk)[None, :]
        mask = kpos < T
        if causal:
            mask = mask & (qpos >= kpos)
        if window is not None:
            mask = mask & ((qpos - kpos) < window)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, g, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, g, S), jnp.float32)
    a0 = jnp.zeros((B, KV, g, S, D), jnp.float32)
    starts = jnp.arange(n_blocks) * bk
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out.reshape(B, KV * g, S, D), 1, 2)  # (B,S,H,D) w/ H=KV*g
    return out.astype(q.dtype)


def decode_attention_ref(q, ck, cv, *, kv_len, scale=None, softcap_val=None,
                         window=None):
    """Single-token decode attention over a (B, T, KV, D) cache.

    kv_len is the number of valid cache entries (the new token is at
    kv_len-1).  Memory is O(T) per head — fine up to 500k.
    """
    B, S, H, D = q.shape
    assert S == 1
    T, KV = ck.shape[1], ck.shape[2]
    g = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qf = q.reshape(B, KV, g, D).astype(jnp.float32)
    logits = jnp.einsum("bkgd,btkd->bkgt", qf, ck.astype(jnp.float32)) * scale
    logits = _apply_softcap(logits, softcap_val)
    t = jnp.arange(T)[None, None, None, :]
    mask = t < kv_len
    if window is not None:
        mask = mask & (t >= kv_len - window)
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", w, cv.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba2 SSD oracle
# ---------------------------------------------------------------------------

def ssd_scan_ref(x, dt, A, B_, C, *, chunk=None):
    """Mamba2 state-space dual, sequential-over-time oracle.

    x:  (B, S, H, P)   inputs per head        (P = head dim)
    dt: (B, S, H)      softplus-ed step sizes (>0)
    A:  (H,)           negative decay rates   (A < 0)
    B_: (B, S, N)      input->state projection (shared across heads)
    C:  (B, S, N)      state->output projection
    returns y: (B, S, H, P)
    state h: (B, H, P, N);  h_t = exp(A*dt) h_{t-1} + dt * x_t B_t^T
             y_t = h_t C_t
    """
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(Af[None, :, None, None] * dtt[:, :, None, None])
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], Bt)
        h = h * decay + upd
        y = jnp.einsum("bhpn,bn->bhp", h, Ct)
        return h, y

    h0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
                                    jnp.moveaxis(B_.astype(jnp.float32), 1, 0),
                                    jnp.moveaxis(C.astype(jnp.float32), 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def ssd_chunked_ref(x, dt, A, B_, C, *, chunk=64):
    """Chunked SSD (the algorithm the Pallas kernel implements): intra-chunk
    quadratic attention-like term + inter-chunk state recurrence."""
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    xf = x.astype(jnp.float32).reshape(Bb, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bb, nc, chunk, H)
    Bf = B_.astype(jnp.float32).reshape(Bb, nc, chunk, N)
    Cf = C.astype(jnp.float32).reshape(Bb, nc, chunk, N)
    Af = A.astype(jnp.float32)

    # per-position decay exponent within chunk: a_t = A*dt_t ; cumsum
    a = Af[None, None, None, :] * dtf  # (B,nc,L,H)
    acs = jnp.cumsum(a, axis=2)

    # intra-chunk: y_intra[t] = C_t . sum_{s<=t} exp(acs_t - acs_s) dt_s x_s B_s^T
    Lmask = jnp.tril(jnp.ones((chunk, chunk), bool))
    acs_h = acs.transpose(0, 1, 3, 2)  # (B,nc,H,L)
    diff = acs_h[..., :, None] - acs_h[..., None, :]  # (B,nc,H,t,s)
    decay_ts = jnp.exp(jnp.where(Lmask[None, None, None], diff, -jnp.inf))
    cb = jnp.einsum("bctn,bcsn->bcts", Cf, Bf)  # (B,nc,t,s)
    w = cb[:, :, None] * decay_ts
    y_intra = jnp.einsum("bchts,bcsh,bcshp->bcthp", w, dtf, xf)

    # chunk summary state: G_c = sum_s exp(acs_L - acs_s) dt_s x_s B_s^T
    tail = jnp.exp(acs[:, :, -1:, :] - acs)  # (B,nc,L,H)
    G = jnp.einsum("bcsh,bcshp,bcsn->bchpn", tail * dtf, xf, Bf)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(acs[:, :, -1, :])  # (B,nc,H) total decay of a chunk

    def step(h, inp):
        Gc, dc = inp
        h_out = h  # state entering this chunk
        h = h * dc[..., None, None] + Gc
        return h, h_out

    h0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    _, h_in = jax.lax.scan(step, h0, (jnp.moveaxis(G, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)  # (B,nc,H,P,N) state entering each chunk

    # inter-chunk contribution: y_inter[t] = C_t exp(acs_t) h_in
    y_inter = jnp.einsum("bcth,bctn,bchpn->bcthp", jnp.exp(acs), Cf, h_in)
    y = (y_intra + y_inter).reshape(Bb, S, H, P)
    return y.astype(x.dtype)


def ssd_decode_ref(h, x, dt, A, B_, C):
    """One decode step. h: (B,H,P,N); x: (B,H,P); dt: (B,H); B_,C: (B,N)."""
    decay = jnp.exp(A.astype(jnp.float32)[None, :, None, None] * dt[:, :, None, None])
    h = h * decay + jnp.einsum("bhp,bn->bhpn", x * dt[..., None], B_)
    y = jnp.einsum("bhpn,bn->bhp", h, C)
    return h, y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RWKV6 (Finch) WKV oracle
# ---------------------------------------------------------------------------

def wkv6_scan_ref(r, k, v, w, u):
    """RWKV6 time-mix core.

    r,k,v: (B, S, H, D);  w: (B, S, H, D) per-step decay in (0,1);
    u: (H, D) bonus for the current token.
    state S: (B, H, D, D);  out_t = r_t . (S + u * k_t v_t^T)
             S <- diag(w_t) S + k_t v_t^T
    """
    Bb, S, H, D = r.shape
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    wf, uf = w.astype(jnp.float32), u.astype(jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        out = jnp.einsum("bhd,bhde->bhe", rt, state + uf[None, :, :, None] * kv)
        state = state * wt[..., None] + kv
        return state, out

    s0 = jnp.zeros((Bb, H, D, D), jnp.float32)
    _, ys = jax.lax.scan(step, s0, tuple(
        jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf)))
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype)


def wkv6_chunked_ref(r, k, v, w, u, *, chunk=64):
    """Chunked WKV6 (the algorithm the Pallas kernel implements).

    Within a chunk the (t,s) interaction matrix is computed with per-channel
    log-decay differences; across chunks a (D,D) state is carried.
    decay(t,s) = prod_{j=s+1..t-1} w_j applied to k_s v_s^T for s < t;
    the current token contributes via the bonus u instead.
    """
    Bb, S, H, D = r.shape
    assert S % chunk == 0
    nc = S // chunk
    rf = r.astype(jnp.float32).reshape(Bb, nc, chunk, H, D)
    kf = k.astype(jnp.float32).reshape(Bb, nc, chunk, H, D)
    vf = v.astype(jnp.float32).reshape(Bb, nc, chunk, H, D)
    lw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-12, 1.0)).reshape(Bb, nc, chunk, H, D)
    uf = u.astype(jnp.float32)

    ecl = jnp.cumsum(lw, axis=2) - lw  # exclusive cumsum over time-in-chunk
    # intra-chunk strictly-lower-triangular interactions
    smask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # s < t
    # exponent(t,s,d) = ecl_t - ecl_s - lw_s
    e_t = ecl[:, :, :, None]          # (B,nc,t,1,H,D)
    e_s = (ecl + lw)[:, :, None]      # (B,nc,1,s,H,D)
    expo = jnp.where(smask[None, None, :, :, None, None], e_t - e_s, -jnp.inf)
    att = jnp.einsum("bcthd,bctshd,bcshd->bctsh", rf, jnp.exp(expo), kf)
    y_intra = jnp.einsum("bctsh,bcshe->bcthe", att, vf)
    # current-token bonus: out[t,e] = (sum_d r_t[d] u[d] k_t[d]) v_t[e]
    bonus = jnp.einsum("bcthd,hd,bcthd->bcth", rf, uf, kf)
    y_bonus = bonus[..., None] * vf

    # inter-chunk: carry (D,D) state; entering-state contribution decays by ecl_t
    # chunk summary: G = sum_s exp(cl_L - cl_s) k_s v_s^T  where cl = ecl + lw
    cl = ecl + lw
    tailw = jnp.exp(cl[:, :, -1:, :, :] - cl)  # (B,nc,L,H,D)
    G = jnp.einsum("bcshd,bcshe->bchde", tailw * kf, vf)
    chunk_decay = jnp.exp(cl[:, :, -1])  # (B,nc,H,D)

    def step(hst, inp):
        Gc, dc = inp
        h_out = hst
        hst = hst * dc[..., None] + Gc
        return hst, h_out

    h0 = jnp.zeros((Bb, H, D, D), jnp.float32)
    _, h_in = jax.lax.scan(step, h0, (jnp.moveaxis(G, 1, 0),
                                      jnp.moveaxis(chunk_decay, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)  # (B,nc,H,D,D)
    y_inter = jnp.einsum("bcthd,bchde->bcthe", rf * jnp.exp(ecl), h_in)

    y = (y_intra + y_bonus + y_inter).reshape(Bb, S, H, D)
    return y.astype(r.dtype)


def wkv6_blocked_ref(r, k, v, w, u, *, chunk=64, subchunk=16):
    """Blocked WKV6 (§Perf optimization; see EXPERIMENTS.md).

    The straightforward chunked form materializes a (t, s, D) decay tensor
    per chunk — O(S·L·D) bytes, the dominant memory-roofline term for rwkv6.
    Here the chunk is split into sub-blocks: *off-diagonal* (t-block,
    s-block) interactions factor per channel as

        exp(ecl_t - cl_s) = exp(ecl_t - c_j) * exp(c_j - cl_s),

    with c_j = cl at the *end* of s-block j, so both exponents are <= 0 for
    t-blocks after j (safe in fp32; exponents clamped at +-60 as a belt) and
    the D-contraction becomes an MXU matmul with no (t,s,D) intermediate.
    Only the small diagonal (subchunk x subchunk x D) blocks keep the exact
    pairwise form.  Math is identical to wkv6_scan_ref; tests compare both.
    """
    Bb, S, H, D = r.shape
    assert S % chunk == 0 and chunk % subchunk == 0
    nc, nb = S // chunk, chunk // subchunk
    L, Ls = chunk, subchunk
    # mixed precision (§Perf A4): the per-channel log-decay cumsum and the
    # recurrent state stay fp32 (accumulation accuracy); every (S x D)-sized
    # elementwise factor and matmul operand is bf16 — these tensors dominate
    # the memory roofline of the layer.
    cdt = r.dtype if jnp.issubdtype(r.dtype, jnp.floating) else jnp.bfloat16
    rf = r.astype(cdt).reshape(Bb, nc, nb, Ls, H, D)
    kf = k.astype(cdt).reshape(Bb, nc, nb, Ls, H, D)
    vf = v.astype(cdt).reshape(Bb, nc, nb, Ls, H, D)
    lw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-12, 1.0)) \
        .reshape(Bb, nc, nb, Ls, H, D)
    uf = u.astype(cdt)
    f32 = jnp.float32

    # per-chunk cumulative log decays (over the flattened chunk time axis)
    lw_c = lw.reshape(Bb, nc, L, H, D)
    cl = jnp.cumsum(lw_c, axis=2)               # inclusive, fp32
    ecl = cl - lw_c                              # exclusive
    cl_b = cl.reshape(Bb, nc, nb, Ls, H, D)
    ecl_b = ecl.reshape(Bb, nc, nb, Ls, H, D)
    cj = cl_b[:, :, :, -1]                       # (B,nc,nb,H,D): block-end

    # --- diagonal sub-blocks: exact pairwise form on (Ls, Ls, D) ----------
    smask = jnp.tril(jnp.ones((Ls, Ls), bool), k=-1)
    e_t = ecl_b[:, :, :, :, None]
    e_s = (cl_b)[:, :, :, None, :]
    expo = jnp.where(smask[None, None, None, :, :, None, None],
                     e_t - e_s, -jnp.inf)
    att_d = jnp.einsum("bcnthd,bcntshd,bcnshd->bcntsh", rf,
                       jnp.exp(expo).astype(cdt), kf).astype(cdt)
    y = jnp.einsum("bcntsh,bcnshe->bcnthe", att_d, vf).astype(f32)

    # --- off-diagonal: factored through block-end reference c_j -----------
    # q~[t] = r_t * exp(ecl_t - c_j)  ;  k~[s] = k_s * exp(c_j - cl_s)
    # both exponents <= 0 for t-block > s-block; clamp as safety
    ke = kf * jnp.exp(jnp.clip(cj[:, :, :, None] - cl_b, -60.0, 60.0)).astype(cdt)
    kv = jnp.einsum("bcnshd,bcnshe->bcnhde", ke, vf
                    ).astype(f32)  # per-block (D,E) states (sum over Ls=16)
    # prefix-accumulate block states, decayed to each later block's
    # reference: state entering block i (ref c_{i-1}) = sum_{j<i}
    # decay(c_{i-1}, c_j) kv_j.  nb is small (e.g. 4): unrolled python loop.
    state = jnp.zeros((Bb, nc, H, D, D), f32)
    ref = None
    for i in range(nb):
        if i > 0:
            # y_inter for block i from accumulated state (ref c_{i-1})
            qi = rf[:, :, i] * jnp.exp(
                jnp.clip(ecl_b[:, :, i] - ref[:, :, None], -120.0, 0.0)
            ).astype(cdt)
            y = y.at[:, :, i].add(
                jnp.einsum("bcthd,bchde->bcthe", qi,
                           state.astype(cdt)).astype(f32))
        # fold block i into the state, re-referenced to c_i
        if i == 0:
            state = kv[:, :, 0]
        else:
            decay = jnp.exp(jnp.clip(cj[:, :, i] - ref, -120.0, 0.0))
            state = state * decay[..., None] + kv[:, :, i]
        ref = cj[:, :, i]

    # --- current-token bonus ----------------------------------------------
    bonus = jnp.einsum("bcnthd,hd,bcnthd->bcnth", rf, uf, kf).astype(f32)
    y = y + bonus[..., None] * vf.astype(f32)

    # --- inter-chunk: carry full (D,D) state across chunks ------------------
    kf_c = kf.reshape(Bb, nc, L, H, D)
    vf_c = vf.reshape(Bb, nc, L, H, D)
    tailw = jnp.exp(jnp.clip(cl[:, :, -1:, :, :] - cl, -120.0, 0.0)).astype(cdt)
    G = jnp.einsum("bcshd,bcshe->bchde", tailw * kf_c, vf_c).astype(f32)
    chunk_decay = jnp.exp(cl[:, :, -1])

    def step(hst, inp):
        Gc, dc = inp
        h_out = hst
        hst = hst * dc[..., None] + Gc
        return hst, h_out

    h0 = jnp.zeros((Bb, H, D, D), f32)
    _, h_in = jax.lax.scan(step, h0, (jnp.moveaxis(G, 1, 0),
                                      jnp.moveaxis(chunk_decay, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)
    y_inter = jnp.einsum("bcthd,bchde->bcthe",
                         rf.reshape(Bb, nc, L, H, D)
                         * jnp.exp(ecl).astype(cdt),
                         h_in.astype(cdt)).astype(f32)
    y = y.reshape(Bb, nc, L, H, D) + y_inter
    return y.reshape(Bb, S, H, D).astype(r.dtype)


def wkv6_decode_ref(state, r, k, v, w, u):
    """One decode step. state: (B,H,D,D); r,k,v,w: (B,H,D); u: (H,D)."""
    kv = jnp.einsum("bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32))
    out = jnp.einsum("bhd,bhde->bhe", r.astype(jnp.float32),
                     state + u.astype(jnp.float32)[None, :, :, None] * kv)
    state = state * w.astype(jnp.float32)[..., None] + kv
    return state, out.astype(r.dtype)
