"""jit'd dispatching wrappers around the Pallas kernels.

Dispatch policy (``use_pallas``):
  * ``"auto"``      — Pallas kernel on TPU, chunked-jnp reference elsewhere
                      (CPU dry-run / tests / CI).
  * ``"never"``     — always the reference path.
  * ``"interpret"`` — Pallas kernel in interpret mode (kernel-correctness
                      tests on CPU).

The reference paths are flash/chunked implementations with the same
block-streaming memory behaviour as the kernels, so the dry-run HLO is
representative of the target algorithm, not of a naive O(S^2) fallback.
"""
from __future__ import annotations

import jax

from repro.kernels import ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def _mode(use_pallas: str) -> str:
    if use_pallas == "auto":
        return "pallas" if _on_tpu() else "ref"
    if use_pallas == "interpret":
        return "interpret"
    return "ref"


def flash_attention(q, k, v, *, causal=True, scale=None, softcap_val=None,
                    window=None, q_pos0=0, use_pallas="auto", block_q=128,
                    block_k=128):
    mode = _mode(use_pallas)
    if mode in ("pallas", "interpret"):
        from repro.kernels import flash_attention as fak
        return fak.flash_attention(
            q, k, v, causal=causal, scale=scale, softcap_val=softcap_val,
            window=window, q_pos0=q_pos0, block_q=block_q, block_k=block_k,
            interpret=(mode == "interpret"))
    return ref.flash_attention_ref(
        q, k, v, causal=causal, scale=scale, softcap_val=softcap_val,
        window=window, q_pos0=q_pos0)


def decode_attention(q, ck, cv, *, kv_len, scale=None, softcap_val=None,
                     window=None):
    return ref.decode_attention_ref(
        q, ck, cv, kv_len=kv_len, scale=scale, softcap_val=softcap_val,
        window=window)


def _pad_seq(arrs, seq_axis, chunk):
    """Pad each array along seq_axis to a multiple of chunk with zeros."""
    import jax.numpy as jnp
    S = arrs[0].shape[seq_axis]
    Sp = -(-S // chunk) * chunk
    if Sp == S:
        return arrs, S
    out = []
    for a in arrs:
        pad = [(0, 0)] * a.ndim
        pad[seq_axis] = (0, Sp - S)
        out.append(jnp.pad(a, pad))
    return out, S


def ssd_scan(x, dt, A, B_, C, *, chunk=128, use_pallas="auto"):
    mode = _mode(use_pallas)
    chunk = min(chunk, x.shape[1])
    # zero-pad ragged sequences: x=0, dt=0 contribute nothing to the state
    (x, dt, B_, C), S = _pad_seq((x, dt, B_, C), 1, chunk)
    if mode in ("pallas", "interpret"):
        from repro.kernels import ssd_scan as ssdk
        y = ssdk.ssd_scan(x, dt, A, B_, C, chunk=chunk,
                          interpret=(mode == "interpret"))
    else:
        y = ref.ssd_chunked_ref(x, dt, A, B_, C, chunk=chunk)
    return y[:, :S]


def ssd_decode(h, x, dt, A, B_, C):
    return ref.ssd_decode_ref(h, x, dt, A, B_, C)


def wkv6_scan(r, k, v, w, u, *, chunk=128, use_pallas="auto", impl="chunked",
              subchunk=16):
    import jax.numpy as jnp
    mode = _mode(use_pallas)
    chunk = min(chunk, r.shape[1])
    # pad ragged sequences: r/k/v = 0 and w = 1 (log-decay 0) are inert
    (r, k, v), S = _pad_seq((r, k, v), 1, chunk)
    if w.shape[1] != r.shape[1]:
        pad = [(0, 0)] * w.ndim
        pad[1] = (0, r.shape[1] - w.shape[1])
        w = jnp.pad(w, pad, constant_values=1.0)
    if mode in ("pallas", "interpret"):
        from repro.kernels import wkv6_scan as wkvk
        y = wkvk.wkv6_scan(r, k, v, w, u, chunk=chunk,
                           interpret=(mode == "interpret"))
    elif impl == "blocked":
        sub = min(subchunk, chunk)
        while chunk % sub:  # snap to a divisor of the chunk
            sub -= 1
        y = ref.wkv6_blocked_ref(r, k, v, w, u, chunk=chunk, subchunk=sub)
    else:
        y = ref.wkv6_chunked_ref(r, k, v, w, u, chunk=chunk)
    return y[:, :S]


def wkv6_decode(state, r, k, v, w, u):
    return ref.wkv6_decode_ref(state, r, k, v, w, u)
