"""Pallas TPU kernel: Mamba2 SSD chunked scan (zamba2's SSM core).

Grid is (batch, head, time-chunk) with the chunk axis innermost
(sequential); the (P x N) recurrent state lives in VMEM scratch across chunk
steps.  Each step computes the intra-chunk quadratic term on the MXU
(chunk x chunk interaction matrix) plus the inter-chunk contribution from
the carried state — the state-space-dual algorithm, tiled so the working
set (chunk x P inputs, chunk x N B/C blocks, P x N state, chunk x chunk
decay) fits VMEM with MXU-aligned dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, h_scr, *, chunk):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (L,)
    A = A_ref[0].astype(jnp.float32)                # scalar
    Bm = B_ref[0].astype(jnp.float32)               # (L, N)
    Cm = C_ref[0].astype(jnp.float32)               # (L, N)

    a = A * dt                                      # (L,)
    acs = jnp.cumsum(a)                             # (L,)
    # intra-chunk decay matrix, lower-triangular in (t, s)
    diff = acs[:, None] - acs[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (t, s)
    w = cb * decay * dt[None, :]
    y_intra = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))  # (t, P)

    # inter-chunk: y_inter[t] = exp(acs_t) * C_t . h_in  (h: (P, N))
    h = h_scr[...]
    ch = jax.lax.dot_general(Cm, h, (((1,), (1,)), ((), ())))      # (t, P)
    y_inter = jnp.exp(acs)[:, None] * ch
    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h <- exp(acs_L) h + sum_s exp(acs_L - acs_s) dt_s x_s B_s^T
    tail = jnp.exp(acs[-1] - acs) * dt                              # (L,)
    G = jax.lax.dot_general(x * tail[:, None], Bm, (((0,), (0,)), ((), ())))
    h_scr[...] = h * jnp.exp(acs[-1]) + G


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B_, C, *, chunk=128, interpret=False):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); B_,C: (B,S,N) -> y: (B,S,H,P)."""
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    grid = (Bb, H, nc)
    y = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B_, C)
    return y
