"""Pallas TPU flash attention.

Block-tiled online-softmax attention with causal masking, sliding windows
(gemma2 local layers), logit soft-capping (gemma2) and GQA (kv head =
q head // group).  The grid is (batch, q_head, q_blocks, kv_blocks) with the
KV dimension innermost (sequential on TPU), carrying the running max /
normalizer / accumulator in VMEM scratch — the classic flash schedule, with
MXU-aligned (block_q x head_dim) @ (head_dim x block_k) tiles.

Validated in interpret mode against kernels/ref.py (tests/test_kernels_*).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, softcap_val, window, q_pos0, kv_len, block_q,
            block_k):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
    if softcap_val is not None:
        logits = softcap_val * jnp.tanh(logits / softcap_val)

    qpos = q_pos0 + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < kv_len
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1, keepdims=True))
    p = jnp.exp(logits - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())))

    @pl.when(ik == nk - 1)
    def _done():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "softcap_val", "window", "q_pos0",
                     "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, scale=None, softcap_val=None,
                    window=None, q_pos0=0, block_q=128, block_k=128,
                    interpret=False):
    """q: (B,S,H,D); k,v: (B,T,KV,D) -> (B,S,H,D)."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    bq = min(block_q, S)
    bk = min(block_k, T)
    Sp = -(-S // bq) * bq
    Tp = -(-T // bk) * bk
    qt = jnp.moveaxis(q, 2, 1)  # (B,H,S,D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if Sp != S:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    if Tp != T:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))

    grid = (B, H, Sp // bq, Tp // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          softcap_val=softcap_val, window=window,
                          q_pos0=q_pos0, kv_len=T, block_q=bq, block_k=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :S]
    return jnp.moveaxis(out, 1, 2)
