from repro.sim.jag import jag_simulate, JAG_BOUNDS, jag_sample_inputs  # noqa
from repro.sim.epidemic import seir_simulate, EPI_BOUNDS  # noqa
from repro.sim.nullsim import null_simulate, sleep_step  # noqa
