"""Null simulators for overhead measurement (paper Sec. 2.3).

The paper benchmarks Merlin with `sleep 1` shell tasks.  ``sleep_step``
reproduces that exactly (host-side sleep, configurable); ``null_simulate``
is the device-side null (a trivially small jitted computation) used to
measure the fused-bundle overhead floor.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def sleep_step(duration: float = 1.0):
    def step(ctx):
        time.sleep(duration)
    return step


def null_simulate(u, rng):
    return {"y": jnp.sum(u) * 0.0 + 1.0}
