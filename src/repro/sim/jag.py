"""A JAG-like semi-analytic ICF implosion model, in JAX (paper Sec. 3.1).

The real JAG [Gaffney 2015] evolves an ICF capsule through stagnation from
2 scalar physics inputs + 3 3-D perturbations and emits scalars, time
series and hyperspectral images.  This stand-in keeps the same I/O
signature class (5-D input -> 20+ scalars, 2 time series, 4 view images)
with physically-flavored scalings (Betti-like yield ~ v^5.8 degradation
laws, Legendre-mode shape distortions), runs in microseconds under vmap,
and has a small "physics failure" region (returns failed=1, NaN yield) to
exercise the resubmission machinery exactly like JAG's 0.22% internal
failures.

Inputs (all in [0,1], rescaled internally):
  0 scale      laser drive scale            [0.85, 1.15]
  1 thickness  shell thickness perturbation [-0.10, 0.10]
  2 asym_p2    P2 drive asymmetry           [-0.08, 0.08]
  3 asym_p4    P4 drive asymmetry           [-0.08, 0.08]
  4 dopant     ablator dopant / mix seed    [0.00, 0.08]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

JAG_BOUNDS = jnp.array([
    [0.85, 1.15],
    [-0.10, 0.10],
    [-0.08, 0.08],
    [-0.08, 0.08],
    [0.00, 0.08],
])

N_T = 32          # time-series samples
IMG = 16          # image resolution
N_VIEWS = 4


def jag_sample_inputs(rng, n):
    """Uniform (blue-noise stand-in) sampling of the 5-D input space in [0,1]."""
    return jax.random.uniform(rng, (n, 5))


def _rescale(u):
    lo, hi = JAG_BOUNDS[:, 0], JAG_BOUNDS[:, 1]
    return lo + u * (hi - lo)


def jag_simulate(u, rng):
    """u: (5,) in [0,1]; rng: PRNGKey -> dict of scalars/series/images."""
    x = _rescale(jnp.clip(u, 0.0, 1.0))
    scale, thick, p2, p4, dop = x[0], x[1], x[2], x[3], x[4]

    # implosion dynamics (Betti-like scalings)
    vel = 340.0 * scale ** 0.6 / (1.0 + 2.0 * thick)          # km/s
    adiabat = 1.8 * (1.0 + 0.5 * jnp.abs(thick))
    mix = 0.08 * dop / 0.08 + 3.0 * (p2 ** 2 + p4 ** 2)
    shape_deg = jnp.exp(-60.0 * (p2 ** 2) - 90.0 * (p4 ** 2))
    tion = 4.2 * (vel / 340.0) ** 1.25 * (1.0 - 0.5 * mix)     # keV
    rhor = 0.9 * (1.0 + thick) * (scale ** 0.3) * shape_deg
    pressure = 280.0 * (vel / 340.0) ** 2.6 * shape_deg
    yield_ = 5.0e15 * (vel / 340.0) ** 5.8 * shape_deg ** 2 * \
        jnp.exp(-8.0 * mix) * (1.0 + thick) ** 1.5
    bang = 8.2 * (1.0 + 1.5 * thick) / (scale ** 0.45)         # ns
    burnwidth = 0.16 * (1.0 + mix) / (scale ** 0.2)

    # "physics failure" region: over-driven thin shells break the solver
    failed = jnp.logical_and(scale > 1.13, thick < -0.085)

    # time series: burn rate + ion temperature trace
    t = jnp.linspace(7.0, 10.0, N_T)
    burn = yield_ / (burnwidth * jnp.sqrt(2 * jnp.pi)) * \
        jnp.exp(-0.5 * ((t - bang) / burnwidth) ** 2)
    tion_t = tion * jnp.exp(-0.5 * ((t - bang) / (2.5 * burnwidth)) ** 2)

    # images: 4 views of the stagnated hotspot with P2/P4 shape distortion
    ang = jnp.linspace(0, jnp.pi, IMG)
    yy, xx = jnp.meshgrid(jnp.linspace(-1, 1, IMG), jnp.linspace(-1, 1, IMG),
                          indexing="ij")
    r = jnp.sqrt(xx ** 2 + yy ** 2) + 1e-6
    costh = yy / r
    # Legendre P2/P4 distorted radius, view-dependent projection factor
    views = jnp.arange(N_VIEWS) * (jnp.pi / N_VIEWS)

    def one_view(phi):
        proj2 = p2 * jnp.cos(2 * phi)
        proj4 = p4 * jnp.cos(4 * phi)
        r0 = 0.45 * (1.0 + proj2 * 0.5 * (3 * costh ** 2 - 1)
                     + proj4 * 0.125 * (35 * costh ** 4 - 30 * costh ** 2 + 3))
        emiss = jnp.exp(-0.5 * ((r - r0) / (0.12 * (1 + mix))) ** 2)
        core = jnp.exp(-0.5 * (r / (0.3 * r0)) ** 2) * (tion / 4.2)
        return (emiss + core) * (yield_ / 5.0e15) ** 0.25

    images = jax.vmap(one_view)(views)  # (4, IMG, IMG)
    noise = jax.random.normal(rng, images.shape) * 0.01
    images = images + noise

    nan = jnp.nan
    yield_out = jnp.where(failed, nan, yield_)
    return {
        "yield": yield_out,
        "tion": jnp.where(failed, nan, tion),
        "velocity": vel,
        "rhor": rhor,
        "pressure": pressure,
        "adiabat": adiabat,
        "mix": mix,
        "bang_time": bang,
        "burn_width": burnwidth,
        "shape_deg": shape_deg,
        "failed": failed.astype(jnp.float32),
        "burn_rate": burn.astype(jnp.float32),
        "tion_trace": tion_t.astype(jnp.float32),
        "images": images.astype(jnp.float32),
        "inputs": u.astype(jnp.float32),
    }
