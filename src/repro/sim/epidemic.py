"""An epicast-like agent/metapopulation epidemic model in JAX (Sec. 3.3).

epicast is an MPI agent-based influenza/COVID model over census tracts; this
stand-in is a stochastic SEIR metapopulation over ``n_patches`` tracts with
commuting coupling, global parameters (R0-like infectivity, latent /
infectious periods) and local parameters (seed size, compliance), plus
non-pharmaceutical-intervention scenarios (contact reduction starting at an
intervention day) — enough structure to reproduce the paper's two-phase
calibrate -> forecast cascading workflow with real dynamics.

Inputs u (6,) in [0,1]:
  0 beta        base transmission rate      [0.15, 0.60]
  1 latent      1/sigma days                [2.0, 5.0]
  2 infectious  1/gamma days                [3.0, 8.0]
  3 seed        initial exposed fraction    [1e-5, 1e-3] (log)
  4 compliance  NPI contact reduction       [0.0, 0.8]
  5 start_day   NPI start day               [5, 40]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPI_BOUNDS = jnp.array([
    [0.15, 0.60],
    [2.0, 5.0],
    [3.0, 8.0],
    [-5.0, -3.0],   # log10 seed
    [0.0, 0.8],
    [5.0, 40.0],
])

N_PATCH = 16
T_DAYS = 60


def _rescale(u):
    lo, hi = EPI_BOUNDS[:, 0], EPI_BOUNDS[:, 1]
    return lo + jnp.clip(u, 0, 1) * (hi - lo)


def seir_simulate(u, rng, t_days: int = T_DAYS):
    """u: (6,) in [0,1] -> dict with daily new cases etc."""
    x = _rescale(u)
    beta, lat, inf, lseed, comp, d0 = x[0], x[1], x[2], x[3], x[4], x[5]
    sigma, gamma = 1.0 / lat, 1.0 / inf
    seed = 10.0 ** lseed

    k1, k2, k3 = jax.random.split(rng, 3)
    pop = 2000.0 * jnp.exp(0.3 * jax.random.normal(k1, (N_PATCH,)))
    # commuting coupling: mostly local contacts, some global mixing
    mix = 0.85 * jnp.eye(N_PATCH) + 0.15 / N_PATCH
    seed_patch = jax.random.uniform(k2, (N_PATCH,)) < 0.3
    E0 = pop * seed * seed_patch
    S0 = pop - E0

    def day(state, t):
        S, E, I, R, key = state
        key, sub = jax.random.split(key)
        npi = jnp.where(t >= d0, 1.0 - comp, 1.0)
        force = beta * npi * (mix @ (I / pop))
        new_e = S * (1 - jnp.exp(-force))
        # demographic noise
        new_e = jnp.clip(new_e * (1 + 0.08 * jax.random.normal(sub, (N_PATCH,))),
                         0.0, S)
        new_i = sigma * E
        new_r = gamma * I
        S = S - new_e
        E = E + new_e - new_i
        I = I + new_i - new_r
        R = R + new_r
        return (S, E, I, R, key), new_i.sum()

    init = (S0, E0, jnp.zeros(N_PATCH), jnp.zeros(N_PATCH), k3)
    (_, _, _, R, _), daily = jax.lax.scan(day, init, jnp.arange(t_days))
    total = R.sum() + daily[-1]
    peak_day = jnp.argmax(daily).astype(jnp.float32)
    return {
        "daily_cases": daily.astype(jnp.float32),
        "attack_rate": (total / pop.sum()).astype(jnp.float32),
        "peak_day": peak_day,
        "peak_cases": daily.max().astype(jnp.float32),
        "inputs": u.astype(jnp.float32),
    }
