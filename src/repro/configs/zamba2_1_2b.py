"""zamba2-1.2b [hybrid]: 38L Mamba2 backbone + shared-weight attention blocks
interleaved (arXiv:2411.15242).  d_model=2048, 32H MHA (kv=32) in the shared
block, d_ff=8192 (shared block MLP), vocab=32000, ssm_state=64.

Layout: 3 unscanned mamba layers, then 5 repeats of
(shared_attn + 6 mamba) = 38 plan entries, shared attention applied 5x with
ONE weight set (zamba2's signature weight sharing; input = concat(hidden,
initial embeddings) as in the paper).  Decode uses a 4096-token rolling
window on the shared attention -> O(1)-ish state at 500k context (this is
why zamba2 runs the long_500k shape; see DESIGN.md)."""
from repro.configs.base import LayerSpec, ModelConfig

M = LayerSpec(kind="mamba2", mlp="none")


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=32000,
        prologue=(M, M, M),
        superblock=(LayerSpec(kind="shared_attn", mlp="none"), M, M, M, M, M, M),
        n_repeat=5,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        decode_window=4096,
        rope_theta=10000.0,
        microbatch=16,
    )
