"""gemma2-27b [dense]: 46L, d_model=4608, 32H GQA kv=16, head_dim=128,
d_ff=36864, vocab=256000 (arXiv:2408.00118).  Alternating local(4096)/global
attention, attn-logit softcap 50, final-logit softcap 30, sandwich norms,
query scale 1/sqrt(d_model/n_heads)=1/12."""
from repro.configs.base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="gemma2-27b",
        family="dense",
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab_size=256000,
        superblock=(LayerSpec(kind="attn", mlp="glu", sliding_window=4096),
                    LayerSpec(kind="attn", mlp="glu")),
        n_repeat=23,
        attn_softcap=50.0,
        final_softcap=30.0,
        sandwich_norm=True,
        embed_scale=True,
        attn_scale=(4608 / 32) ** -0.5,
        rope_theta=10000.0,
        microbatch=16,
        accum_dtype="bfloat16",  # multi-pod HBM fit (§Dry-run)
    )
