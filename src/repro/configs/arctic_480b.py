"""arctic-480b [moe]: 35L, d_model=7168, 56H GQA kv=8, vocab=32000
(hf:Snowflake/snowflake-arctic-base).  128 experts top-2 (d_ff=4864) with a
dense residual MLP in parallel (dense-MoE hybrid).

Optimizer defaults to Adafactor: 480B params with unfactored AdamW fp32
moments does not fit 256 x 16 GB (see DESIGN.md §Arch-applicability)."""
from repro.configs.base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab_size=32000,
        superblock=(LayerSpec(kind="attn", mlp="moe"),),
        n_repeat=35,
        n_experts=128,
        top_k=2,
        d_ff_expert=4864,
        moe_dense_residual=True,
        optimizer="adafactor",
        rope_theta=10000.0,
        tie_embeddings=False,
        microbatch=16,
        # §Perf hillclimb B (EXPERIMENTS.md): bf16 grad accumulation +
        # capacity 1.0 (compute -19%, fit -3.3GB).  remat="dots" gives a
        # further -10% memory-term / -11% compute when HBM allows.
        accum_dtype="bfloat16",
        capacity_factor=1.0,
    )
