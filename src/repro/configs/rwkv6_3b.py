"""rwkv6-3b "Finch" [ssm]: 32L, d_model=2560 (attn-free), d_ff=8960,
vocab=65536; data-dependent decay linear attention (arXiv:2404.05892).
40 wkv heads of dim 64; O(1) decode state -> runs the long_500k shape."""
from repro.configs.base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        head_dim=64,
        d_ff=8960,
        vocab_size=65536,
        superblock=(LayerSpec(kind="rwkv6", mlp="none"),),
        n_repeat=32,
        rwkv_head_dim=64,
        microbatch=8,
        # §Perf-optimized defaults (EXPERIMENTS.md hillclimb A): blocked WKV
        # at chunk 64 cuts the dominant memory-roofline term 1.87x vs the
        # naive chunked form at 256.  Paper-faithful baseline: override
        # {"ssm_chunk": 256, "wkv_impl": "chunked"}.
        ssm_chunk=64,
        wkv_impl="blocked",
        wkv_subchunk=16,
    )
