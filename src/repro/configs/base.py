"""Model/workload configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig`` built from a
repeating ``superblock`` of ``LayerSpec``s (scanned ``n_repeat`` times), plus
optional unscanned prologue layers (e.g. deepseek's first dense layer) and an
optional encoder stack (whisper).  This keeps the lowered HLO small (one scan
body per superblock) which matters both for compile time and for remat.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

# ---------------------------------------------------------------------------
# Layer / model configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside a superblock."""

    kind: str = "attn"  # attn | mla | mamba2 | rwkv6 | xattn
    mlp: str = "glu"  # glu | gelu_mlp | moe | none (rwkv6 has its own)
    # attention options
    sliding_window: Optional[int] = None  # local attention window (gemma2)
    causal: bool = True


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str = ""
    family: str = "dense"  # dense | moe | ssm | hybrid | audio | vlm
    # core dims
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024
    # layer plan
    superblock: Tuple[LayerSpec, ...] = (LayerSpec(),)
    n_repeat: int = 2  # superblock repeats; n_repeat*len(superblock)+prologue = n_layers
    prologue: Tuple[LayerSpec, ...] = ()  # unscanned leading layers
    # attention options
    rope_theta: float = 10000.0
    attn_softcap: Optional[float] = None  # gemma2: 50.0
    final_softcap: Optional[float] = None  # gemma2: 30.0
    qk_norm: bool = False
    attn_scale: Optional[float] = None  # override 1/sqrt(head_dim)
    # MLA (deepseek) options
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # MoE options
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_dense_residual: bool = False  # arctic: dense MLP in parallel w/ MoE
    router_noise: float = 0.0
    capacity_factor: float = 1.25
    moe_impl: str = "gshard"  # gshard | sort (sort = beyond-paper optimized)
    moe_group: int = 1024  # tokens per dispatch group (capacity granularity)
    # Mamba2 / SSM options
    ssm_state: int = 64
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    shared_attn_every: int = 0  # zamba2: shared-weight attn block period
    # RWKV6 options
    rwkv_head_dim: int = 64
    rwkv_lora_decay: int = 64
    rwkv_lora_mix: int = 32
    wkv_impl: str = "chunked"  # chunked | blocked (§Perf optimized)
    wkv_subchunk: int = 16
    # encoder (whisper) / vision options
    n_enc_layers: int = 0
    enc_len: int = 1500  # precomputed frame embeddings (stub frontend)
    n_img_tokens: int = 0  # precomputed patch embeddings (stub frontend)
    d_vision: int = 0
    xattn_every: int = 0  # vision: cross-attn layer period inside superblock plan
    # embeddings
    tie_embeddings: bool = True
    embed_scale: bool = False  # gemma-style sqrt(d_model) scaling
    sandwich_norm: bool = False  # gemma2: pre+post norms around attn/mlp
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    # training
    optimizer: str = "adamw"  # adamw | adafactor
    remat: str = "full"  # none | full | dots
    microbatch: int = 1  # gradient-accumulation microbatches per step
    accum_dtype: str = "float32"  # grad-accumulator dtype (bf16: §Perf lever)
    # serving
    decode_window: Optional[int] = None  # cap KV length at decode (hybrid archs)
    mla_absorb: bool = False  # deepseek decode matmul-absorption (beyond-paper)
    # kernels
    use_pallas: str = "auto"  # auto | never | interpret
    # lowering: unroll layer scans (dry-run flop probes need straight-line
    # HLO because XLA cost_analysis counts a while-loop body exactly once)
    scan_unroll: Any = 1  # int | True

    @property
    def plan(self) -> Tuple[LayerSpec, ...]:
        return self.prologue + self.superblock * self.n_repeat

    def validate(self) -> None:
        n = len(self.prologue) + len(self.superblock) * self.n_repeat
        assert n == self.n_layers, (
            f"{self.arch_id}: layer plan covers {n} layers, config says {self.n_layers}")
        if any(s.kind == "attn" for s in self.plan):
            assert self.n_heads % self.n_kv_heads == 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Workload shapes (assigned input-shape sets)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

# archs with a sub-quadratic long-context path (see DESIGN.md §Arch-applicability)
SUBQUADRATIC = {"zamba2-1.2b", "rwkv6-3b"}


def shape_applicable(arch_id: str, shape: str, family: str) -> bool:
    if shape == "long_500k":
        return arch_id in SUBQUADRATIC
    return True
