"""llama-3.2-vision-11b [vlm]: 40L total = 32 self-attn + 8 gated cross-attn
image layers (every 5th), d_model=4096, 32H GQA kv=8, d_ff=14336,
vocab=128256 (hf:meta-llama/Llama-3.2-11B-Vision).  The vision tower is a
STUB: input_specs() provides precomputed patch embeddings (B, 1600, 4096)
which w_img projects into the text space; cross-attn K/V over them are
cached once at prefill."""
from repro.configs.base import LayerSpec, ModelConfig

A = LayerSpec(kind="attn", mlp="glu")


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="llama-3.2-vision-11b",
        family="vlm",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=128256,
        superblock=(LayerSpec(kind="xattn", mlp="glu"), A, A, A, A),
        n_repeat=8,
        n_img_tokens=1600,
        d_vision=4096,
        rope_theta=500000.0,
        microbatch=8,
    )
