"""whisper-tiny [audio]: enc-dec, 4L encoder + 4L decoder, d_model=384, 6H,
d_ff=1536, vocab=51865 (arXiv:2212.04356).  The conv/audio frontend is a
STUB per the assignment: input_specs() provides precomputed frame embeddings
(B, 1500, 384).  Decoder layers are (self-attn + cross-attn + GELU MLP).

Tiny model on a 256-chip mesh: the per-arch sharding rules map `batch` to
all mesh axes (pure data parallelism) — see registry.ARCH_RULES."""
from repro.configs.base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-tiny",
        family="audio",
        n_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51865,
        superblock=(LayerSpec(kind="dec", mlp="gelu_mlp"),),
        n_repeat=4,
        n_enc_layers=4,
        enc_len=1500,
        rope_theta=10000.0,
        # 51865-vocab logits replicate over `model` (odd vocab): microbatch
        # to keep the fp32 softmax working set inside HBM (§Dry-run fit)
        microbatch=8,
    )
