"""phi4-mini-3.8b [dense]: 32L, d_model=3072, 24H GQA kv=8, d_ff=8192,
vocab=200064; RoPE + SwiGLU + GQA (arXiv:2412.08905).

Note: 24 heads / 8 kv-heads do not divide the 16-way `model` mesh axis; the
divisibility-aware sharding rules fall back to replicated head axes with the
flat QKV projections still tensor-sharded (see parallel/sharding.py)."""
from repro.configs.base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="phi4-mini-3.8b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=200064,
        superblock=(LayerSpec(kind="attn", mlp="glu"),),
        n_repeat=32,
        rope_theta=10000.0,
        microbatch=8,
    )
