from repro.configs.base import LayerSpec, ModelConfig, ShapeConfig, SHAPES  # noqa
from repro.configs.registry import ARCHS, get_config, reduced_config, input_specs  # noqa
