"""deepseek-v2-lite-16b [moe]: 27L, d_model=2048, 16H, vocab=102400
(arXiv:2405.04434).  MLA with kv_lora_rank=512 (+64 decoupled rope dims,
128 nope, 128 v); MoE: 64 routed experts top-6 + 2 shared experts,
d_ff(expert)=1408; first layer is dense (d_ff=10944, published config —
the assignment line lists only the expert d_ff).

The assignment note "2 shared+160 routed" matches full V2; the -Lite config
(64 routed) is used, consistent with the "MoE 64e top-6" header."""
from repro.configs.base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-v2-lite-16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=10944,
        vocab_size=102400,
        prologue=(LayerSpec(kind="mla", mlp="glu"),),
        superblock=(LayerSpec(kind="mla", mlp="moe"),),
        n_repeat=26,
        kv_lora_rank=512,
        qk_rope_dim=64,
        qk_nope_dim=128,
        v_head_dim=128,
        n_experts=64,
        top_k=6,
        n_shared_experts=2,
        d_ff_expert=1408,
        rope_theta=10000.0,
        microbatch=8,
        # §Perf hillclimb C (EXPERIMENTS.md): latent-space decode via k-up
        # projection absorption — 70-90x HLO-flop cut at decode; pair with
        # int8 latent cache (ServeEngine cache_dtype / dryrun --cache-dtype)
        # for a further -34% on the decode memory term.
        mla_absorb=True,
    )
