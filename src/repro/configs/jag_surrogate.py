"""The paper's own ML workload: a surrogate model for the JAG ICF simulator
(Sec. 3.1/3.2 of the Merlin paper; cf. arXiv:1912.08113 "transfer-learned
surrogates").  Here: a compact decoder-style transformer regressor over
tokenized (input-params, observables) pairs used by the optimization-loop
and ensemble examples.  Small enough to train for real on CPU."""
from repro.configs.base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="jag-surrogate",
        family="dense",
        n_layers=4,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        head_dim=64,
        d_ff=1024,
        vocab_size=4096,
        superblock=(LayerSpec(kind="attn", mlp="glu"),),
        n_repeat=4,
        microbatch=1,
    )
