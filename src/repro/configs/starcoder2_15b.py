"""starcoder2-15b [dense]: 40L, d_model=6144, 48H GQA kv=4, d_ff=24576,
vocab=49152; GQA + RoPE (arXiv:2402.19173).  MLP is a plain GELU stack (the
published config), not gated."""
from repro.configs.base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        superblock=(LayerSpec(kind="attn", mlp="gelu_mlp"),),
        n_repeat=40,
        rope_theta=100000.0,
        tie_embeddings=False,
        microbatch=16,
    )
