"""Architecture registry: ``--arch <id>`` resolution, reduced smoke configs,
per-arch sharding-rule overrides, and input specs for every workload shape.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_applicable

ARCHS = {
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "arctic-480b": "repro.configs.arctic_480b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "jag-surrogate": "repro.configs.jag_surrogate",
}

# per-arch logical->physical rule overrides (see parallel/sharding.py)
ARCH_RULES: Dict[str, Dict] = {
    # whisper-tiny is far too small for tensor parallelism on 256 chips:
    # run it pure-DP with batch over every mesh axis.
    "whisper-tiny": {"batch": ("pod", "data", "model"), "fsdp": (),
                     "tensor": (), "vocab": (), "heads": ()},
}


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(ARCHS[arch_id])
    cfg = mod.get_config()
    cfg.validate()
    return cfg


def arch_rules(arch_id: str) -> Optional[Dict]:
    return ARCH_RULES.get(arch_id)


# ---------------------------------------------------------------------------
# reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------

def reduced_config(arch_id: str) -> ModelConfig:
    """Same family/topology, tiny dims: one fwd/train step must run on CPU."""
    cfg = get_config(arch_id)
    import math
    heads = max(2, cfg.n_heads // 8)
    kv = math.gcd(heads, max(1, min(cfg.n_kv_heads, heads)))
    over: Dict[str, Any] = dict(
        d_model=128, n_heads=heads, n_kv_heads=kv, head_dim=32,
        d_ff=256, vocab_size=512, n_repeat=2, microbatch=1,
        ssm_state=16, ssm_head_dim=32, ssm_chunk=32,
        rwkv_head_dim=32, rwkv_lora_decay=16, rwkv_lora_mix=8,
        kv_lora_rank=32, qk_rope_dim=16, qk_nope_dim=32, v_head_dim=32,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_ff_expert=64 if cfg.d_ff_expert else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        enc_len=16 if cfg.n_enc_layers else cfg.enc_len,
        n_img_tokens=16 if cfg.n_img_tokens else 0,
        d_vision=64 if cfg.n_img_tokens else 0,
        decode_window=32 if cfg.decode_window else None,
        attn_scale=None,
    )
    n_layers = len(cfg.prologue) + len(cfg.superblock) * over["n_repeat"]
    over["n_layers"] = n_layers
    r = cfg.replace(**over)
    r.validate()
    return r


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def _extras(cfg: ModelConfig, B: int):
    ex = {}
    if cfg.n_enc_layers:
        ex["enc_embed"] = ((B, cfg.enc_len, cfg.d_model), jnp.bfloat16)
    if cfg.n_img_tokens:
        ex["img_embed"] = ((B, cfg.n_img_tokens, cfg.d_vision), jnp.bfloat16)
    return ex


def input_specs(cfg: ModelConfig, shape: ShapeConfig, abstract: bool = True,
                rng: Optional[jax.Array] = None):
    """Model inputs for a workload shape.

    ``abstract=True`` -> jax.ShapeDtypeStruct stand-ins (dry-run lowering,
    no allocation).  ``abstract=False`` -> concrete random arrays (tests).

    train/prefill: {"tokens", ("labels")} (+ stub-frontend embeddings).
    decode: {"token": (B,1)} — the KV caches are a separate argument built
    by models.lm.init_caches (see launch/dryrun.py).
    """
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = ((B, S), jnp.int32)
        specs["labels"] = ((B, S), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = ((B, S), jnp.int32)
    else:  # decode
        specs["token"] = ((B, 1), jnp.int32)
    if shape.kind != "decode":
        specs.update(_extras(cfg, B))

    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in specs.items()}
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    out = {}
    for k, (s, d) in specs.items():
        rng, sub = jax.random.split(rng)
        if d == jnp.int32:
            out[k] = jax.random.randint(sub, s, 0, cfg.vocab_size, dtype=d)
        else:
            out[k] = (jax.random.normal(sub, s) * 0.02).astype(d)
    return out


def applicable_shapes(arch_id: str):
    cfg = get_config(arch_id)
    return [s for s in SHAPES.values()
            if shape_applicable(arch_id, s.name, cfg.family)]
