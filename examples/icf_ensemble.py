"""The 100M-JAG Sierra study (paper Sec. 3.1), scaled to this machine.

Reproduces every mechanism of the original at 1/10000 scale:
  * YAML study spec (simulate -> aggregate funnel),
  * hierarchical task generation from ONE enqueued message,
  * bundles of simulations fused per task, hierarchical npz bundling
    (10 sims/bundle file, 100 files/leaf -> 1000-sim aggregates),
  * injected worker failures (the "volatile early-access period"),
  * crawl-and-resubmit recovery passes: completion goes ~70% -> ~100%,
    mirroring the paper's 70% -> 85% -> 99.755% arc.

The study itself is a declarative spec file (examples/specs/
icf_ensemble.yaml) compiled into the runtime's task DAG — the code below
only registers the two fn-steps and drives the run.

Run: PYTHONPATH=src python examples/icf_ensemble.py [n_samples]
"""
import os
import sys
import tempfile
import time

import jax
import numpy as np

from repro.core import Bundler, EnsembleExecutor, MerlinRuntime, StudySpec, WorkerPool
from repro.core.hierarchy import HierarchyCfg
from repro.core.resilience import crawl_and_resubmit
from repro.sim import jag_simulate, jag_sample_inputs

SPEC_PATH = os.path.join(os.path.dirname(__file__), "specs",
                         "icf_ensemble.yaml")


def main(n_samples: int = 10_000):
    with tempfile.TemporaryDirectory() as ws:
        rt = MerlinRuntime(workspace=ws,
                           hierarchy=HierarchyCfg(max_fanout=16, bundle=10))
        bundler = Bundler(f"{ws}/jag", files_per_leaf=100)
        executor = EnsembleExecutor(jag_simulate, bundler)
        rt.register("simulate", executor.step_fn())
        agg_stats = {}

        def aggregate(ctx):
            outs = bundler.aggregate_all()
            agg_stats["n_aggregates"] = len(outs)
        rt.register("aggregate", aggregate)

        with open(SPEC_PATH) as f:
            spec = StudySpec.from_yaml(f.read())
        samples = np.asarray(jag_sample_inputs(jax.random.PRNGKey(0),
                                               n_samples))

        rt.broker._vt = 1.0  # fast redelivery for dead workers
        t0 = time.time()
        # 30% worker death rate: the "volatile early access period"
        with WorkerPool(rt, n_workers=4, failure_rate=0.3, seed=3) as pool:
            study = rt.run(spec, samples)
            rt.wait(study, timeout=600)
            pool.drain(timeout=60)
            present, corrupt = bundler.crawl()
            print(f"pass 1: {len(present)}/{n_samples} "
                  f"({100 * len(present) / n_samples:.1f}%) complete, "
                  f"{rt.broker.stats['redelivered']} redeliveries, "
                  f"{time.time() - t0:.1f}s")

            # recovery passes: crawl the tree, resubmit missing work
            tmpl = {"study": study, "stage": 0, "combo": 0,
                    "n_samples": n_samples, "fanout": 16, "bundle": 10}
            for p in range(2, 6):
                missing, ntasks = crawl_and_resubmit(
                    bundler, n_samples, rt.broker, tmpl, bundle=10)
                if missing == 0:
                    break
                pool.drain(timeout=120)
                present, _ = bundler.crawl()
                print(f"pass {p}: resubmitted {ntasks} tasks -> "
                      f"{len(present)}/{n_samples} "
                      f"({100 * len(present) / n_samples:.2f}%)")

        data = bundler.load_all()
        ok = np.isfinite(data["yield"])
        rate = executor.stats["samples"] / max(executor.stats["sim_time"], 1e-9)
        print(f"final: {len(present)}/{n_samples} on disk; "
              f"{int((~ok).sum())} internal physics failures "
              f"({100 * (~ok).mean():.2f}%, cf. paper's 0.22%)")
        print(f"dataset: {data['images'].nbytes / 2**20:.0f} MiB of images, "
              f"{agg_stats.get('n_aggregates', 0)} aggregate files, "
              f"device throughput {rate:.0f} sims/s")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 10_000)
