"""Quickstart: the whole Merlin-on-JAX story in ~60 lines.

1. Define a study (simulate -> collect) over 512 JAG ICF samples.
2. Run it through the producer-consumer runtime with 4 surge-able workers
   (one root message enqueued; workers expand the task hierarchy).
3. Train an ML surrogate on the bundled ensemble and report its fit —
   the "ML-ready" part of ML-ready HPC ensembles.

Run: PYTHONPATH=src python examples/quickstart.py

Two-process mode (the paper's actual deployment shape — a standalone
broker host, like the RabbitMQ server of Sec. 2-3):

    PYTHONPATH=src python examples/quickstart.py --two-process

spawns ``python -m repro.launch.serve broker-serve`` as a separate OS
process and attaches the runtime + worker pool to it over TCP.  The queue
lives entirely in the server process — no shared directory, no shared
memory; kill either side and the other's leases expire and redeliver.

Sharded mode (the federation that scales past one broker process):

    PYTHONPATH=src python examples/quickstart.py --sharded

spawns TWO broker-serve processes (shards 0/2 and 1/2) and connects the
runtime with ``broker=[url0, url1]`` — a ShardedBroker that routes each
queue to its owning shard by stable hash.  The study's ``real`` and
``gen`` queues land on different shards, so generation and simulation
traffic never share a broker process.
"""
import argparse
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.core import Bundler, EnsembleExecutor, MerlinRuntime, Step, StudySpec, WorkerPool
from repro.core.active import train_surrogate
from repro.core.hierarchy import HierarchyCfg
from repro.data.pipeline import regression_dataset
from repro.sim import jag_simulate, jag_sample_inputs
import jax


def spawn_broker_server(workspace: str, name: str = "broker",
                        extra_args: "list[str]" = ()) \
        -> "tuple[subprocess.Popen, str]":
    """Start a broker-serve process; return (proc, tcp:// URL)."""
    port_file = os.path.join(workspace, f"{name}.port")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "broker-serve",
         "--port", "0", "--port-file", port_file, *extra_args],
        env={**os.environ,
             "PYTHONPATH": "src" + os.pathsep + os.environ.get("PYTHONPATH", "")})
    deadline = time.monotonic() + 30
    while not os.path.exists(port_file):
        if proc.poll() is not None:
            raise RuntimeError("broker server died during startup")
        if time.monotonic() > deadline:
            proc.terminate()
            raise RuntimeError("broker server did not come up in 30s")
        time.sleep(0.05)
    with open(port_file) as f:
        port = int(f.read())
    return proc, f"tcp://127.0.0.1:{port}"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--two-process", action="store_true",
                    help="host the queue in a separate broker-serve process "
                         "(no shared filesystem for the queue)")
    ap.add_argument("--sharded", action="store_true",
                    help="host the queues on TWO broker-serve shard "
                         "processes, federated client-side by queue hash")
    args = ap.parse_args(argv)

    procs = []
    with tempfile.TemporaryDirectory() as ws:
        broker = None  # default: in-process InMemoryBroker
        if args.sharded:
            urls = []
            for i in range(2):
                p, url = spawn_broker_server(
                    ws, name=f"shard{i}", extra_args=["--shard-of", f"{i}/2"])
                procs.append(p)
                urls.append(url)
                print(f"shard {i}/2 up at {url} (pid {p.pid})")
            broker = urls  # a list of endpoints == a ShardedBroker
        elif args.two_process:
            proc, broker = spawn_broker_server(ws)
            procs.append(proc)
            print(f"broker server up at {broker} (pid {proc.pid})")
        try:
            # 1. runtime + study ---------------------------------------------
            rt = MerlinRuntime(broker=broker, workspace=ws,
                               hierarchy=HierarchyCfg(max_fanout=8, bundle=64))
            if args.sharded:
                sb = rt.broker  # the ShardedBroker built from the URL list
                print("queue routing: " + ", ".join(
                    f"{q} -> shard {sb.shard_for(q)}"
                    for q in (rt.real_queue, rt.gen_queue)))
            bundler = Bundler(f"{ws}/results", files_per_leaf=4)
            executor = EnsembleExecutor(jag_simulate, bundler)
            rt.register("simulate", executor.step_fn())
            spec = StudySpec(name="quickstart", steps=[
                Step(name="simulate", fn="simulate")])

            samples = np.asarray(jag_sample_inputs(jax.random.PRNGKey(0), 512))

            # 2. producer-consumer execution ---------------------------------
            with WorkerPool(rt, n_workers=4) as pool:
                study = rt.run(spec, samples)      # `merlin run`: one message
                assert rt.wait(study, timeout=120)
                print(f"workers processed {pool.stats()['real']} bundles "
                      f"({executor.stats['samples']} simulations, "
                      f"{executor.stats['sim_time']:.2f}s device time)")

            # 3. ML-ready: train a surrogate on the ensemble -----------------
            data = bundler.load_all()
            X, y = regression_dataset(data, target="yield")
            n = len(X)
            sur = train_surrogate(X[: n // 2], y[: n // 2], steps=400)
            mu, sd = sur.predict(X[n // 2:])
            ss_res = float(np.mean((mu - y[n // 2:]) ** 2))
            ss_tot = float(np.var(y[n // 2:]))
            print(f"surrogate R^2 on held-out half: {1 - ss_res / ss_tot:.3f} "
                  f"(n_train={n // 2})")
        finally:
            for proc in procs:
                proc.terminate()
                proc.wait(timeout=10)


if __name__ == "__main__":
    main()
