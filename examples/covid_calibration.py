"""COVID-19 intervention study (paper Sec. 3.3): the calibrate->forecast
cascade on the epicast-like SEIR model, as ONE declarative DAG
(presim -> select -> forecast -> package; see examples/specs/
covid_cascade.yaml for the YAML rendering).

Calibration fits per-metro model parameters against "observed" case
curves (metros are DAG parameters; parameter draws are samples).  The
per-metro select step publishes its ABC posterior as a named sample set,
and the graph edge to the forecast nodes fans each metro out over three
non-pharmaceutical-intervention scenarios — what used to be a phase-2
``merlin run`` launched from inside a worker is now just edges.

Run: PYTHONPATH=src python examples/covid_calibration.py
"""
import tempfile
import time

import jax
import numpy as np

from repro.core import MerlinRuntime, WorkerPool
from repro.core.cascade import CalibrationCascade
from repro.core.hierarchy import HierarchyCfg
from repro.sim import seir_simulate

METROS = ["NYC", "SEA", "ATL"]


def synth_observations(seed=0):
    """Ground-truth runs standing in for the live case-data pull."""
    rng = np.random.default_rng(seed)
    obs = {}
    for i, m in enumerate(METROS):
        u = rng.uniform(0.25, 0.75, 6).astype(np.float32)
        curve = jax.jit(seir_simulate)(u, jax.random.PRNGKey(100 + i))[
            "daily_cases"]
        obs[m] = np.asarray(curve) * rng.normal(1.0, 0.05, curve.shape)
    return obs


def main():
    observed = synth_observations()
    with tempfile.TemporaryDirectory() as ws:
        rt = MerlinRuntime(workspace=ws,
                           hierarchy=HierarchyCfg(max_fanout=8, bundle=32))
        casc = CalibrationCascade(rt, seir_simulate, observed, n_calib=128,
                                  n_posterior=24)
        t0 = time.time()
        with WorkerPool(rt, n_workers=3) as pool:
            casc.start()
            while time.time() - t0 < 600:
                if all(len(casc.results.get(m, {})) >= 4 for m in METROS):
                    break
                time.sleep(0.25)
            pool.drain(timeout=120)

        print(f"calibrate->forecast cascade finished in {time.time()-t0:.1f}s")
        print(f"{'metro':<6}{'cal RMSE':>10} | peak cases/day by scenario")
        for m in METROS:
            r = casc.results[m]
            scen = "  ".join(f"{s}={r[s]['peak_median']:.0f}"
                             for s in sorted(r) if s != "posterior_rmse")
            print(f"{m:<6}{r['posterior_rmse']:>10.2f} | {scen}")


if __name__ == "__main__":
    main()
