"""ML-augmented optimization of a fusion design (paper Sec. 3.2).

Re-optimizes JAG capsule inputs for maximum *robust* yield (expected yield
under manufacturing perturbations) subject to an implosion-velocity
constraint, via the self-re-enqueueing Merlin workflow: simulate ->
post-process -> train surrogate -> constrained acquisition -> next batch,
with iterations launched from inside worker tasks.

Run: PYTHONPATH=src python examples/optimization_loop.py
"""
import tempfile
import time

import numpy as np

from repro.core import MerlinRuntime, WorkerPool
from repro.core.active import OptimizationLoop
from repro.core.hierarchy import HierarchyCfg
from repro.sim import jag_simulate


def main():
    with tempfile.TemporaryDirectory() as ws:
        rt = MerlinRuntime(workspace=ws,
                           hierarchy=HierarchyCfg(max_fanout=8, bundle=16))
        loop = OptimizationLoop(rt, jag_simulate, batch_per_iter=96,
                                max_iters=4, constraint_max=360.0, seed=0)
        with WorkerPool(rt, n_workers=3) as pool:
            loop.start()
            t0 = time.time()
            while len(loop.history) < loop.max_iters and time.time() - t0 < 600:
                time.sleep(0.25)
            pool.drain(timeout=120)

        print("iter |    n | best yield")
        for h in loop.history:
            print(f"{h['iter']:>4} | {h['n']:>4} | {h['best']:.3e}")
        gain = loop.history[-1]["best"] / loop.history[0]["best"]
        print(f"robust-yield improvement over random init: {gain:.2f}x "
              f"in {len(loop.history)} iterations "
              f"({loop.history[-1]['n']} total simulations)")


if __name__ == "__main__":
    main()
