"""Study spec: YAML parsing, validation, parameter expansion (%zip)."""
import pytest

from repro.core.dag import compile_dag
from repro.core.spec import (SpecError, Step, StudySpec, expand_parameters,
                             substitute, topo_order)

YAML = """
description:
  name: icf_demo
env:
  variables:
    OUTPUT_ROOT: /tmp/out
study:
  - name: sim
    run:
      cmd: "echo sim $(SCALE) $(SAMPLE_LO)"
      shell: /bin/bash
  - name: post
    run:
      cmd: "echo post"
      depends: [sim]
  - name: collect
    run:
      cmd: "echo collect"
      depends: [post_*]
      samples: false
global.parameters:
  SCALE:
    values: [0.9, 1.0, 1.1]
"""


def test_yaml_roundtrip():
    spec = StudySpec.from_yaml(YAML)
    spec.validate()
    assert [s.name for s in spec.steps] == ["sim", "post", "collect"]
    assert spec.step("collect").over_samples is False
    assert spec.parameters["SCALE"] == [0.9, 1.0, 1.1]
    assert spec.variables["OUTPUT_ROOT"] == "/tmp/out"


FAILURE_YAML = """
description:
  name: policy_demo
study:
  - name: sim
    run:
      cmd: "echo sim"
      retries: 5
      timeout: 30
      on_failure: dead_letter
  - name: post
    run:
      cmd: "echo post"
      depends: [sim]
"""


def test_yaml_parses_failure_policy_fields():
    spec = StudySpec.from_yaml(FAILURE_YAML)
    spec.validate()
    sim, post = spec.steps
    assert sim.max_retries == 5          # `retries:` alias
    assert sim.timeout == 30.0
    assert sim.on_failure == "dead_letter"
    # defaults: retry twice, no deadline, nack-to-retry at exhaustion
    assert post.max_retries == 2
    assert post.timeout is None
    assert post.on_failure == "retry"


def test_validate_rejects_bad_failure_policy():
    with pytest.raises(SpecError, match="on_failure"):
        StudySpec(name="x", steps=[
            Step(name="a", cmd="true", on_failure="explode")]).validate()
    with pytest.raises(SpecError, match="timeout"):
        StudySpec(name="x", steps=[
            Step(name="a", cmd="true", timeout=0.0)]).validate()
    with pytest.raises(SpecError, match="timeout"):
        StudySpec(name="x", steps=[
            Step(name="a", cmd="true", timeout=-5)]).validate()
    with pytest.raises(SpecError, match="retries"):
        StudySpec(name="x", steps=[
            Step(name="a", cmd="true", max_retries=-1)]).validate()


def test_dag_nodes_carry_failure_policy_and_do_not_fuse_across_it():
    spec = StudySpec(name="pol", steps=[
        Step(name="a", fn="a", timeout=10, on_failure="skip"),
        Step(name="b", fn="b", depends=("a",), timeout=20,
             on_failure="skip")])
    dag = compile_dag(spec)
    # differing timeouts must not chain-fuse (one wall-clock budget per
    # fused execution would silently widen the tighter step's deadline)
    assert len(dag.nodes) == 2
    assert dag.nodes[0].timeout == 10 and dag.nodes[0].on_failure == "skip"
    same = StudySpec(name="pol2", steps=[
        Step(name="a", fn="a", timeout=10, on_failure="skip"),
        Step(name="b", fn="b", depends=("a",), timeout=10,
             on_failure="skip")])
    assert len(compile_dag(same).nodes) == 1  # identical policies fuse


def test_parameter_expansion_cartesian():
    spec = StudySpec(name="x", steps=[Step(name="a")],
                     parameters={"A": [1, 2], "B": ["x", "y", "z"]})
    combos = expand_parameters(spec)
    assert len(combos) == 6
    assert {"A": 1, "B": "x"} in combos


def test_parameter_expansion_zip():
    spec = StudySpec(name="x", steps=[Step(name="a", cmd="echo")],
                     parameters={"CFG%zip": ["small", "large"],
                                 "SEED%zip": [11, 17]})
    combos = expand_parameters(spec)
    assert combos == [{"CFG": "small", "SEED": 11},
                      {"CFG": "large", "SEED": 17}]


def test_parameter_expansion_mixed_zip_product():
    spec = StudySpec(name="x", steps=[Step(name="a", cmd="echo")],
                     parameters={"CFG%zip": ["small", "large"],
                                 "SEED%zip": [11, 17],
                                 "MODE": ["fast", "slow"]})
    combos = expand_parameters(spec)
    # zipped pairs crossed with the plain Cartesian axis
    assert len(combos) == 4
    assert {"CFG": "small", "SEED": 11, "MODE": "fast"} in combos
    assert {"CFG": "large", "SEED": 17, "MODE": "slow"} in combos
    assert not any(c["CFG"] == "small" and c["SEED"] == 17 for c in combos)


def test_zip_length_mismatch_rejected():
    spec = StudySpec(name="x", steps=[Step(name="a", cmd="echo")],
                     parameters={"A%zip": [1, 2, 3], "B%zip": [1, 2]})
    with pytest.raises(SpecError, match="%zip"):
        spec.validate()


def test_topo_order_and_cycle_detection():
    spec = StudySpec(name="x", steps=[
        Step(name="c", cmd="echo", depends=("b",)),
        Step(name="a", cmd="echo"),
        Step(name="b", cmd="echo", depends=("a",))])
    assert [s.name for s in topo_order(spec)] == ["a", "b", "c"]
    bad = StudySpec(name="x", steps=[
        Step(name="a", cmd="echo", depends=("b",)),
        Step(name="b", cmd="echo", depends=("a",))])
    with pytest.raises(SpecError, match="cycle"):
        bad.validate()


def test_unknown_dependency_rejected():
    spec = StudySpec(name="x", steps=[Step(name="a", cmd="echo", depends=("nope",))])
    with pytest.raises(SpecError, match="unknown step"):
        spec.validate()


def test_unknown_param_name_rejected():
    spec = StudySpec(name="x",
                     steps=[Step(name="a", cmd="echo", params=("NOPE",))],
                     parameters={"A": [1]})
    with pytest.raises(SpecError, match="NOPE"):
        spec.validate()


def test_substitution():
    out = substitute("run $(X) on $(WORKSPACE)", {"X": 3, "WORKSPACE": "/w"})
    assert out == "run 3 on /w"


def test_dag_compile_chains_and_funnels():
    # the linear-chain shape: sim -> post fuse into one parallel node,
    # the funnel collect stays its own single node
    spec = StudySpec.from_yaml(YAML)
    dag = compile_dag(spec)
    assert dag.kinds() == ["parallel", "single"]
    assert [s.name for s in dag.nodes[0].steps] == ["sim", "post"]
    assert dag.nodes[1].name == "collect"


def test_dag_compile_interleaved():
    spec = StudySpec(name="x", steps=[
        Step(name="a", cmd="echo"),
        Step(name="barrier", cmd="echo", depends=("a_*",),
             over_samples=False),
        Step(name="b", cmd="echo", depends=("barrier",)),
    ])
    dag = compile_dag(spec)
    assert dag.kinds() == ["parallel", "single", "parallel"]
