"""Study spec: YAML parsing, validation, parameter expansion (%zip)."""
import pytest

from repro.core.dag import compile_dag
from repro.core.spec import (SpecError, Step, StudySpec, expand_parameters,
                             substitute, topo_order)

YAML = """
description:
  name: icf_demo
env:
  variables:
    OUTPUT_ROOT: /tmp/out
study:
  - name: sim
    run:
      cmd: "echo sim $(SCALE) $(SAMPLE_LO)"
      shell: /bin/bash
  - name: post
    run:
      cmd: "echo post"
      depends: [sim]
  - name: collect
    run:
      cmd: "echo collect"
      depends: [post_*]
      samples: false
global.parameters:
  SCALE:
    values: [0.9, 1.0, 1.1]
"""


def test_yaml_roundtrip():
    spec = StudySpec.from_yaml(YAML)
    spec.validate()
    assert [s.name for s in spec.steps] == ["sim", "post", "collect"]
    assert spec.step("collect").over_samples is False
    assert spec.parameters["SCALE"] == [0.9, 1.0, 1.1]
    assert spec.variables["OUTPUT_ROOT"] == "/tmp/out"


def test_parameter_expansion_cartesian():
    spec = StudySpec(name="x", steps=[Step(name="a")],
                     parameters={"A": [1, 2], "B": ["x", "y", "z"]})
    combos = expand_parameters(spec)
    assert len(combos) == 6
    assert {"A": 1, "B": "x"} in combos


def test_parameter_expansion_zip():
    spec = StudySpec(name="x", steps=[Step(name="a", cmd="echo")],
                     parameters={"CFG%zip": ["small", "large"],
                                 "SEED%zip": [11, 17]})
    combos = expand_parameters(spec)
    assert combos == [{"CFG": "small", "SEED": 11},
                      {"CFG": "large", "SEED": 17}]


def test_parameter_expansion_mixed_zip_product():
    spec = StudySpec(name="x", steps=[Step(name="a", cmd="echo")],
                     parameters={"CFG%zip": ["small", "large"],
                                 "SEED%zip": [11, 17],
                                 "MODE": ["fast", "slow"]})
    combos = expand_parameters(spec)
    # zipped pairs crossed with the plain Cartesian axis
    assert len(combos) == 4
    assert {"CFG": "small", "SEED": 11, "MODE": "fast"} in combos
    assert {"CFG": "large", "SEED": 17, "MODE": "slow"} in combos
    assert not any(c["CFG"] == "small" and c["SEED"] == 17 for c in combos)


def test_zip_length_mismatch_rejected():
    spec = StudySpec(name="x", steps=[Step(name="a", cmd="echo")],
                     parameters={"A%zip": [1, 2, 3], "B%zip": [1, 2]})
    with pytest.raises(SpecError, match="%zip"):
        spec.validate()


def test_topo_order_and_cycle_detection():
    spec = StudySpec(name="x", steps=[
        Step(name="c", cmd="echo", depends=("b",)),
        Step(name="a", cmd="echo"),
        Step(name="b", cmd="echo", depends=("a",))])
    assert [s.name for s in topo_order(spec)] == ["a", "b", "c"]
    bad = StudySpec(name="x", steps=[
        Step(name="a", cmd="echo", depends=("b",)),
        Step(name="b", cmd="echo", depends=("a",))])
    with pytest.raises(SpecError, match="cycle"):
        bad.validate()


def test_unknown_dependency_rejected():
    spec = StudySpec(name="x", steps=[Step(name="a", cmd="echo", depends=("nope",))])
    with pytest.raises(SpecError, match="unknown step"):
        spec.validate()


def test_unknown_param_name_rejected():
    spec = StudySpec(name="x",
                     steps=[Step(name="a", cmd="echo", params=("NOPE",))],
                     parameters={"A": [1]})
    with pytest.raises(SpecError, match="NOPE"):
        spec.validate()


def test_substitution():
    out = substitute("run $(X) on $(WORKSPACE)", {"X": 3, "WORKSPACE": "/w"})
    assert out == "run 3 on /w"


def test_dag_compile_chains_and_funnels():
    # the linear-chain shape: sim -> post fuse into one parallel node,
    # the funnel collect stays its own single node
    spec = StudySpec.from_yaml(YAML)
    dag = compile_dag(spec)
    assert dag.kinds() == ["parallel", "single"]
    assert [s.name for s in dag.nodes[0].steps] == ["sim", "post"]
    assert dag.nodes[1].name == "collect"


def test_dag_compile_interleaved():
    spec = StudySpec(name="x", steps=[
        Step(name="a", cmd="echo"),
        Step(name="barrier", cmd="echo", depends=("a_*",),
             over_samples=False),
        Step(name="b", cmd="echo", depends=("barrier",)),
    ])
    dag = compile_dag(spec)
    assert dag.kinds() == ["parallel", "single", "parallel"]
