"""Study spec: YAML parsing, DAG validation, parameter expansion."""
import pytest

from repro.core.runtime import plan_stages
from repro.core.spec import (Step, StudySpec, expand_parameters, substitute,
                             topo_order)

YAML = """
description:
  name: icf_demo
env:
  variables:
    OUTPUT_ROOT: /tmp/out
study:
  - name: sim
    run:
      cmd: "echo sim $(SCALE) $(SAMPLE_LO)"
      shell: /bin/bash
  - name: post
    run:
      cmd: "echo post"
      depends: [sim]
  - name: collect
    run:
      cmd: "echo collect"
      depends: [post_*]
      samples: false
global.parameters:
  SCALE:
    values: [0.9, 1.0, 1.1]
"""


def test_yaml_roundtrip():
    spec = StudySpec.from_yaml(YAML)
    spec.validate()
    assert [s.name for s in spec.steps] == ["sim", "post", "collect"]
    assert spec.step("collect").over_samples is False
    assert spec.parameters["SCALE"] == [0.9, 1.0, 1.1]
    assert spec.variables["OUTPUT_ROOT"] == "/tmp/out"


def test_parameter_expansion_cartesian():
    spec = StudySpec(name="x", steps=[Step(name="a")],
                     parameters={"A": [1, 2], "B": ["x", "y", "z"]})
    combos = expand_parameters(spec)
    assert len(combos) == 6
    assert {"A": 1, "B": "x"} in combos


def test_topo_order_and_cycle_detection():
    spec = StudySpec(name="x", steps=[
        Step(name="c", depends=("b",)),
        Step(name="a"),
        Step(name="b", depends=("a",))])
    assert [s.name for s in topo_order(spec)] == ["a", "b", "c"]
    bad = StudySpec(name="x", steps=[
        Step(name="a", depends=("b",)), Step(name="b", depends=("a",))])
    with pytest.raises(AssertionError):
        bad.validate()


def test_unknown_dependency_rejected():
    spec = StudySpec(name="x", steps=[Step(name="a", depends=("nope",))])
    with pytest.raises(AssertionError):
        spec.validate()


def test_substitution():
    out = substitute("run $(X) on $(WORKSPACE)", {"X": 3, "WORKSPACE": "/w"})
    assert out == "run 3 on /w"


def test_stage_planning_chains_and_funnels():
    spec = StudySpec.from_yaml(YAML)
    stages = plan_stages(spec)
    assert [st["kind"] for st in stages] == ["parallel", "single"]
    assert [s.name for s in stages[0]["steps"]] == ["sim", "post"]


def test_stage_planning_interleaved():
    spec = StudySpec(name="x", steps=[
        Step(name="a"),
        Step(name="barrier", depends=("a_*",), over_samples=False),
        Step(name="b", depends=("barrier",)),
    ])
    stages = plan_stages(spec)
    assert [st["kind"] for st in stages] == ["parallel", "single", "parallel"]
