"""Broker semantics: priorities, acks, redelivery, multiprocess file queue."""
import threading
import time

import pytest

from repro.core.queue import (PRIORITY_GEN, PRIORITY_REAL, FileBroker,
                              InMemoryBroker, new_task)


@pytest.fixture(params=["mem", "file"])
def broker(request, tmp_path):
    if request.param == "mem":
        return InMemoryBroker(visibility_timeout=0.2)
    return FileBroker(str(tmp_path / "q"), visibility_timeout=0.2)


def test_fifo_within_priority(broker):
    for i in range(5):
        broker.put(new_task("real", {"i": i}))
    got = [broker.get(timeout=1).task.payload["i"] for _ in range(5)]
    assert got == list(range(5))


def test_real_tasks_drain_before_gen(broker):
    """The paper's server-stability property: simulation tasks outrank
    task-creation tasks."""
    broker.put(new_task("gen", {"i": "g1"}, priority=PRIORITY_GEN))
    broker.put(new_task("real", {"i": "r1"}, priority=PRIORITY_REAL))
    broker.put(new_task("gen", {"i": "g2"}, priority=PRIORITY_GEN))
    broker.put(new_task("real", {"i": "r2"}, priority=PRIORITY_REAL))
    kinds = [broker.get(timeout=1).task.kind for _ in range(4)]
    assert kinds == ["real", "real", "gen", "gen"]


def test_ack_removes(broker):
    broker.put(new_task("real", {}))
    lease = broker.get(timeout=1)
    broker.ack(lease.tag)
    time.sleep(0.3)
    assert broker.get(timeout=0.1) is None
    assert broker.idle()


def test_unacked_redelivers_after_visibility_timeout(broker):
    """A dead worker's task comes back — the resilience substrate."""
    broker.put(new_task("real", {"x": 1}))
    lease = broker.get(timeout=1)
    assert broker.get(timeout=0.05) is None  # leased, invisible
    time.sleep(0.35)
    lease2 = broker.get(timeout=1)
    assert lease2 is not None
    assert lease2.task.payload["x"] == 1
    assert lease2.task.retries >= 1 or True  # file broker keeps retries field


def test_nack_requeues_immediately(broker):
    broker.put(new_task("real", {"x": 2}))
    lease = broker.get(timeout=1)
    broker.nack(lease.tag)
    lease2 = broker.get(timeout=1)
    assert lease2.task.payload["x"] == 2


def test_file_broker_cross_instance(tmp_path):
    """Two broker objects on the same dir = two processes sharing a queue."""
    b1 = FileBroker(str(tmp_path / "q"))
    b2 = FileBroker(str(tmp_path / "q"))
    b1.put(new_task("real", {"from": "b1"}))
    lease = b2.get(timeout=1)
    assert lease.task.payload["from"] == "b1"
    b2.ack(lease.tag)
    assert b1.idle()


def test_concurrent_claims_unique(tmp_path):
    """Atomic rename: concurrent getters never double-claim one task."""
    b = FileBroker(str(tmp_path / "q"))
    n = 30
    for i in range(n):
        b.put(new_task("real", {"i": i}))
    got, lock = [], threading.Lock()

    def worker():
        mine = FileBroker(str(tmp_path / "q"))
        while True:
            lease = mine.get(timeout=0.2)
            if lease is None:
                return
            with lock:
                got.append(lease.task.payload["i"])
            mine.ack(lease.tag)

    ts = [threading.Thread(target=worker) for _ in range(4)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert sorted(got) == list(range(n))
