"""Broker semantics: priorities, acks, redelivery, multiprocess file queue."""
import threading
import time

import pytest

from repro.core.queue import (PRIORITY_GEN, PRIORITY_REAL, FileBroker,
                              InMemoryBroker, dlq_queue_name, is_dlq,
                              new_task, original_queue)


@pytest.fixture(params=["mem", "file"])
def broker(request, tmp_path):
    if request.param == "mem":
        return InMemoryBroker(visibility_timeout=0.2)
    return FileBroker(str(tmp_path / "q"), visibility_timeout=0.2)


def test_dlq_name_helpers():
    assert dlq_queue_name("sims") == "dlq.sims"
    assert dlq_queue_name("dlq.sims") == "dlq.sims"  # idempotent
    assert is_dlq("dlq.sims") and not is_dlq("sims")
    assert original_queue("dlq.sims") == "sims"
    assert original_queue("sims") == "sims"


def test_dlq_excluded_from_wildcard_but_reachable_by_name(broker):
    """dlq.* queues are parking lots: wildcard consumption, qsize(None)
    and idle() all ignore them, while explicit addressing still works —
    a dead-lettered task can never be re-delivered by accident."""
    broker.put(new_task("real", {"dead": 1}, queue="dlq.sims"))
    # wildcard consumers never see it
    assert broker.get(timeout=0.05) is None
    assert broker.qsize() == 0
    assert broker.qsize(["dlq.sims"]) == 1
    # nothing in the mainline and nothing leased -> the broker is idle
    # even though the DLQ is non-empty (drain loops must terminate)
    assert broker.idle()
    # the operator's explicit fetch (merlin-dlq) still reaches it
    lease = broker.get(timeout=0.5, queues=["dlq.sims"])
    assert lease is not None and lease.task.payload == {"dead": 1}
    broker.ack(lease.tag)
    # queue_names() keeps reporting it for discovery
    broker.put(new_task("real", {}, queue="dlq.sims"))
    broker.put(new_task("real", {}, queue="sims"))
    assert set(broker.queue_names()) == {"dlq.sims", "sims"}
    assert broker.qsize() == 1  # only the mainline task counts


def test_fifo_within_priority(broker):
    for i in range(5):
        broker.put(new_task("real", {"i": i}))
    got = [broker.get(timeout=1).task.payload["i"] for _ in range(5)]
    assert got == list(range(5))


def test_real_tasks_drain_before_gen(broker):
    """The paper's server-stability property: simulation tasks outrank
    task-creation tasks."""
    broker.put(new_task("gen", {"i": "g1"}, priority=PRIORITY_GEN))
    broker.put(new_task("real", {"i": "r1"}, priority=PRIORITY_REAL))
    broker.put(new_task("gen", {"i": "g2"}, priority=PRIORITY_GEN))
    broker.put(new_task("real", {"i": "r2"}, priority=PRIORITY_REAL))
    kinds = [broker.get(timeout=1).task.kind for _ in range(4)]
    assert kinds == ["real", "real", "gen", "gen"]


def test_ack_removes(broker):
    broker.put(new_task("real", {}))
    lease = broker.get(timeout=1)
    broker.ack(lease.tag)
    time.sleep(0.3)
    assert broker.get(timeout=0.1) is None
    assert broker.idle()


def test_unacked_redelivers_after_visibility_timeout(broker):
    """A dead worker's task comes back — the resilience substrate."""
    broker.put(new_task("real", {"x": 1}))
    lease = broker.get(timeout=1)
    assert broker.get(timeout=0.05) is None  # leased, invisible
    time.sleep(0.35)
    lease2 = broker.get(timeout=1)
    assert lease2 is not None
    assert lease2.task.payload["x"] == 1
    assert lease2.task.retries >= 1 or True  # file broker keeps retries field


def test_nack_requeues_immediately(broker):
    broker.put(new_task("real", {"x": 2}))
    lease = broker.get(timeout=1)
    broker.nack(lease.tag)
    lease2 = broker.get(timeout=1)
    assert lease2.task.payload["x"] == 2


def test_file_broker_cross_instance(tmp_path):
    """Two broker objects on the same dir = two processes sharing a queue."""
    b1 = FileBroker(str(tmp_path / "q"))
    b2 = FileBroker(str(tmp_path / "q"))
    b1.put(new_task("real", {"from": "b1"}))
    lease = b2.get(timeout=1)
    assert lease.task.payload["from"] == "b1"
    b2.ack(lease.tag)
    assert b1.idle()


@pytest.fixture(params=["mem", "file"])
def make_broker_kw(request, tmp_path):
    """Factory taking backend kwargs (fairness, queue_timeouts, ...)."""
    def make(**kw):
        if request.param == "mem":
            return InMemoryBroker(**kw)
        return FileBroker(str(tmp_path / "q"), **kw)
    return make


def test_per_queue_visibility_timeout(make_broker_kw):
    """A fast gen queue and a slow sim queue get independent lease clocks."""
    b = make_broker_kw(visibility_timeout=30.0, queue_timeouts={"gen": 0.15})
    b.put(new_task("real", {}, queue="sims"))
    b.put(new_task("gen", {}, queue="gen"))
    l_sim = b.get(timeout=1, queues=("sims",))
    l_gen = b.get(timeout=1, queues=("gen",))
    assert l_sim and l_gen
    # only the gen lease expires; the sim queue keeps the default 30s clock
    back = b.get(timeout=2)
    assert back is not None and back.task.queue == "gen"
    assert back.task.retries == 1
    b.ack(back.tag)
    assert b.get(timeout=0.1) is None
    assert b.inflight() == 1  # the sim lease is still held


def test_set_visibility_timeout_after_construction(make_broker_kw):
    b = make_broker_kw(visibility_timeout=30.0)
    b.set_visibility_timeout("sims", 0.15)
    b.put(new_task("real", {}, queue="sims"))
    lease = b.get(timeout=1)
    assert lease is not None
    lease2 = b.get(timeout=2)  # 0.15s clock, not 30s
    assert lease2 is not None and lease2.task.retries == 1


def test_filebroker_per_queue_vt_shared_across_instances(tmp_path):
    """The override is queue state: another instance on the same directory
    (a different 'allocation' sweeping expiries) honors it."""
    b1 = FileBroker(str(tmp_path / "q"), visibility_timeout=30.0)
    b1.set_visibility_timeout("sims", 0.1)
    b1.put(new_task("real", {}, queue="sims"))
    assert b1.get(timeout=1) is not None  # leased, never acked
    b2 = FileBroker(str(tmp_path / "q"), visibility_timeout=30.0)
    lease = b2.get(timeout=2)  # b2's sweep must apply the 0.1s override
    assert lease is not None and lease.task.retries == 1


def test_weighted_fairness_prevents_starvation(make_broker_kw):
    """50 queued flood tasks vs 3 trickle tasks: round-robin interleaves
    them instead of draining the flood first."""
    b = make_broker_kw(fairness="weighted")
    b.put_many([new_task("real", {"i": i}, queue="flood") for i in range(50)])
    b.put_many([new_task("real", {"i": i}, queue="trickle") for i in range(3)])
    first = [b.get(timeout=1).task.queue for _ in range(6)]
    assert "trickle" in first[:2]
    assert first.count("trickle") >= 3  # all trickle served in 6 slots
    assert b.stats["starvation_avoided"] >= 1


def test_weighted_fairness_respects_weights(make_broker_kw):
    """weight 3 vs 1: the heavy queue gets ~3 slots per cycle."""
    b = make_broker_kw(fairness="weighted",
                       queue_weights={"heavy": 3, "light": 1})
    b.put_many([new_task("real", {"i": i}, queue="heavy") for i in range(9)])
    b.put_many([new_task("real", {"i": i}, queue="light") for i in range(3)])
    got = [b.get(timeout=1).task.queue for _ in range(12)]
    # every consecutive window of 4 deliveries contains exactly 1 light
    for w in range(0, 12, 4):
        assert got[w:w + 4].count("light") == 1, got


def test_strict_priority_remains_default(make_broker_kw):
    b = make_broker_kw()
    b.put_many([new_task("real", {"i": i}, queue="flood") for i in range(10)])
    b.put(new_task("real", {}, queue="late"))
    first = [b.get(timeout=1).task.queue for _ in range(10)]
    assert first == ["flood"] * 10  # enqueue order wins, no rotation
    assert b.stats["starvation_avoided"] == 0


def test_filebroker_priority_out_of_range(tmp_path):
    """The filename encodes priority as %03d: out-of-range values must be
    rejected loudly (they would silently mis-sort on disk), on both the
    single and the batched put path."""
    b = FileBroker(str(tmp_path / "q"))
    for bad in (-1, 1000):
        with pytest.raises(ValueError):
            b.put(new_task("real", {}, priority=bad))
        with pytest.raises(ValueError):
            b.put_many([new_task("real", {}, priority=bad)])
    assert b.qsize() == 0  # nothing snuck onto disk


def test_weighted_rr_pick_on_stale_heap_forces_rescan(tmp_path):
    """The fairness race: the weighted RR pick lands on a queue whose only
    indexed names were already claimed by ANOTHER instance.  The rename
    races must fail over to other queues' work, mark the index stale, and
    force a disk re-list (bypassing the rescan throttle) so work this
    instance has never listed is found immediately instead of after the
    throttle window."""
    root = str(tmp_path / "q")
    # huge rescan_interval: only the stale-claim force can trigger a
    # re-list within this test's lifetime
    b1 = FileBroker(root, rescan_interval=60.0, fairness="weighted")
    b1.put_many([new_task("real", {"q": "flood", "i": i}, queue="flood")
                 for i in range(3)])
    b1.put(new_task("real", {"q": "trickle"}, queue="trickle"))
    # a second instance (another "allocation") claims EVERYTHING b1 has
    # indexed, so every entry in b1's heaps is now stale
    b2 = FileBroker(root, rescan_interval=0.0)
    stolen = b2.get_many(10, timeout=1)
    assert len(stolen) == 4
    # ...and enqueues fresh work b1 has never listed
    b2.put(new_task("real", {"q": "fresh"}, queue="flood"))
    # b1's claim round: every RR pick hits a stale name (rename fails),
    # the forced rescan finds b2's fresh task despite the 60s throttle
    lease = b1.get(timeout=2)
    assert lease is not None and lease.task.payload["q"] == "fresh"
    assert b1.stats["stale_claims"] >= 1
    b1.ack(lease.tag)
    for l in stolen:
        b2.ack(l.tag)
    assert b1.idle() and b2.idle()


def test_concurrent_claims_unique(tmp_path):
    """Atomic rename: concurrent getters never double-claim one task."""
    b = FileBroker(str(tmp_path / "q"))
    n = 30
    for i in range(n):
        b.put(new_task("real", {"i": i}))
    got, lock = [], threading.Lock()

    def worker():
        mine = FileBroker(str(tmp_path / "q"))
        while True:
            lease = mine.get(timeout=0.2)
            if lease is None:
                return
            with lock:
                got.append(lease.task.payload["i"])
            mine.ack(lease.tag)

    ts = [threading.Thread(target=worker) for _ in range(4)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert sorted(got) == list(range(n))


# ---------------------------------------------------------------------------
# per-queue max_queue_depth overrides
# ---------------------------------------------------------------------------

def test_per_queue_depth_override(make_broker_kw):
    """One queue bounded on an otherwise-unbounded broker: only it
    backpressures."""
    from repro.core.queue import BrokerFull
    b = make_broker_kw(put_timeout=0.2, queue_depths={"gen": 2})
    b.put(new_task("gen", {}, queue="gen"))
    b.put(new_task("gen", {}, queue="gen"))
    with pytest.raises(BrokerFull):
        b.put(new_task("gen", {}, queue="gen"))
    for _ in range(10):  # the sibling queue has no bound at all
        b.put(new_task("real", {}, queue="sims"))
    # draining frees capacity for the bounded queue again
    lease = b.get(timeout=1, queues=("gen",))
    b.ack(lease.tag)
    b.put(new_task("gen", {}, queue="gen"))


def test_per_queue_depth_tightens_and_clears(make_broker_kw):
    """set_max_queue_depth overrides the global bound per queue; None
    clears the override back to the global bound."""
    from repro.core.queue import BrokerFull
    b = make_broker_kw(put_timeout=0.2, max_queue_depth=5)
    b.set_max_queue_depth("gen", 1)
    b.put(new_task("gen", {}, queue="gen"))
    with pytest.raises(BrokerFull):  # override (1) beats the global (5)
        b.put(new_task("gen", {}, queue="gen"))
    b.set_max_queue_depth("gen", None)
    for _ in range(4):  # back on the global bound of 5
        b.put(new_task("gen", {}, queue="gen"))
    with pytest.raises(BrokerFull):
        b.put(new_task("gen", {}, queue="gen"))


def test_filebroker_depth_override_shared_across_instances(tmp_path):
    """Overrides persist to .depth.json: a fresh instance and an already-
    running one (after its sweep) both honor another instance's bound."""
    from repro.core.queue import BrokerFull, FileBroker
    root = str(tmp_path / "q")
    b1 = FileBroker(root, put_timeout=0.2)
    b2 = FileBroker(root, put_timeout=0.2)  # constructed BEFORE the override
    b1.set_max_queue_depth("gen", 1)
    b3 = FileBroker(root, put_timeout=0.2)  # constructed after: loads at init
    b3.put(new_task("gen", {}, queue="gen"))
    with pytest.raises(BrokerFull):
        b3.put(new_task("gen", {}, queue="gen"))
    # b2 learns the override via its sweep (idle() runs one)
    b2.idle()
    with pytest.raises(BrokerFull):
        b2.put(new_task("gen", {}, queue="gen"))


# -- FileBroker task-file format (v1 JSON text / v2 binary) -------------------

def _find_task_files(root):
    import os
    out = []
    for dirpath, _dirs, files in os.walk(root):
        for f in files:
            if f.endswith(".json") and not f.startswith("."):
                out.append(os.path.join(dirpath, f))
    return out


def test_task_file_format_sniffing_roundtrip():
    """encode_task_file picks v2 only when the payload carries a float
    array long enough to be worth it; decode sniffs the first byte, so
    both formats round-trip through the same reader."""
    from repro.core.queue import (TASK_FILE_V2_MAGIC, decode_task_file,
                                  encode_task_file)
    small = new_task("real", {"x": 1, "arr": [1.0, 2.0]}, queue="sims")
    big = new_task("real", {"arr": [float(i) for i in range(64)]},
                   queue="sims")
    enc_small = encode_task_file(small)        # auto -> v1 (greppable text)
    enc_big = encode_task_file(big)            # auto -> v2 (binary floats)
    assert enc_small[:1] == b"{"
    assert enc_big[:1] == TASK_FILE_V2_MAGIC
    # forcing either direction works regardless of payload shape
    assert encode_task_file(big, "json")[:1] == b"{"
    assert encode_task_file(small, "binary")[:1] == TASK_FILE_V2_MAGIC
    for enc, src in ((enc_small, small), (enc_big, big),
                     (encode_task_file(big, "json"), big),
                     (encode_task_file(small, "binary"), small)):
        got = decode_task_file(enc)
        assert got.id == src.id and got.queue == src.queue
        assert got.payload == src.payload and got.priority == src.priority


def test_task_file_v2_rejects_non_task_document():
    from repro.core.queue import TASK_FILE_V2_MAGIC, decode_task_file
    from repro.core.wirecodec import BIN_CODEC
    with pytest.raises(ValueError, match="task object"):
        decode_task_file(TASK_FILE_V2_MAGIC + BIN_CODEC.encode([1, 2, 3]))


def test_filebroker_task_format_validated(tmp_path):
    with pytest.raises(ValueError, match="task_format"):
        FileBroker(str(tmp_path / "q"), task_format="msgpack")


def test_filebroker_mixed_format_directory_drains(tmp_path):
    """Rolling upgrade: a v1-only producer and a binary producer share one
    queue root; any instance drains both formats transparently."""
    from repro.core.queue import TASK_FILE_V2_MAGIC
    root = str(tmp_path / "q")
    old = FileBroker(root, task_format="json")
    new = FileBroker(root, task_format="binary")
    old.put(new_task("real", {"src": "v1", "i": 0}, queue="sims"))
    new.put(new_task("real", {"src": "v2",
                              "arr": [float(i) for i in range(32)]},
                     queue="sims"))
    firsts = set()
    for path in _find_task_files(root):
        with open(path, "rb") as f:
            firsts.add(f.read(1))
    assert firsts == {b"{", TASK_FILE_V2_MAGIC}  # both formats on disk
    reader = FileBroker(root)  # auto: reads both, writes by payload shape
    seen = {}
    for _ in range(2):
        lease = reader.get(timeout=1, queues=("sims",))
        assert lease is not None
        seen[lease.task.payload["src"]] = lease.task
        reader.ack(lease.tag)
    assert set(seen) == {"v1", "v2"}
    assert seen["v2"].payload["arr"] == [float(i) for i in range(32)]


def test_filebroker_v2_survives_nack_rewrite(tmp_path):
    """nack rewrites the task file (retries bump); a binary-format broker
    must keep the rewritten file decodable and the retry count durable."""
    root = str(tmp_path / "q")
    b = FileBroker(root, task_format="binary", visibility_timeout=5.0)
    b.put(new_task("real", {"arr": [float(i) for i in range(32)]},
                   queue="sims"))
    lease = b.get(timeout=1, queues=("sims",))
    b.nack(lease.tag)
    again = FileBroker(root).get(timeout=1, queues=("sims",))  # fresh reader
    assert again is not None and again.task.retries == 1
    assert again.task.payload["arr"][-1] == 31.0
