"""Execution handlers: script rendering, the three built-in mechanisms,
MockScheduler's submit->poll lifecycle, and failure surfaces."""
import os
import time

import numpy as np
import pytest

from repro.core.handlers import (FnStepHandler, HandlerError, MockScheduler,
                                 SchedulerJobHandler, SubprocessHandler,
                                 default_handlers, render_script)
from repro.core.runtime import Context, MerlinRuntime
from repro.core.spec import Step


def _ctx(rt, tmp_path, combo=None, lo=0, hi=2):
    ws = str(tmp_path / "wdir")
    os.makedirs(ws, exist_ok=True)
    return Context(rt, "t", combo or {}, np.zeros((4, 2), np.float32),
                   lo, hi, ws, {"OUT": "/tmp/o"})


def test_default_registry_names():
    h = default_handlers()
    assert set(h) == {"fn", "subprocess", "scheduler"}
    assert h["fn"].inprocess and not h["subprocess"].inprocess
    assert not h["scheduler"].inprocess


def test_render_script_substitutes_env(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path))
    ctx = _ctx(rt, tmp_path, combo={"METRO": "NYC"}, lo=3, hi=7)
    step = Step(name="s", cmd="echo $(METRO) $(SAMPLE_LO)-$(SAMPLE_HI) "
                               "$(OUT) $(MERLIN_STUDY)")
    script = render_script(step, ctx)
    body = open(script).read()
    assert "NYC 3-7 /tmp/o t" in body
    assert script.endswith("s.sh")


def test_fn_handler_runs_registered_fn(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path))
    seen = []
    rt.register("go", lambda ctx: seen.append(ctx.lo))
    FnStepHandler().execute(rt, Step(name="s", fn="go"), _ctx(rt, tmp_path))
    assert seen == [0]


def test_fn_handler_unregistered_fn_raises(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path))
    with pytest.raises(HandlerError, match="not registered"):
        FnStepHandler().execute(rt, Step(name="s", fn="missing"),
                                _ctx(rt, tmp_path))
    with pytest.raises(HandlerError, match="needs fn"):
        FnStepHandler().execute(rt, Step(name="s", cmd="true"),
                                _ctx(rt, tmp_path))


def test_subprocess_handler_runs_and_fails(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path))
    ctx = _ctx(rt, tmp_path)
    SubprocessHandler().execute(rt, Step(name="ok", cmd="echo hi > out.txt"),
                                ctx)
    assert open(os.path.join(ctx.workspace, "out.txt")).read() == "hi\n"
    with pytest.raises(HandlerError, match="rc=3"):
        SubprocessHandler().execute(rt, Step(name="bad", cmd="exit 3"), ctx)


def test_mock_scheduler_lifecycle(tmp_path):
    sched = MockScheduler(hold_s=0.15)
    script = str(tmp_path / "job.sh")
    open(script, "w").write("echo done > marker\n")
    jid = sched.submit(script, str(tmp_path), {"nodes": 2})
    assert sched.status(jid) == "PENDING"  # held before launch
    deadline = time.monotonic() + 10
    while sched.status(jid) != "COMPLETED":
        assert time.monotonic() < deadline
        time.sleep(0.01)
    assert (tmp_path / "marker").exists()
    assert sched.submitted == 1
    assert sched.jobs[jid]["resources"] == {"nodes": 2}
    with pytest.raises(HandlerError, match="unknown job"):
        sched.status("mock-nope")


def test_scheduler_handler_polls_to_completion(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path))
    h = SchedulerJobHandler(scheduler=MockScheduler(hold_s=0.05),
                            poll_s=0.01, timeout=30)
    ctx = _ctx(rt, tmp_path)
    h.execute(rt, Step(name="j", cmd="echo x > res.txt"), ctx)
    assert (tmp_path / "wdir" / "res.txt").exists()


def test_scheduler_handler_failed_job_raises(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path))
    h = SchedulerJobHandler(scheduler=MockScheduler(), poll_s=0.01,
                            timeout=30)
    with pytest.raises(HandlerError, match="FAILED"):
        h.execute(rt, Step(name="j", cmd="exit 1"), _ctx(rt, tmp_path))


def test_scheduler_handler_timeout_cancels(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path))
    sched = MockScheduler()
    h = SchedulerJobHandler(scheduler=sched, poll_s=0.01, timeout=0.2)
    with pytest.raises(HandlerError, match="timed out"):
        h.execute(rt, Step(name="j", cmd="sleep 30"), _ctx(rt, tmp_path))
    # the runaway job was cancelled, not leaked
    (job,) = sched.jobs.values()
    deadline = time.monotonic() + 5
    while job["proc"].poll() is None:
        assert time.monotonic() < deadline
        time.sleep(0.01)
    assert job["proc"].poll() != 0


def test_subprocess_per_step_timeout_kills_child(tmp_path):
    """A step-level ``timeout:`` overrides the handler default and the
    child is killed at the wall-clock deadline, not left running."""
    rt = MerlinRuntime(workspace=str(tmp_path))
    h = SubprocessHandler(timeout=600.0)  # generous default, tight step
    t0 = time.monotonic()
    with pytest.raises(HandlerError, match=r"timed out after 0.3s"):
        h.execute(rt, Step(name="slow", cmd="sleep 30", timeout=0.3),
                  _ctx(rt, tmp_path))
    assert time.monotonic() - t0 < 10  # the 600s default did NOT apply


def test_scheduler_per_step_timeout_cancels_job(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path))
    sched = MockScheduler()
    h = SchedulerJobHandler(scheduler=sched, poll_s=0.01, timeout=600.0)
    with pytest.raises(HandlerError, match="timed out"):
        h.execute(rt, Step(name="j", cmd="sleep 30", timeout=0.2),
                  _ctx(rt, tmp_path))
    (job,) = sched.jobs.values()
    deadline = time.monotonic() + 5
    while job["proc"].poll() is None:
        assert time.monotonic() < deadline
        time.sleep(0.01)
    assert job["proc"].poll() != 0  # cancelled at the step deadline


def test_handler_name_resolution_via_step():
    assert Step(name="a", fn="f").handler_name() == "fn"
    assert Step(name="a", cmd="true").handler_name() == "subprocess"
    assert Step(name="a", cmd="true",
                handler="scheduler").handler_name() == "scheduler"
