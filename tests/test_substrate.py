"""Optimizer / checkpoint / data / sharding substrate tests."""
import os
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.ckpt.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.data.pipeline import SyntheticTokens, quantize_record
from repro.parallel import sharding as shd
from repro.train.optimizer import adafactor, adamw, ef_compress, make_optimizer


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def quad_target():
    return {"w": jnp.zeros(4), "m": jnp.zeros((3, 5))}


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_converges_on_quadratic(name):
    t1 = jnp.array([1.0, -2.0, 3.0, 0.5])
    t2 = jnp.arange(15.0).reshape(3, 5) / 10
    p = quad_target()
    opt = make_optimizer(name, lr=0.05, wd=0.0) if name == "adamw" else \
        make_optimizer(name, lr=0.05)
    st = opt.init(p)

    def loss(pp):
        return jnp.sum((pp["w"] - t1) ** 2) + jnp.sum((pp["m"] - t2) ** 2)

    for _ in range(400):
        g = jax.grad(loss)(p)
        p, st = opt.update(g, st, p)
    assert float(loss(p)) < 1e-2


def test_adafactor_state_is_factored():
    p = {"big": jnp.zeros((64, 128)), "vec": jnp.zeros(10)}
    st = adafactor().init(p)
    assert st["f"]["big"]["vr"].shape == (64,)
    assert st["f"]["big"]["vc"].shape == (128,)
    assert st["f"]["vec"]["v"].shape == (10,)


def test_ef_compression_converges_and_carries_residual():
    t = jnp.array([1.0, -2.0, 3.0])
    p = {"w": jnp.zeros(3)}
    opt = ef_compress(adamw(lr=0.05, wd=0.0), bits=8)
    st = opt.init(p)
    for _ in range(300):
        g = jax.grad(lambda pp: jnp.sum((pp["w"] - t) ** 2))(p)
        p, st = opt.update(g, st, p)
    assert float(jnp.abs(p["w"] - t).max()) < 0.05


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": [jnp.ones(4),
                                                      {"c": jnp.zeros(2)}]}
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for step in [5, 10, 15]:
        mgr.save(step, tree)
    assert mgr.steps() == [10, 15]  # gc kept last 2
    like = jax.eval_shape(lambda: tree)
    got = mgr.restore(15, like=like)
    assert np.allclose(got["a"], tree["a"])
    assert np.allclose(got["b"][1]["c"], 0)


def test_checkpoint_atomic_no_partial_reads(tmp_path):
    tree = {"a": jnp.ones(3)}
    save_pytree(str(tmp_path / "ck"), tree)
    # a leftover tmp dir from a crashed writer must be ignored
    os.makedirs(str(tmp_path / "ck2.tmp"))
    mgr = CheckpointManager(str(tmp_path), keep=5)
    assert mgr.steps() == []  # tmp/non-manifest dirs invisible


def test_trainer_restart_resumes(tmp_path):
    from repro.configs import registry
    from repro.train.trainer import Trainer
    cfg = registry.get_config("jag-surrogate").replace(
        n_repeat=1, n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=128)
    tr = Trainer(cfg, str(tmp_path), iter(SyntheticTokens(2, 16, 128)),
                 ckpt_every=3)
    tr.train(5)
    tr2 = Trainer(cfg, str(tmp_path), iter(SyntheticTokens(2, 16, 128)),
                  ckpt_every=3)
    st = tr2.restore_or_init()
    assert int(st.step) == 5
    st = tr2.train(7, state=st)
    assert int(st.step) == 7
    assert tr2.history[0]["step"] == 6  # resumed, not restarted


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_tokens_deterministic_and_step_addressable():
    a = SyntheticTokens(4, 16, 1000, seed=3)
    b = SyntheticTokens(4, 16, 1000, seed=3)
    x, y = next(a), next(b)
    assert np.array_equal(x["tokens"], y["tokens"])
    assert np.array_equal(a.batch_at(7)["tokens"], b.batch_at(7)["tokens"])
    assert not np.array_equal(a.batch_at(7)["tokens"],
                              a.batch_at(8)["tokens"])
    assert x["tokens"].max() < 1000 and x["tokens"].min() >= 0
    # next-token alignment
    assert np.array_equal(x["tokens"][:, 1:], x["labels"][:, :-1])


def test_quantize_record_disjoint_fields():
    toks = quantize_record(np.array([0.1, 0.9]), np.array([0.5]), vocab=1024,
                           bins_per_field=256)
    assert toks.shape == (3,)
    assert 0 <= toks[0] < 256 and 256 <= toks[1] < 512 and 512 <= toks[2] < 768


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

FAKE_MESH = types.SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16})
RULES = {k: v for k, v in shd.DEFAULT_RULES.items()}


def test_spec_divisibility_fallback():
    # 24 heads don't divide 16 -> replicated; 32 do -> sharded
    s = shd.spec_for((2, 24, 128), (None, "heads", None), FAKE_MESH, RULES)
    assert s == P(None, None, None)
    s = shd.spec_for((2, 32, 128), (None, "heads", None), FAKE_MESH, RULES)
    assert s == P(None, "model", None)


def test_spec_multi_axis_batch():
    s = shd.spec_for((256, 4096), ("batch", None), FAKE_MESH, RULES)
    assert s == P(("pod", "data"), None)
    # batch=1 falls back to replicated
    s = shd.spec_for((1, 4096), ("batch", None), FAKE_MESH, RULES)
    assert s == P(None, None)


def test_spec_no_double_axis_use():
    # two logical dims mapping to "model": only the first gets it
    s = shd.spec_for((64, 32), ("vocab", "heads"), FAKE_MESH, RULES)
    assert s == P("model", None)


def test_param_spec_scan_stacked():
    s = shd.param_spec(("blocks", "0", "attn", "wq"), (12, 4096, 4096),
                       FAKE_MESH, RULES)
    assert s == P(None, "data", "model")
    # embed: vocab-sharded only (fsdp on d_model broke the token gather
    # under GSPMD — see DESIGN.md §5b)
    s = shd.param_spec(("embed",), (256000, 4608), FAKE_MESH, RULES)
    assert s == P("model", None)
    # granite's 49155 vocab is not divisible by 16 -> replicated vocab dim
    s = shd.param_spec(("embed",), (49155, 4096), FAKE_MESH, RULES)
    assert s == P(None, None)


def test_constrain_is_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert shd.constrain(x, "batch", None) is x
