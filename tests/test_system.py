"""End-to-end behaviour of the Merlin system: full studies through broker +
workers + hierarchy + bundler, resilience stories, surge workers."""
import os
import time

import numpy as np
import pytest

from repro.core import (Bundler, MerlinRuntime, Step, StudySpec, WorkerPool)
from repro.core.hierarchy import HierarchyCfg
from repro.core.resilience import crawl_and_resubmit


def make_runtime(tmp_path, fanout=3, bundle=4):
    return MerlinRuntime(workspace=str(tmp_path / "ws"),
                         hierarchy=HierarchyCfg(max_fanout=fanout, bundle=bundle))


def test_full_study_chain_and_funnel(tmp_path):
    rt = make_runtime(tmp_path)
    b = Bundler(str(tmp_path / "res"), files_per_leaf=5)
    post_calls = []

    rt.register("sim", lambda ctx: b.write_bundle(
        ctx.lo, ctx.hi, {"y": (ctx.sample_block ** 2).sum(axis=1)}))
    rt.register("post", lambda ctx: post_calls.append(
        [tuple(r) for r in ctx.sub_ranges]))
    collected = {}

    def collect(ctx):
        present, corrupt = b.crawl()
        collected["n"] = len(present)
    rt.register("collect", collect)

    spec = StudySpec(name="demo", steps=[
        Step(name="sim", fn="sim"),
        Step(name="post", fn="post", depends=("sim",)),
        Step(name="collect", fn="collect", depends=("post_*",),
             over_samples=False)])
    samples = np.random.default_rng(0).random((97, 5)).astype(np.float32)
    with WorkerPool(rt, n_workers=4) as pool:
        sid = rt.run(spec, samples)
        assert rt.wait(sid, timeout=60)
    data = b.load_all()
    assert np.allclose(data["y"], (samples ** 2).sum(1), rtol=1e-5)
    assert collected["n"] == 97
    # the execution engine may fuse contiguous bundles across workers into
    # one fn-step invocation, but the sub_ranges contract preserves the 25
    # per-bundle spans (ceil(97/4)) exactly, with full coverage
    spans = sorted(r for call in post_calls for r in call)
    assert spans == [(lo, min(lo + 4, 97)) for lo in range(0, 97, 4)]
    assert len(post_calls) <= 25


def test_parameter_sample_layering(tmp_path):
    """Fig. 1: each DAG parameter combo runs the full sample hierarchy."""
    rt = make_runtime(tmp_path, bundle=8)
    seen = []
    # per sub-range, not per fn call: the engine may fuse contiguous
    # bundles of one combo into a single invocation
    rt.register("sim", lambda ctx: seen.extend(
        (ctx.combo["SCALE"], lo) for lo, _ in ctx.sub_ranges))
    spec = StudySpec(name="p", steps=[Step(name="sim", fn="sim")],
                     parameters={"SCALE": [0.9, 1.1]})
    with WorkerPool(rt, n_workers=3) as pool:
        sid = rt.run(spec, np.zeros((32, 2), np.float32))
        assert rt.wait(sid, timeout=60)
    scales = {s for s, _ in seen}
    assert scales == {0.9, 1.1}
    assert len(seen) == 2 * 4  # 2 combos x ceil(32/8) bundles


def test_shell_steps_execute(tmp_path):
    rt = make_runtime(tmp_path, bundle=16)
    spec = StudySpec(name="sh", steps=[
        Step(name="touch", cmd="echo $(SAMPLE_LO)-$(SAMPLE_HI) > out.txt")])
    with WorkerPool(rt, n_workers=2) as pool:
        sid = rt.run(spec, np.zeros((32, 1), np.float32))
        assert rt.wait(sid, timeout=60)
    outs = []
    for root, _, files in os.walk(rt.workspace):
        outs += [os.path.join(root, f) for f in files if f == "out.txt"]
    assert len(outs) == 2
    contents = sorted(open(p).read().strip() for p in outs)
    assert contents == ["0-16", "16-32"]


def test_surge_workers_join_midstudy(tmp_path):
    """Sec. 3.1 'worker farm': capacity added mid-run picks up queued work."""
    rt = make_runtime(tmp_path, bundle=1, fanout=4)
    rt.register("slow", lambda ctx: time.sleep(0.05))
    spec = StudySpec(name="surge", steps=[Step(name="slow", fn="slow")])
    pool = WorkerPool(rt, n_workers=1)
    try:
        sid = rt.run(spec, np.zeros((40, 1), np.float32))
        time.sleep(0.3)
        pool.scale(5)  # surge
        assert rt.wait(sid, timeout=60)
        # wait() can return between a worker's final once-marker and its
        # stats increment; drain (idle broker = all acks done, and acks
        # follow the increment) makes the counter read deterministic
        pool.drain(timeout=20)
        stats = pool.stats()
        assert stats["real"] == 40
        # the surged workers actually took work
        per_worker = [w.stats["real"] for w in pool.workers]
        assert sum(1 for c in per_worker[1:] if c > 0) >= 3
    finally:
        pool.shutdown()


def test_worker_death_recovery_and_crawl_resubmit(tmp_path):
    """The 70% -> 99.755% story of Sec. 3.1, in miniature."""
    rt = make_runtime(tmp_path, bundle=2, fanout=4)
    rt.broker._vt = 0.3
    b = Bundler(str(tmp_path / "res"))
    rt.register("sim", lambda ctx: b.write_bundle(
        ctx.lo, ctx.hi, {"y": np.ones(ctx.hi - ctx.lo)}))
    spec = StudySpec(name="sim", steps=[Step(name="sim", fn="sim")])
    with WorkerPool(rt, n_workers=4, failure_rate=0.3, seed=7) as pool:
        sid = rt.run(spec, np.zeros((100, 2), np.float32))
        rt.wait(sid, timeout=90)
        pool.drain(timeout=20)
        tmpl = {"study": sid, "stage": 0, "combo": 0, "n_samples": 100,
                "fanout": 4, "bundle": 2}
        for _ in range(4):
            missing, _ = crawl_and_resubmit(b, 100, rt.broker, tmpl, bundle=2)
            if missing == 0:
                break
            pool.drain(timeout=30)
    present, corrupt = b.crawl()
    assert len(present) == 100
    assert not corrupt
    assert rt.broker.stats["redelivered"] > 0  # failures actually happened


def test_restart_from_journal(tmp_path):
    """Journal replay: a fresh runtime sees completed bundles."""
    rt = make_runtime(tmp_path, bundle=4)
    rt.register("sim", lambda ctx: None)
    spec = StudySpec(name="j", steps=[Step(name="sim", fn="sim")])
    with WorkerPool(rt, n_workers=2) as pool:
        sid = rt.run(spec, np.zeros((16, 1), np.float32))
        assert rt.wait(sid, timeout=60)
    done = rt.journal.done_bundles(sid)
    assert len(done) == 4
    events = [e["ev"] for e in rt.journal.replay()]
    assert "study_start" in events and "stage_done" in events
