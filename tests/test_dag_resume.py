"""Crash-resume on a diamond DAG: a worker process dies mid-graph (after
prep, during left), a FRESH process attaches to the persisted study and
resumes it; completed nodes must not re-execute (exactly-once audit via
the once-marker counters) and downstream unlock order must hold."""
import os
import subprocess
import sys

import numpy as np

from repro.core.queue import FileBroker
from repro.core.runtime import MerlinRuntime
from repro.core.spec import Step, StudySpec
from repro.core.worker import WorkerPool

STUDY = "diacrash"

# the crashing first allocation: left os._exit(17)s the whole process the
# moment it runs — which is necessarily after prep's advance unlocked it
CHILD = r"""
import os, sys, time
import numpy as np
from repro.core.queue import FileBroker
from repro.core.runtime import MerlinRuntime
from repro.core.spec import Step, StudySpec
from repro.core.worker import WorkerPool

root, ws = sys.argv[1], sys.argv[2]

def log(name, ctx):
    with open(os.path.join(ws, "exec.log"), "a") as f:
        f.write(f"{name} {ctx.lo} {ctx.hi}\n")

rt = MerlinRuntime(broker=FileBroker(root, visibility_timeout=600),
                   workspace=ws)
rt.register("prep", lambda ctx: log("prep", ctx))

def left(ctx):
    log("left", ctx)
    os._exit(17)

rt.register("left", left)
rt.register("right", lambda ctx: log("right", ctx))
rt.register("join", lambda ctx: log("join", ctx))
spec = StudySpec(name="dia", steps=[
    Step(name="prep", fn="prep"),
    Step(name="left", fn="left", depends=("prep",)),
    Step(name="right", fn="right", depends=("prep",)),
    Step(name="join", fn="join", depends=("left", "right"),
         over_samples=False)])
with WorkerPool(rt, n_workers=2):
    rt.run(spec, samples=np.zeros((4, 2), np.float32), study_id=sys.argv[3])
    time.sleep(120)  # killed from inside left long before this expires
"""


def _register_fns(rt, ws):
    def log(name):
        def fn(ctx):
            with open(os.path.join(ws, "exec.log"), "a") as f:
                f.write(f"{name} {ctx.lo} {ctx.hi}\n")
        return fn
    for name in ("prep", "left", "right", "join"):
        rt.register(name, log(name))


def test_crash_and_attach_resumes_exactly_once(tmp_path):
    root, ws = str(tmp_path / "broker"), str(tmp_path / "ws")
    os.makedirs(ws, exist_ok=True)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src")]
        + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])
    proc = subprocess.run([sys.executable, "-c", CHILD, root, ws, STUDY],
                          capture_output=True, text=True, env=env,
                          timeout=180)
    assert proc.returncode == 17, proc.stderr[-1000:]

    # -- fresh process (this one): attach, audit, resume -------------------
    rt = MerlinRuntime(broker=FileBroker(root, visibility_timeout=600),
                       workspace=ws)
    _register_fns(rt, ws)
    study = rt.attach(STUDY)
    assert not rt.study_done(study)
    # prep completed and advanced in the crashed allocation
    assert rt.counters.once_exists(f"{STUDY}/s0/c0/advance")
    # left started but never completed: no advance, so join never unlocked
    assert not rt.counters.once_exists(f"{STUDY}/s1/c0/advance")
    assert not rt.counters.once_exists(f"{STUDY}/s3/c0/enqueue")

    log_path = os.path.join(ws, "exec.log")
    pre = open(log_path).read().splitlines()

    requeued = rt.resume(study)
    assert (1, 0) in requeued  # left is ready (parent done) and incomplete
    assert (0, 0) not in requeued  # prep must NOT be re-armed
    # no pool.drain here: the crashed allocation's stale lease (600s
    # visibility) keeps the broker non-idle; study completion is the
    # signal that matters
    with WorkerPool(rt, n_workers=2):
        assert rt.wait(study, timeout=120)

    # -- exactly-once audit: each bundle's done-marker was claimed exactly
    # once across BOTH allocations, so the per-instance completion counter
    # sits at precisely its expected bundle count (4 leaf bundles for the
    # parallel nodes, 1 for the funnel join) — never double-counted
    for n, expected in ((0, 4), (1, 4), (2, 4), (3, 1)):
        assert rt.counters.get(f"{STUDY}/s{n}/c0") == expected
        assert rt.counters.once_exists(f"{STUDY}/s{n}/c0/advance")
    # the resumed allocation appended to the log, never re-ran prep
    post = open(log_path).read().splitlines()
    assert post[:len(pre)] == pre
    new_steps = {ln.split()[0] for ln in post[len(pre):]}
    assert "prep" not in new_steps  # done nodes are not re-executed
    assert "join" in new_steps      # the blocked fan-in finally ran
    assert "left" in new_steps      # the crashed node was re-executed

    # -- downstream unlock order survives the crash boundary ---------------
    state = rt.dag_state(study)["state"]
    assert all(v["status"] == "done" for v in state.values())
    ep = {k: v["epoch"] for k, v in state.items()}
    assert ep["s0/c0"] < ep["s1/c0"] < ep["s3/c0"]
    assert ep["s0/c0"] < ep["s2/c0"] < ep["s3/c0"]
    events = [e["ev"] for e in rt.journal.replay()]
    assert "study_resume" in events and "study_done" in events
