"""Elastic rescale: train state checkpointed on one device topology resumes
on a different mesh (the framework's answer to "a pod went away").

Subprocess forces 8 host devices (device count locks at jax init); inside:
save single-device state -> restore with a (2,4) mesh's sharding tree ->
one sharded train step -> loss matches the unsharded continuation.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.configs import registry
    from repro.train.trainstep import init_state, make_train_step, TrainState
    from repro.train.optimizer import make_optimizer
    from repro.ckpt.checkpoint import save_pytree, restore_pytree
    from repro.parallel.sharding import param_spec_tree
    from repro.data.pipeline import SyntheticTokens

    cfg = registry.reduced_config("granite-3-8b").replace(
        d_model=64, n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
        vocab_size=512, microbatch=2)
    opt = make_optimizer(cfg.optimizer, lr=1e-3)
    state = init_state(jax.random.PRNGKey(0), cfg, opt)
    data = SyntheticTokens(8, 16, cfg.vocab_size)

    # "pod 1": unsharded steps 0..2, checkpoint at 2
    step1 = jax.jit(make_train_step(cfg, opt))
    for i in range(2):
        state, m = step1(state, data.batch_at(i))
    save_pytree("/tmp/elastic_ck", tuple(state))
    ref_state, ref_m = step1(state, data.batch_at(2))
    ref_loss = float(ref_m["loss"])

    # "pod 2": different topology — restore RESHARDED onto a (2,4) mesh
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    template = jax.eval_shape(lambda: init_state(jax.random.PRNGKey(0), cfg, opt))
    shardings = TrainState(
        param_spec_tree(template.params, mesh),
        jax.tree.map(lambda _: None, template.opt),  # opt: default placement
        None)
    restored = TrainState(*restore_pytree("/tmp/elastic_ck", tuple(template),
                                          tuple(shardings)))
    assert int(restored.step) == 2
    # params actually live sharded now
    sh = jax.tree.leaves(restored.params)[1].sharding
    assert getattr(sh, "mesh", None) is not None

    step2 = jax.jit(make_train_step(cfg, opt, mesh=mesh))
    new_state, m = step2(restored, data.batch_at(2))
    loss = float(m["loss"])
    print("REF", ref_loss, "ELASTIC", loss)
    assert abs(loss - ref_loss) / ref_loss < 1e-3, (loss, ref_loss)
    print("OK")
""")


@pytest.mark.slow
def test_elastic_rescale_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "OK" in r.stdout
