"""Shared-memory transport (core/shmring.py): the SPSC ring, the
``shm://`` broker channel (pipelined acks + consumer prefetch), and the
Bundler's BundleRing write sink.

Ring and BundleRing tests touch only /dev/shm.  Served-broker tests also
open unix-domain doorbell sockets, so they carry the ``net`` marker for
restricted sandboxes (same convention as test_netbroker.py).
"""
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core.bundler import Bundler
from repro.core.netbroker import BrokerServer, make_broker
from repro.core.queue import (Broker, BrokerError, InMemoryBroker, Lease,
                              Task, new_task)
from repro.core.shmring import (BundleRing, ShmBroker, ShmListener, ShmRing)


# ---------------------------------------------------------------------------
# ShmRing: the SPSC byte ring
# ---------------------------------------------------------------------------

@pytest.fixture
def ring():
    r = ShmRing(create=True, capacity=256)
    yield r
    r.close()
    r.unlink()


def test_ring_fifo_roundtrip(ring):
    for i in range(5):
        assert ring.try_push(b"rec%d" % i)
    got = []
    while True:
        rec = ring.try_pop()
        if rec is None:
            break
        got.append(rec)
    assert got == [b"rec%d" % i for i in range(5)]
    assert ring.try_pop() is None


def test_ring_peek_has_no_side_effects(ring):
    assert not ring.try_peek()
    ring.try_push(b"x")
    assert ring.try_peek()
    assert ring.try_peek()  # still there
    assert ring.try_pop() == b"x"
    assert not ring.try_peek()


def test_ring_wraps_around_the_tail_fragment(ring):
    # records sized so cursors repeatedly land mid-ring and the u32 wrap
    # marker (or a too-small tail fragment) must be skipped
    payloads = [bytes([i % 256]) * (17 + 7 * (i % 13)) for i in range(400)]
    it = iter(payloads)
    got, pending = [], 0
    backlog = []
    for p in it:
        while not ring.try_push(p):  # full: drain one record first
            rec = ring.try_pop()
            assert rec is not None
            got.append(rec)
    while True:
        rec = ring.try_pop()
        if rec is None:
            break
        got.append(rec)
    assert got == payloads


def test_ring_full_returns_false_oversize_raises(ring):
    big = b"z" * (ring.capacity + 1)
    with pytest.raises(ValueError):
        ring.try_push(big)
    filler = b"f" * 100
    while ring.try_push(filler):
        pass
    assert not ring.try_push(filler)  # full, not an error
    assert ring.try_pop() == filler
    assert ring.try_push(filler)  # space reclaimed


def test_ring_blocking_push_pop_timeout(ring):
    assert ring.pop(timeout=0.05) is None  # empty: times out
    assert ring.push(b"a", timeout=0.05)
    assert ring.pop(timeout=0.05) == b"a"
    while ring.try_push(b"b" * 100):
        pass
    assert not ring.push(b"b" * 100, timeout=0.05)  # full: times out


def test_ring_doorbell_elision_flag(ring):
    # caught-up consumer (empty ring) -> producer must ring its doorbell
    assert ring.try_push(b"one")
    assert ring.consumer_was_caught_up
    # backlog present -> the earlier record's wakeup byte still covers us
    assert ring.try_push(b"two")
    assert not ring.consumer_was_caught_up
    ring.try_pop()
    ring.try_pop()
    assert ring.try_push(b"three")
    assert ring.consumer_was_caught_up


def test_ring_cross_process_attach(ring):
    ring.try_push(b"parent->child")
    code = (
        "import sys\n"
        "from repro.core.shmring import ShmRing\n"
        "r = ShmRing(name=sys.argv[1])\n"
        "assert r.try_pop() == b'parent->child'\n"
        "assert r.try_push(b'child->parent')\n"
        "r.close()\n"
    )
    env = {**os.environ,
           "PYTHONPATH": os.path.join(os.path.dirname(os.path.dirname(
               os.path.abspath(__file__))), "src")
           + os.pathsep + os.environ.get("PYTHONPATH", "")}
    subprocess.run([sys.executable, "-c", code, ring.name],
                   check=True, env=env, timeout=30)
    assert ring.pop(timeout=1.0) == b"child->parent"


# ---------------------------------------------------------------------------
# ShmBroker over a served registry
# ---------------------------------------------------------------------------

@pytest.fixture
def served_shm(tmp_path):
    backend = InMemoryBroker(visibility_timeout=2.0)
    srv = BrokerServer(backend, shm_path=str(tmp_path / "ring")).start()
    client = ShmBroker(str(tmp_path / "ring"))
    yield backend, srv, client
    client.close()
    srv.stop()


@pytest.mark.net
def test_shm_broker_satisfies_protocol_and_url(served_shm, tmp_path):
    _backend, _srv, client = served_shm
    assert isinstance(client, Broker)
    assert client.address == f"shm://{tmp_path / 'ring'}"
    via_url = make_broker(client.address)
    assert isinstance(via_url, ShmBroker)
    assert via_url.ping()
    via_url.close()


@pytest.mark.net
def test_shm_put_get_ack_drain(served_shm):
    _backend, _srv, client = served_shm
    client.put_many([new_task("k", {"i": i}) for i in range(100)])
    assert client.qsize() == 100
    seen = []
    while True:
        leases = client.get_many(8, timeout=0.2)
        if not leases:
            break
        assert all(isinstance(l, Lease) for l in leases)
        seen.extend(l.task.payload["i"] for l in leases)
        client.ack_many([l.tag for l in leases])
    assert sorted(seen) == list(range(100))
    assert client.qsize() == 0
    assert client.inflight() == 0


@pytest.mark.net
def test_shm_stats_report_transport(served_shm):
    _backend, _srv, client = served_shm
    s = client.stats
    assert s["transport"] == "shm"
    assert s["wire_codec"] == "bin1"


@pytest.mark.net
def test_shm_queue_selectors(served_shm):
    _backend, _srv, client = served_shm
    client.put(new_task("k", {}, queue="qa"))
    client.put(new_task("k", {}, queue="qb"))
    la = client.get(timeout=0.5, queues=["qa"])
    assert la is not None and la.task.queue == "qa"
    client.ack(la.tag)
    assert client.qsize(queues=["qb"]) == 1
    assert set(client.queue_names()) >= {"qb"}


@pytest.mark.net
def test_shm_nack_redelivers(served_shm):
    _backend, _srv, client = served_shm
    client.put(new_task("k", {"x": 1}))
    lease = client.get(timeout=0.5)
    client.nack(lease.tag)
    again = client.get(timeout=2.0)
    assert again is not None and again.task.payload == {"x": 1}
    assert again.task.retries == lease.task.retries + 1
    client.ack(again.tag)


@pytest.mark.net
def test_shm_visibility_timeout_redelivery(tmp_path):
    backend = InMemoryBroker(visibility_timeout=0.3)
    srv = BrokerServer(backend, shm_path=str(tmp_path / "ring")).start()
    client = ShmBroker(str(tmp_path / "ring"), prefetch=0)
    try:
        client.put(new_task("k", {"v": 7}))
        first = client.get(timeout=0.5)
        assert first is not None  # leased, never acked: lease must expire
        again = client.get(timeout=2.0)
        assert again is not None and again.task.payload == {"v": 7}
        client.ack(again.tag)
    finally:
        client.close()
        srv.stop()


@pytest.mark.net
def test_shm_put_many_bisects_oversized_batches(served_shm):
    _backend, _srv, client = served_shm
    # ~100 KiB per payload, 24 tasks: the single put_many frame exceeds
    # the 1 MiB request ring and must split transparently
    blob = "x" * (100 * 1024)
    client.put_many([new_task("k", {"i": i, "blob": blob})
                     for i in range(24)])
    assert client.qsize() == 24
    got = 0
    while got < 24:
        leases = client.get_many(4, timeout=1.0)
        assert leases
        assert all(len(l.task.payload["blob"]) == len(blob) for l in leases)
        client.ack_many([l.tag for l in leases])
        got += len(leases)


@pytest.mark.net
def test_shm_single_task_too_large_raises(served_shm):
    _backend, _srv, client = served_shm
    with pytest.raises(BrokerError, match="too large"):
        client.put(new_task("k", {"blob": "x" * (2 << 20)}))


@pytest.mark.net
def test_shm_deferred_failure_raises_on_next_sync_op(served_shm):
    """Pipelined-ack contract: a deferred op's failure is reported
    out-of-band by the NEXT synchronous call, with the deferred op
    named — and the channel stays usable afterwards."""
    _backend, _srv, client = served_shm
    client._call("frobnicate", _defer=True)  # unknown op, no sync reply
    with pytest.raises(BrokerError, match="deferred frobnicate"):
        client.qsize()
    client.put(new_task("k", {}))  # channel survived the oob error
    assert client.qsize() == 1


@pytest.mark.net
def test_shm_sync_acks_when_pipelining_disabled(tmp_path):
    backend = InMemoryBroker(visibility_timeout=2.0)
    srv = BrokerServer(backend, shm_path=str(tmp_path / "ring")).start()
    client = ShmBroker(str(tmp_path / "ring"), pipeline_acks=False)
    try:
        client.put_many([new_task("k", {"i": i}) for i in range(20)])
        while True:
            leases = client.get_many(4, timeout=0.2)
            if not leases:
                break
            client.ack_many([l.tag for l in leases])
        assert client.qsize() == 0 and client.inflight() == 0
    finally:
        client.close()
        srv.stop()


# ---------------------------------------------------------------------------
# consumer prefetch (the depth-K speculative get_many pipeline)
# ---------------------------------------------------------------------------

@pytest.mark.net
def test_prefetch_serves_hot_drain_from_stash(tmp_path, monkeypatch):
    backend = InMemoryBroker(visibility_timeout=5.0)
    srv = BrokerServer(backend, shm_path=str(tmp_path / "ring")).start()
    client = ShmBroker(str(tmp_path / "ring"), prefetch=2)
    sync_gets = []
    orig = ShmBroker._call

    def counting(self, op, *a, **kw):
        if op == "get_many" and not kw.get("_defer"):
            sync_gets.append(op)
        return orig(self, op, *a, **kw)

    monkeypatch.setattr(ShmBroker, "_call", counting)
    try:
        client.put_many([new_task("k", {"i": i}) for i in range(160)])
        got = 0
        while got < 160:
            leases = client.get_many(8, timeout=1.0)
            assert leases
            client.ack_many([l.tag for l in leases])
            got += len(leases)
        # after the first sync claim primes the pipeline, a hot drain is
        # fed from the stash: sync get_manys stay far below the 20 calls
        assert len(sync_gets) <= 5
        assert client.qsize() == 0
    finally:
        client.close()
        srv.stop()


@pytest.mark.net
def test_prefetch_selector_switch_returns_stash(served_shm):
    """Speculative leases for queue A must be nacked back (not silently
    consumed) when the caller switches to queue B mid-drain."""
    _backend, _srv, client = served_shm
    client.put_many([new_task("k", {}, queue="qa") for _ in range(8)])
    client.put_many([new_task("k", {}, queue="qb") for _ in range(8)])
    la = client.get_many(4, timeout=0.5, queues=["qa"])
    client.ack_many([l.tag for l in la])
    lb = client.get_many(8, timeout=1.0, queues=["qb"])  # switch selector
    client.ack_many([l.tag for l in lb])
    assert len(lb) == 8
    rest = client.get_many(8, timeout=1.0, queues=["qa"])  # nacked back
    client.ack_many([l.tag for l in rest])
    assert client.qsize() == 0
    deadline = time.monotonic() + 2.0
    while client.inflight() and time.monotonic() < deadline:
        time.sleep(0.05)
    assert client.inflight() == 0


@pytest.mark.net
def test_prefetch_settled_before_sync_ops(served_shm):
    """A sync op (qsize) issued while speculative get_manys are in
    flight must stay in FIFO step — and the speculatively-claimed
    leases remain claimable afterwards via the stash."""
    _backend, _srv, client = served_shm
    client.put_many([new_task("k", {"i": i}) for i in range(20)])
    leases = client.get_many(4, timeout=0.5)  # primes the pipeline
    client.ack_many([l.tag for l in leases])
    n = client.qsize()  # forces settle of in-flight speculative gets
    assert 0 <= n <= 16
    got = len(leases)
    while got < 20:
        more = client.get_many(4, timeout=1.0)
        assert more
        client.ack_many([l.tag for l in more])
        got += len(more)
    assert client.qsize() == 0


@pytest.mark.net
def test_prefetch_close_hands_stash_back(tmp_path):
    backend = InMemoryBroker(visibility_timeout=30.0)
    srv = BrokerServer(backend, shm_path=str(tmp_path / "ring")).start()
    client = ShmBroker(str(tmp_path / "ring"), prefetch=2)
    client.put_many([new_task("k", {"i": i}) for i in range(12)])
    leases = client.get_many(4, timeout=0.5)
    client.ack_many([l.tag for l in leases])
    client.close()  # stash + in-flight speculative leases nacked back
    deadline = time.monotonic() + 3.0
    while time.monotonic() < deadline:
        if backend.qsize() == 8 and backend.inflight() == 0:
            break
        time.sleep(0.05)
    # a 30 s visibility timeout cannot explain recovery: close() did it
    assert backend.qsize() == 8 and backend.inflight() == 0
    srv.stop()


@pytest.mark.net
def test_prefetch_disabled_is_purely_synchronous(tmp_path, monkeypatch):
    backend = InMemoryBroker(visibility_timeout=5.0)
    srv = BrokerServer(backend, shm_path=str(tmp_path / "ring")).start()
    client = ShmBroker(str(tmp_path / "ring"), prefetch=0)
    pushes = []
    orig = ShmBroker._push_req

    def recording(self, ch, frame):
        pushes.append(frame)
        return orig(self, ch, frame)

    monkeypatch.setattr(ShmBroker, "_push_req", recording)
    try:
        client.put_many([new_task("k", {}) for _ in range(8)])
        n_after_put = len(pushes)
        leases = client.get_many(8, timeout=0.5)
        client.ack_many([l.tag for l in leases])
        # exactly one get frame + one (deferred) ack frame: no
        # speculative extras
        assert len(pushes) == n_after_put + 2
        assert client.qsize() == 0
    finally:
        client.close()
        srv.stop()


# ---------------------------------------------------------------------------
# BundleRing + Bundler sink
# ---------------------------------------------------------------------------

def test_bundle_ring_roundtrip(tmp_path):
    reg = str(tmp_path / "bundles.json")
    with BundleRing(reg, capacity=1 << 16, create=True) as consumer:
        producer = BundleRing(reg)
        arr = np.arange(12, dtype=np.float64).reshape(3, 4)
        assert producer.push_bundle(0, 3, {"loss": arr})
        lo, hi, arrays = consumer.pop_bundle(timeout=1.0)
        assert (lo, hi) == (0, 3)
        np.testing.assert_array_equal(arrays["loss"], arr)
        producer.close()


def test_bundle_ring_drops_when_full_or_oversized(tmp_path):
    reg = str(tmp_path / "bundles.json")
    with BundleRing(reg, capacity=1 << 12, create=True) as ring:
        huge = np.zeros(1 << 14)  # frame > capacity: dropped, not raised
        assert not ring.push_bundle(0, 1, {"a": huge})
        small = np.zeros(64)
        while ring.push_bundle(0, 1, {"a": small}):
            pass  # fill it up -> further pushes drop
        assert not ring.push_bundle(0, 1, {"a": small})
        assert ring.drain()  # the accepted ones are all still readable


def test_bundler_feeds_sink_after_durable_write(tmp_path):
    reg = str(tmp_path / "bundles.json")
    with BundleRing(reg, capacity=1 << 16, create=True) as consumer:
        bundler = Bundler(str(tmp_path / "data"), sink=BundleRing(reg))
        path = bundler.write_bundle(
            0, 4, {"y": np.arange(4, dtype=np.float32)})
        assert os.path.exists(path)  # file written BEFORE the sink push
        lo, hi, arrays = consumer.pop_bundle(timeout=1.0)
        assert (lo, hi) == (0, 4)
        np.testing.assert_array_equal(arrays["y"],
                                      np.arange(4, dtype=np.float32))


def test_bundler_broken_sink_never_breaks_the_write(tmp_path):
    class Broken:
        def push_bundle(self, lo, hi, results):
            raise RuntimeError("sink down")

    bundler = Bundler(str(tmp_path / "data"))
    bundler.attach_sink(Broken())
    path = bundler.write_bundle(0, 2, {"y": np.zeros(2)})
    assert os.path.exists(path)  # durable path unaffected
