"""ExecutionEngine: cross-worker micro-batching, drain/shutdown flush
semantics, poison isolation inside fused batches, engine lifecycle, and
the multi-device shard_map dispatch equivalence (subprocess, forced
8-device host)."""
import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import ensemble as E
from repro.core.bundler import Bundler
from repro.core.engine import (ContinuousBatcher, DeadlineExpired,
                               EngineClosed, ExecutionEngine)
from repro.core.hierarchy import HierarchyCfg
from repro.core.queue import PRIORITY_REAL, BrokerFull, new_task
from repro.core.resilience import RetryPolicy
from repro.core.runtime import MerlinRuntime
from repro.core.spec import Step, StudySpec
from repro.core.worker import WorkerPool


def _seed_study(rt: MerlinRuntime, study: str, spans, n_samples: int,
                bundle: int, fn: str = "sim") -> None:
    """Register a study and enqueue its leaf tasks directly (the resubmit
    path): the node counter expects exactly len(spans) bundles."""
    spec = StudySpec(name=study, steps=[Step(name=fn, fn=fn)])
    samples = np.random.default_rng(0).random(
        (n_samples, 3)).astype(np.float32)
    rt.register_study(spec, study_id=study, samples=samples)
    rt.broker.put_many([
        new_task("real", {"study": study, "stage": 0, "combo": 0,
                          "n_samples": n_samples, "bundle": bundle,
                          "fanout": 16, "samples": [lo, hi],
                          "real_queue": "real", "gen_queue": "gen"},
                 priority=PRIORITY_REAL, queue="real")
        for lo, hi in spans])


# ---------------------------------------------------------------------------
# adaptive deadline (EMA of submission inter-arrival gaps)
# ---------------------------------------------------------------------------

class _StubRuntime:
    """Execution sink for engine-only unit tests."""

    def execute_real_many(self, tasks):
        pass

    def execute_real(self, task):
        pass


def test_adaptive_flush_cuts_lone_straggler_latency():
    """When arrivals are slower than the batching window, waiting out the
    full deadline cannot buy fusion — the engine flushes after the idle
    grace (max_wait / 4) instead."""
    eng = ExecutionEngine(_StubRuntime(), max_batch=64, max_wait_ms=400.0,
                          adaptive=True)
    try:
        # first submission: no EMA yet -> full deadline applies
        p0 = eng.submit(new_task("real", {"i": 0}))
        time.sleep(0.8)  # a slow feed: gap (0.8s) >> max_wait (0.4s)
        assert p0.done()  # flushed by its deadline long ago
        t0 = time.monotonic()
        p1 = eng.submit(new_task("real", {"i": 1}))
        assert p1.wait(5.0)
        waited = time.monotonic() - t0
        # idle grace is 100ms; the full window would be 400ms
        assert waited < 0.35, f"adaptive flush too slow: {waited:.3f}s"
        s = eng.stats()
        assert s["adaptive_flushes"] >= 1
        assert s["ema_gap_ms"] > 400.0
    finally:
        eng.close()


def test_adaptive_engine_leaves_bursts_alone():
    """Back-to-back submissions (gap << max_wait) must batch exactly as
    before: no adaptive flush fires, the size rule still wins."""
    eng = ExecutionEngine(_StubRuntime(), max_batch=8, max_wait_ms=300.0,
                          adaptive=True)
    try:
        pendings = eng.submit_many([new_task("real", {"i": i})
                                    for i in range(8)])
        assert all(p.wait(5.0) for p in pendings)
        s = eng.stats()
        assert s["size_flushes"] == 1
        assert s["adaptive_flushes"] == 0
        assert s["max_batch_seen"] == 8
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# cross-worker coalescing
# ---------------------------------------------------------------------------

def test_cross_worker_fusion_exceeds_per_worker_batch(tmp_path):
    """4 workers at batch 4 feeding one engine: at least one fused context
    must span MORE leaf tasks than any single worker's lease batch — the
    cross-get_many / cross-worker coalescing the per-worker path cannot
    do."""
    rt = MerlinRuntime(workspace=str(tmp_path))
    calls = []
    rt.register("sim", lambda ctx: calls.append(list(map(tuple,
                                                         ctx.sub_ranges))))
    spans = [(i * 2, (i + 1) * 2) for i in range(16)]
    _seed_study(rt, "xw", spans, n_samples=32, bundle=2)
    # max_wait well above scheduler jitter so the fused batch forms from
    # a size or drain flush, not a deadline flush racing worker leases
    with WorkerPool(rt, n_workers=4, batch=4,
                    engine_cfg={"max_batch": 16, "max_wait_ms": 2000}) as p:
        assert p.drain(timeout=60)
        eng_stats = p.stats()["engine"]
    covered = sorted(r for call in calls for r in call)
    assert covered == spans  # every leaf executed exactly once
    assert max(len(c) for c in calls) > 4  # fused beyond one lease batch
    assert eng_stats["max_batch_seen"] > 4
    assert eng_stats["batches"] >= 1
    # histogram and flush-reason accounting are coherent
    assert sum(eng_stats["batch_hist"].values()) == eng_stats["batches"]
    assert (eng_stats["size_flushes"] + eng_stats["deadline_flushes"]
            + eng_stats["forced_flushes"]) == eng_stats["batches"]
    assert eng_stats["executed"] == 16


def test_engine_coalesces_across_queues(tmp_path):
    """Tasks leased from different QUEUES but the same study/stage/combo
    land in one buffer and fuse (compatibility is execution identity, not
    queue identity)."""
    rt = MerlinRuntime(workspace=str(tmp_path))
    calls = []
    rt.register("sim", lambda ctx: calls.append(len(ctx.sub_ranges)))
    spec = StudySpec(name="q2", steps=[Step(name="sim", fn="sim")])
    rt.register_study(spec, study_id="q2",
                      samples=np.zeros((8, 2), np.float32))
    tasks = []
    for i in range(4):  # alternate contiguous spans across two queues
        tasks.append(new_task(
            "real", {"study": "q2", "stage": 0, "combo": 0, "n_samples": 8,
                     "bundle": 2, "fanout": 16, "samples": [i * 2, i * 2 + 2],
                     "real_queue": "real", "gen_queue": "gen"},
            priority=PRIORITY_REAL, queue="sims-a" if i % 2 else "sims-b"))
    rt.broker.put_many(tasks)
    # max_wait well above scheduler jitter: the flush under test is the
    # one drain() forces after every task is leased, not a deadline flush
    # racing the second worker's lease (deadline flushes have their own
    # tests below)
    with WorkerPool(rt, n_workers=2, batch=2, queues=("sims-a", "sims-b"),
                    engine_cfg={"max_batch": 8, "max_wait_ms": 2000}) as p:
        assert p.drain(timeout=60)
    assert sum(calls) == 4
    assert max(calls) > 2  # spans from both queues fused into one launch


# ---------------------------------------------------------------------------
# drain / shutdown flush semantics
# ---------------------------------------------------------------------------

def test_drain_flushes_partial_microbatch(tmp_path):
    """A partially-filled buffer under a HUGE max_wait must not strand
    leased tasks: drain() forces the flush."""
    rt = MerlinRuntime(workspace=str(tmp_path))
    done = []
    rt.register("sim", lambda ctx: done.append((ctx.lo, ctx.hi)))
    _seed_study(rt, "dr", [(0, 2), (2, 4), (4, 6)], 6, 2)
    t0 = time.monotonic()
    with WorkerPool(rt, n_workers=1, batch=4,
                    engine_cfg={"max_batch": 64,
                                "max_wait_ms": 60_000}) as p:
        assert p.drain(timeout=30)
        elapsed = time.monotonic() - t0
    assert sorted(r for c in done for r in [c]) and len(done) >= 1
    assert sum(hi - lo for lo, hi in done) == 6
    assert elapsed < 20  # nowhere near the 60s batching deadline
    assert rt.broker.idle()  # all acked, nothing left to expire


def test_shutdown_flushes_partial_microbatch(tmp_path):
    """shutdown() without a prior drain must also execute + ack the
    buffered partial batch (not abandon the leases)."""
    rt = MerlinRuntime(workspace=str(tmp_path))
    done = []
    rt.register("sim", lambda ctx: done.extend(map(tuple, ctx.sub_ranges)))
    _seed_study(rt, "sd", [(0, 2), (2, 4)], 4, 2)
    pool = WorkerPool(rt, n_workers=1, batch=2,
                      engine_cfg={"max_batch": 64, "max_wait_ms": 60_000})
    # wait until both tasks are leased and submitted (buffer holds them)
    deadline = time.monotonic() + 10
    while time.monotonic() < deadline and rt.broker.qsize() > 0:
        time.sleep(0.01)
    pool.shutdown()
    assert sorted(done) == [(0, 2), (2, 4)]
    assert rt.broker.idle()  # acked on the way out, not left to expire


# ---------------------------------------------------------------------------
# poison isolation in fused cross-worker batches
# ---------------------------------------------------------------------------

def test_poison_in_fused_batch_dead_letters_alone(tmp_path):
    """One poison task inside a cross-worker fused batch must dead-letter
    by itself (retries exhausted -> acked away) while every sibling
    executes and acks."""
    rt = MerlinRuntime(workspace=str(tmp_path))
    done = []

    def step(ctx):
        if any(tuple(r) == (4, 6) for r in ctx.sub_ranges):
            raise RuntimeError("poison")
        done.extend(map(tuple, ctx.sub_ranges))

    rt.register("sim", step)
    spans = [(i * 2, (i + 1) * 2) for i in range(8)]
    _seed_study(rt, "px", spans, 16, 2)
    with WorkerPool(rt, n_workers=2, batch=4,
                    retry_policy=RetryPolicy(max_retries=2),
                    engine_cfg={"max_batch": 8, "max_wait_ms": 50}) as p:
        assert p.drain(timeout=60)  # reaches idle => poison dead-lettered
        stats = p.stats()
    assert sorted(set(done)) == [s for s in spans if s != (4, 6)]
    assert (4, 6) not in done
    assert stats["failed"] >= 1  # the poison task's failures were recorded
    assert rt.broker.idle()
    # siblings completed exactly once each (once-markers all present)
    for lo, hi in spans:
        marked = rt.counters.once_exists(f"px/exec/s0/c0/{lo}_{hi}")
        assert marked == ((lo, hi) != (4, 6))


def test_cmd_and_funnel_tasks_bypass_engine(tmp_path):
    """Only parallel fn-step stages are engine-fusable; cmd-step and
    funnel tasks run in the worker's own thread (N workers = N concurrent
    subprocesses), so a slow cmd step cannot head-of-line-block the
    dispatcher."""
    from repro.core.spec import Step, StudySpec
    rt = MerlinRuntime(workspace=str(tmp_path))
    spec = StudySpec(name="mix", steps=[
        Step(name="sim", cmd="true"),
        Step(name="post", fn="post", depends=("sim_*",),
             over_samples=False)])
    rt.register_study(spec, study_id="mix")
    cmd_task = new_task("real", {"study": "mix", "stage": 0, "combo": 0,
                                 "n_samples": 4, "bundle": 2, "fanout": 4,
                                 "samples": [0, 2]})
    funnel_task = new_task("real", {"study": "mix", "stage": 1, "combo": 0,
                                    "n_samples": 4, "bundle": 2,
                                    "fanout": 4, "samples": [0, 1]})
    unknown = new_task("real", {"study": "nope", "stage": 0, "combo": 0,
                                "samples": [0, 1]})
    assert not rt.coalescable(cmd_task)
    assert not rt.coalescable(funnel_task)
    assert not rt.coalescable(unknown)
    rt2 = MerlinRuntime(workspace=str(tmp_path / "w2"))
    rt2.register("sim", lambda ctx: None)
    spec2 = StudySpec(name="fn", steps=[Step(name="sim", fn="sim")])
    rt2.register_study(spec2, study_id="fn")
    fn_task = new_task("real", {"study": "fn", "stage": 0, "combo": 0,
                                "n_samples": 4, "bundle": 2, "fanout": 4,
                                "samples": [0, 2]})
    assert rt2.coalescable(fn_task)


def test_base_exception_in_step_never_acks_unexecuted_siblings(tmp_path):
    """A step raising a BaseException (SystemExit) must not let the
    dispatcher resolve batch-mates as successes they never earned: every
    task either executed (resolved None) or comes back as a failure for
    redelivery — at-least-once survives."""
    rt = MerlinRuntime(workspace=str(tmp_path))
    done = []

    def step(ctx):
        if any(tuple(r) == (2, 4) for r in ctx.sub_ranges):
            raise SystemExit(1)  # not an Exception subclass
        done.extend(map(tuple, ctx.sub_ranges))

    rt.register("sim", step)
    spans = [(0, 2), (2, 4), (4, 6), (6, 8)]
    _seed_study(rt, "be", spans, 8, 2)
    leases = rt.broker.get_many(4, timeout=1)
    eng = ExecutionEngine(rt, max_batch=4, max_wait_ms=5)
    pendings = eng.submit_many([l.task for l in leases])
    for p in pendings:
        assert p.wait(30)
    by_span = {tuple(p.task.payload["samples"]): p for p in pendings}
    assert isinstance(by_span[(2, 4)].error, SystemExit)
    for span in ((0, 2), (4, 6), (6, 8)):
        assert by_span[span].error is None  # executed via fallback
        assert span in done
    assert (2, 4) not in done
    eng.close()
    for lease in leases:
        rt.broker.ack(lease.tag)


# ---------------------------------------------------------------------------
# lifecycle: shared engine, refcounts, closed-engine behavior
# ---------------------------------------------------------------------------

def test_shared_engine_refcount_across_pools(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path))
    rt.register("sim", lambda ctx: None)
    p1 = WorkerPool(rt, n_workers=1)
    p2 = WorkerPool(rt, n_workers=1)
    assert p1.engine is p2.engine  # one scheduler per runtime
    p1.shutdown()
    assert not p1.engine.closed  # p2 still attached
    p2.shutdown()
    assert p2.engine.closed  # last pool out closes the dispatcher
    p3 = WorkerPool(rt, n_workers=1)  # a fresh engine is created
    assert p3.engine is not p1.engine and not p3.engine.closed
    p3.shutdown()


def test_submit_to_closed_engine_raises(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path))
    eng = ExecutionEngine(rt)
    eng.close()
    with pytest.raises(EngineClosed):
        eng.submit(new_task("real", {}))


def test_close_resolves_buffered_handles(tmp_path):
    """close() executes the buffered batch (forced flush), so handles
    resolve instead of hanging their waiters."""
    rt = MerlinRuntime(workspace=str(tmp_path))
    done = []
    rt.register("sim", lambda ctx: done.append((ctx.lo, ctx.hi)))
    _seed_study(rt, "cl", [(0, 3)], 3, 3)
    lease = rt.broker.get(timeout=1)
    eng = ExecutionEngine(rt, max_batch=64, max_wait_ms=60_000)
    pending = eng.submit(lease.task)
    eng.close()
    assert pending.done()
    assert pending.error is None and done == [(0, 3)]
    rt.broker.ack(lease.tag)


# ---------------------------------------------------------------------------
# multi-device shard_map dispatch (forced 8-device subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_mesh_dispatch_matches_single_device_bit_for_bit():
    """The acceptance equivalence: shard_map dispatch over 8 forced host
    devices is bit-for-bit identical to single-device execution for an
    IEEE-exact simulator (and within last-ULP transcendental codegen
    variance for the JAG stand-in), with compiles inside the bucketed
    bound.  Runs in a subprocess because the in-process suite pins the
    1-device default (tests/conftest.py)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root]
        + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])
    cfg = {"sizes": [32, 32, 16, 5]}
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.ensemble_throughput",
         "--mesh-worker", json.dumps(cfg)],
        capture_output=True, text=True, env=env, cwd=root, timeout=590)
    assert proc.returncode == 0, proc.stderr[-1000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert out["bit_equal"] is True
    assert out["jag_max_rel_diff"] <= 1e-3
    # compile count: one trace per bucket per path, within the bound
    for tag in ("exact_sharded", "jag_sharded", "exact_single",
                "jag_single"):
        assert out[tag]["traces"] <= out["bucket_bound"]
    # the sharded streams actually used the mesh (32- and 16-buckets
    # divide 8 devices; the 5->8 bucket does too)
    assert out["exact_sharded"]["mesh_launches"] >= 3
    assert out["exact_single"].get("mesh_launches", 0) == 0


# ---------------------------------------------------------------------------
# executor mesh plumbing that does not need a subprocess
# ---------------------------------------------------------------------------

def test_single_device_auto_mesh_is_none():
    """On the suite's 1-device host, mesh='auto' degrades to exactly the
    old single-device behavior."""
    ex = E.EnsembleExecutor(lambda u, rng: {"v": u}, mesh="auto")
    assert ex.mesh is None
    assert ex.stats["devices"] == 1
    out = ex.run_bundle(0, 3, np.zeros((3, 2), np.float32))
    assert out["v"].shape == (3, 2)
    assert ex.stats["mesh_launches"] == 0


# ---------------------------------------------------------------------------
# affinity-keyed batching (per-study engine affinity)
# ---------------------------------------------------------------------------

class _AffinityStub:
    """Records which affinity keys each fused launch mixed."""

    def __init__(self):
        self.batches = []

    def affinity_key(self, task):
        return task.payload["study"]

    def execute_real_many(self, tasks):
        self.batches.append([t.payload["study"] for t in tasks])

    def execute_real(self, task):
        self.batches.append([task.payload["study"]])


def test_affinity_key_keeps_interleaved_studies_apart():
    """Two studies submitting interleaved through one shared engine: no
    fused launch may mix studies (each study's ensemble executor has its
    own jit cache and bundle archive), and the short dispatch is counted
    as an affinity split."""
    rt = _AffinityStub()
    eng = ExecutionEngine(rt, max_batch=8, max_wait_ms=60.0,
                          adaptive=False)
    try:
        tasks = [new_task("real", {"study": "a" if i % 2 == 0 else "b",
                                   "i": i}) for i in range(8)]
        pendings = eng.submit_many(tasks)
        assert all(p.wait(10.0) for p in pendings)
        s = eng.stats()
    finally:
        eng.close()
    assert len(rt.batches) >= 2  # one fused launch would have mixed keys
    for batch in rt.batches:
        assert len(set(batch)) == 1, f"launch mixed studies: {batch}"
    assert sorted(k for b in rt.batches for k in b) == ["a"] * 4 + ["b"] * 4
    # the front group dispatched short (4 < max_batch) with "b" waiting
    assert s["affinity_splits"] >= 1


# ---------------------------------------------------------------------------
# deferred host writes (single writer thread overlapping dispatch)
# ---------------------------------------------------------------------------

class _DeferStub:
    """Runtime exposing the deferred-write pipeline with visible phases."""

    def __init__(self, compute_s=0.0, write_s=0.0):
        self.compute_s = compute_s
        self.write_s = write_s
        self.events = []

    def execute_real_many_deferred(self, tasks):
        time.sleep(self.compute_s)
        self.events.append(("compute", len(tasks)))

        def finalize():
            time.sleep(self.write_s)
            self.events.append(("finalize", len(tasks)))
        return finalize

    def execute_real_many(self, tasks):
        pass

    def execute_real(self, task):
        pass


def test_deferred_pipeline_resolves_only_after_finalize():
    """Ack-after-durable: a handle may not resolve until the writer ran
    the batch's finalize (host sync + bundle write + once-markers)."""
    rt = _DeferStub(write_s=0.05)
    eng = ExecutionEngine(rt, max_batch=4, max_wait_ms=20.0)
    try:
        pendings = eng.submit_many([new_task("real", {"i": i})
                                    for i in range(4)])
        assert all(p.wait(10.0) for p in pendings)
        # resolution implies the writer already finalized this batch
        assert ("finalize", 4) in rt.events
        s = eng.stats()
        assert s["deferred_batches"] == 1
        assert s["write_s"] > 0.0
        assert "write_overlap_s" in s
    finally:
        eng.close()


def test_deferred_writes_overlap_next_dispatch():
    """Back-to-back batches: batch N's finalize runs on the writer thread
    while the dispatcher is already computing batch N+1, and the overlap
    shows up in stats["write_overlap_s"]."""
    rt = _DeferStub(compute_s=0.05, write_s=0.05)
    eng = ExecutionEngine(rt, max_batch=1, max_wait_ms=5.0)
    try:
        pendings = eng.submit_many([new_task("real", {"i": i})
                                    for i in range(3)])
        assert all(p.wait(20.0) for p in pendings)
        s = eng.stats()
        assert s["deferred_batches"] == 3
        assert s["write_overlap_s"] > 0.0, \
            "finalize never overlapped a dispatch"
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# serving: ContinuousBatcher (admission, deadlines, shed, drain)
# ---------------------------------------------------------------------------

class _Gate:
    """infer_fn whose FIRST call blocks on an event — lets a test park
    the batcher loop mid-launch while follow-up requests queue up."""

    def __init__(self):
        self.event = threading.Event()
        self.calls = []  # first-column values of each launch, in order

    def __call__(self, X):
        first = not self.calls
        self.calls.append(np.array(X[:, 0]))
        if first:
            assert self.event.wait(10.0)
        return X * 2.0

    def wait_entered(self):
        for _ in range(1000):
            if self.calls:
                return
            time.sleep(0.005)
        raise AssertionError("batcher loop never entered infer_fn")


def test_batcher_fuses_requests_queued_behind_a_launch():
    """Requests arriving while a batch executes are admitted together
    into the next launch, and each caller gets exactly its own slice."""
    gate = _Gate()
    b = ContinuousBatcher(gate, max_batch_rows=64, max_inflight=32)
    try:
        hold = b.submit(np.zeros((2, 3), np.float32))
        gate.wait_entered()
        reqs = [b.submit(np.full((2, 3), float(i), np.float32))
                for i in range(4)]
        gate.event.set()
        assert hold.wait(10.0) and all(r.wait(10.0) for r in reqs)
        assert np.allclose(hold.result, 0.0)
        for i, r in enumerate(reqs):  # per-request slices, not batch-mates'
            assert r.result.shape == (2, 3)
            assert np.allclose(r.result, 2.0 * i)
        s = b.stats()
        assert s["batches"] == 2  # 1 held launch + 1 fused launch of 4
        assert s["batch_requests_hist"].get(4) == 1
        assert s["completed"] == 5 and s["failed"] == 0
    finally:
        b.close()


def test_batcher_naive_mode_is_flush_per_request():
    """The A/B baseline: naive mode launches exactly one request per
    batch even when the queue is deep."""
    calls = []

    def infer(X):
        calls.append(len(X))
        time.sleep(0.02)  # slow enough that peers pile up behind it
        return X

    b = ContinuousBatcher(infer, naive=True, max_inflight=32)
    try:
        reqs = [b.submit(np.ones((2, 2), np.float32)) for _ in range(5)]
        assert all(r.wait(10.0) for r in reqs)
        s = b.stats()
        assert s["batches"] == 5
        assert set(s["batch_requests_hist"]) == {1}
    finally:
        b.close()


def test_batcher_admission_is_deadline_ordered():
    """Under backlog the deadline-carrying request is admitted ahead of
    an earlier-submitted request with no deadline."""
    gate = _Gate()
    b = ContinuousBatcher(gate, max_inflight=16)
    try:
        hold = b.submit(np.zeros((1, 2), np.float32))
        gate.wait_entered()
        slack = b.submit(np.full((1, 2), 1.0, np.float32))  # no deadline
        urgent = b.submit(np.full((1, 2), 2.0, np.float32),
                          deadline_s=30.0)
        gate.event.set()
        assert all(r.wait(10.0) for r in (hold, slack, urgent))
    finally:
        b.close()
    fused = np.concatenate(gate.calls[1:])
    assert fused[0] == 2.0, f"deadline request not first: {fused}"


def test_batcher_bucket_boundary_topup():
    """Admission grows the batch to max_batch_rows, then keeps topping up
    only while rows still fit the power-of-two bucket the batch already
    pays padding for: queued 5+3+2 rows at max_batch_rows=8 must launch
    as [8, 2], never [10]."""
    gate = _Gate()
    b = ContinuousBatcher(gate, max_batch_rows=8, max_inflight=16)
    try:
        hold = b.submit(np.zeros((1, 2), np.float32))
        gate.wait_entered()
        reqs = [b.submit(np.ones((n, 2), np.float32)) for n in (5, 3, 2)]
        gate.event.set()
        assert hold.wait(10.0) and all(r.wait(10.0) for r in reqs)
    finally:
        b.close()
    assert [len(c) for c in gate.calls] == [1, 8, 2]


def test_batcher_deadline_expires_without_executing():
    """A request whose deadline passes while queued resolves with
    DeadlineExpired and its rows never reach infer_fn (504 semantics)."""
    gate = _Gate()
    b = ContinuousBatcher(gate, max_inflight=16)
    try:
        hold = b.submit(np.zeros((1, 2), np.float32))
        gate.wait_entered()
        doomed = b.submit(np.full((1, 2), 7.0, np.float32),
                          deadline_s=0.05)
        time.sleep(0.15)  # deadline passes while the loop is parked
        gate.event.set()
        assert hold.wait(10.0) and doomed.wait(10.0)
        assert isinstance(doomed.error, DeadlineExpired)
        assert doomed.result is None
        s = b.stats()
        assert s["expired"] == 1
        # accounting identity: every admitted request is accounted for
        assert s["completed"] + s["failed"] + s["expired"] == s["submitted"]
    finally:
        b.close()
    assert all(7.0 not in c for c in gate.calls), "expired request executed"


def test_batcher_sheds_with_brokerfull_before_admission():
    """At max_inflight queued requests, submit raises BrokerFull (429
    semantics) without admitting — and the queued requests still finish."""
    gate = _Gate()
    b = ContinuousBatcher(gate, max_inflight=2)
    try:
        hold = b.submit(np.zeros((1, 2), np.float32))
        gate.wait_entered()  # hold left the heap; queue is empty again
        queued = [b.submit(np.ones((1, 2), np.float32)) for _ in range(2)]
        with pytest.raises(BrokerFull):
            b.submit(np.ones((1, 2), np.float32))
        assert b.stats()["shed"] == 1
        gate.event.set()
        assert hold.wait(10.0) and all(r.wait(10.0) for r in queued)
        assert all(r.error is None for r in queued)  # shed cost no one else
    finally:
        b.close()


def test_batcher_drain_completes_admitted_then_refuses():
    """drain(): already-admitted requests run to completion while new
    submissions are refused with EngineClosed (the gateway's 503)."""
    gate = _Gate()
    b = ContinuousBatcher(gate, max_inflight=16)
    hold = b.submit(np.zeros((1, 2), np.float32))
    gate.wait_entered()
    queued = [b.submit(np.ones((1, 2), np.float32)) for _ in range(3)]
    drained = []
    t = threading.Thread(target=lambda: drained.append(b.drain(10.0)))
    t.start()
    for _ in range(1000):  # wait for drain() to flip the admission gate
        try:
            b.submit(np.ones((1, 2), np.float32))
        except EngineClosed:
            break
        time.sleep(0.005)
    else:
        raise AssertionError("drain never started refusing admissions")
    gate.event.set()
    t.join(timeout=15.0)
    assert drained == [True]
    assert hold.wait(1.0) and all(r.wait(1.0) for r in queued)
    assert all(r.error is None for r in queued)
    b.close()


def test_batcher_close_resolves_backlog_with_engineclosed():
    """close() without drain must never strand a waiter: anything still
    queued resolves with EngineClosed."""
    gate = _Gate()
    b = ContinuousBatcher(gate, max_inflight=16)
    hold = b.submit(np.zeros((1, 2), np.float32))
    gate.wait_entered()
    queued = b.submit(np.ones((1, 2), np.float32))
    gate.event.set()
    b.close()
    assert hold.wait(10.0) and queued.wait(10.0)
    # the held request was mid-execution (completes); anything the loop
    # did not reach before close resolves, with a typed error if dropped
    assert queued.done()
    assert queued.error is None or isinstance(queued.error, EngineClosed)
