"""Wire codec tests: bin1 roundtrips, defensive decoding, per-connection
negotiation (including mixed fleets and legacy peers), and seeded frame
fuzzing.  Socket tests carry the ``net`` marker; the fuzz tests carry
``chaos`` like the rest of the fault-injection suite."""
import json
import socket
import struct
import threading

import numpy as np
import pytest

from repro.core.netbroker import (BrokerServer, NetBroker, _recv_frame,
                                  _recv_raw, _send_frame)
from repro.core.queue import InMemoryBroker, new_task
from repro.core.wirecodec import (BIN_CODEC, CODECS, CodecError,
                                  DEFAULT_PREFERENCE, JSON_CODEC, get_codec,
                                  negotiate_codec)


# ---------------------------------------------------------------------------
# bin1 roundtrips
# ---------------------------------------------------------------------------

ROUNDTRIP_VALUES = [
    None,
    True,
    False,
    0,
    -1,
    1,
    2 ** 80,            # unbounded ints (JSON parity)
    -(2 ** 80),
    1.5,
    -0.0,
    float("inf"),
    float("-inf"),
    "",
    "plain",
    "unicode ☃ \U0001f600",
    b"",
    b"\x00\xff raw bytes",
    [],
    {},
    [1, "two", None, [3.0, 4.0]],
    {"nested": {"deep": [{"k": "v"}]}, "n": 7},
]


@pytest.mark.parametrize("value", ROUNDTRIP_VALUES,
                         ids=[repr(v)[:40] for v in ROUNDTRIP_VALUES])
def test_bin1_roundtrip(value):
    assert BIN_CODEC.decode(BIN_CODEC.encode(value)) == value


def test_bin1_roundtrip_nan():
    out = BIN_CODEC.decode(BIN_CODEC.encode(float("nan")))
    assert out != out  # NaN survives (JSON cannot even carry it)


def test_bin1_float_list_fast_path():
    # a homogeneous float list travels as ONE raw buffer; mixed lists
    # take the generic path — both must round-trip identically
    floats = [0.0, -1.25, 3.5e300, float("inf")]
    enc = BIN_CODEC.encode(floats)
    assert enc[0] == 0x09  # _T_F64ARR
    assert BIN_CODEC.decode(enc) == floats
    mixed = [1.0, 2, 3.0]
    assert BIN_CODEC.decode(BIN_CODEC.encode(mixed)) == mixed


@pytest.mark.parametrize("dtype", ["float64", "float32", "int32"])
def test_bin1_ndarray_roundtrip(dtype):
    arr = np.arange(24, dtype=dtype).reshape(2, 3, 4)
    out = BIN_CODEC.decode(BIN_CODEC.encode({"x": arr}))["x"]
    assert out.dtype == arr.dtype
    assert out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)


def test_bin1_ndarray_noncontiguous_and_scalars():
    arr = np.arange(16, dtype=np.float64).reshape(4, 4)[:, ::2]  # strided
    out = BIN_CODEC.decode(BIN_CODEC.encode(arr))
    np.testing.assert_array_equal(out, arr)
    obj = {"i": np.int64(7), "f": np.float32(1.5), "b": np.bool_(True)}
    dec = BIN_CODEC.decode(BIN_CODEC.encode(obj))
    assert dec == {"i": 7, "f": 1.5, "b": True}


def test_bin1_rejects_unencodable():
    with pytest.raises(CodecError):
        BIN_CODEC.encode({"bad": object()})


def test_bin1_depth_limit():
    deep = None
    for _ in range(80):
        deep = [deep]
    with pytest.raises(CodecError, match="nesting"):
        BIN_CODEC.encode(deep)


# ---------------------------------------------------------------------------
# JSON floor: arrays must survive a fallback connection
# ---------------------------------------------------------------------------

def test_json_codec_degrades_arrays_to_lists():
    obj = {"x": np.arange(3, dtype=np.float64), "n": np.int32(5)}
    out = JSON_CODEC.decode(JSON_CODEC.encode(obj))
    assert out == {"x": [0.0, 1.0, 2.0], "n": 5}


def test_json_codec_rejects_unknown_types():
    with pytest.raises(TypeError):
        JSON_CODEC.encode({"bad": object()})
    with pytest.raises(CodecError):
        JSON_CODEC.decode(b"\xff not json")


# ---------------------------------------------------------------------------
# defensive decode: corrupt bytes -> CodecError, never a hang or crash
# ---------------------------------------------------------------------------

def test_bin1_truncation_at_every_offset():
    frame = BIN_CODEC.encode({"k": [1.0, 2.0, 3.0], "s": "abc",
                              "a": np.arange(4, dtype=np.float64)})
    for cut in range(len(frame)):
        with pytest.raises(CodecError):
            BIN_CODEC.decode(frame[:cut])


def test_bin1_unknown_tag_and_trailing_garbage():
    with pytest.raises(CodecError, match="unknown bin1 tag"):
        BIN_CODEC.decode(b"\x99")
    with pytest.raises(CodecError, match="trailing"):
        BIN_CODEC.decode(BIN_CODEC.encode(1) + b"\x00")
    with pytest.raises(CodecError):
        BIN_CODEC.decode(b"")


def test_bin1_hostile_lengths_do_not_allocate():
    # a tag claiming a huge count must fail the bounds check, not try to
    # build a billion-entry list / string
    huge = bytearray([0x05])  # _T_STR
    huge += b"\xff\xff\xff\xff\x7f"  # varint ~3.4e10
    with pytest.raises(CodecError):
        BIN_CODEC.decode(bytes(huge))
    with pytest.raises(CodecError):
        BIN_CODEC.decode(bytes([0x07]) + b"\xff\xff\xff\xff\x7f")  # list
    with pytest.raises(CodecError):
        BIN_CODEC.decode(bytes([0x09]) + b"\xff\xff\xff\xff\x7f")  # f64arr
    # ndarray with an absurd rank or dtype
    with pytest.raises(CodecError):
        BIN_CODEC.decode(bytes([0x0A, 0x02]) + b"zz")
    deep = b"\x07\x01" * 80 + b"\x00"  # 80 nested single-item lists
    with pytest.raises(CodecError, match="nesting"):
        BIN_CODEC.decode(deep)


# ---------------------------------------------------------------------------
# negotiation
# ---------------------------------------------------------------------------

def test_negotiate_codec_matrix():
    assert negotiate_codec(DEFAULT_PREFERENCE, DEFAULT_PREFERENCE) == "bin1"
    assert negotiate_codec(("json",), ("bin1", "json")) == "json"
    assert negotiate_codec(DEFAULT_PREFERENCE, ("json",)) == "json"
    assert negotiate_codec(DEFAULT_PREFERENCE, ()) == "json"
    # unknown names on either side fall through to the floor
    assert negotiate_codec(("zstd9", "json"), ("zstd9",)) == "json"
    assert negotiate_codec((), ("bin1",)) == "json"


def test_get_codec_unknown_raises():
    assert get_codec("bin1") is BIN_CODEC
    assert get_codec("json") is JSON_CODEC
    with pytest.raises(ValueError, match="unknown wire codec"):
        get_codec("gzip")
    with pytest.raises(ValueError):
        NetBroker("tcp://127.0.0.1:1", codec="gzip")
    with pytest.raises(ValueError):
        BrokerServer(InMemoryBroker(), codecs=("gzip",))


# ---------------------------------------------------------------------------
# live negotiation over sockets
# ---------------------------------------------------------------------------

def _roundtrip_task(client):
    arr = np.arange(8, dtype=np.float64)
    client.put(new_task("sim", {"x": arr}))
    lease = client.get(timeout=2.0)
    assert lease is not None
    client.ack(lease.tag)
    got = lease.task.payload["x"]
    # bin1 preserves the ndarray; the JSON floor degrades it to a list
    np.testing.assert_array_equal(np.asarray(got, dtype=np.float64), arr)


@pytest.mark.net
@pytest.mark.parametrize("server_codecs,client_codec,expect", [
    (DEFAULT_PREFERENCE, "auto", "bin1"),
    (DEFAULT_PREFERENCE, "bin1", "bin1"),
    (DEFAULT_PREFERENCE, "json", "json"),
    (("json",), "auto", "json"),       # binary-unaware server
    (("json",), "bin1", "json"),       # bin1 insisted, floor still wins
])
def test_negotiation_over_socket(server_codecs, client_codec, expect):
    server = BrokerServer(InMemoryBroker(visibility_timeout=0.5),
                          codecs=server_codecs).start()
    try:
        client = NetBroker(server.address, reconnect_timeout=2.0,
                           codec=client_codec)
        try:
            _roundtrip_task(client)
            assert client._negotiated == expect
        finally:
            client.close()
    finally:
        server.stop()


@pytest.mark.net
def test_mixed_fleet_one_server_counts_codecs():
    server = BrokerServer(InMemoryBroker(visibility_timeout=0.5)).start()
    try:
        binc = NetBroker(server.address, codec="auto")
        legacy = NetBroker(server.address, codec="json")
        try:
            binc.put(new_task("sim", {"i": 1}))
            legacy.put(new_task("sim", {"i": 2}))
            tags = []
            for _ in range(2):
                lease = binc.get(timeout=2.0)
                assert lease is not None
                tags.append(lease.tag)
            binc.ack_many(tags)
            assert server.stats["codecs"]["bin1"] >= 1
            assert server.stats["codecs"]["json"] >= 1
        finally:
            binc.close()
            legacy.close()
    finally:
        server.stop()


@pytest.mark.net
def test_raw_legacy_client_still_speaks_json():
    # a pre-codec client never sends hello: bare length-prefixed JSON
    # frames must keep working against an upgraded server
    server = BrokerServer(InMemoryBroker(visibility_timeout=0.5)).start()
    try:
        with socket.create_connection(("127.0.0.1", server.port),
                                      timeout=2.0) as s:
            _send_frame(s, {"op": "put", "task": {
                "id": "t-legacy", "kind": "sim", "payload": {"i": 1},
                "priority": 0, "queue": "default", "retries": 0,
                "enqueued_at": 0.0}})
            assert _recv_frame(s)["ok"]
            _send_frame(s, {"op": "qsize"})
            resp = _recv_frame(s)
            assert resp["ok"] and resp["n"] == 1
    finally:
        server.stop()


@pytest.mark.net
def test_client_falls_back_when_server_rejects_hello():
    # emulate a pre-codec server: answers hello with an unknown-op error;
    # the client must settle on JSON and keep working
    lsock = socket.socket()
    lsock.bind(("127.0.0.1", 0))
    lsock.listen(1)
    port = lsock.getsockname()[1]

    def fake_server():
        conn, _ = lsock.accept()
        with conn:
            req = _recv_frame(conn)
            assert req["op"] == "hello"
            _send_frame(conn, {"ok": False, "error": "unknown op hello",
                               "error_type": "BrokerError"})
            req = _recv_frame(conn)  # must arrive as plain JSON
            assert req["op"] == "qsize"
            _send_frame(conn, {"ok": True, "n": 0})

    t = threading.Thread(target=fake_server, daemon=True)
    t.start()
    try:
        client = NetBroker(f"tcp://127.0.0.1:{port}", reconnect_timeout=1.0)
        try:
            assert client.qsize() == 0
            assert client._negotiated == "json"
        finally:
            client.close()
        t.join(timeout=5.0)
        assert not t.is_alive()
    finally:
        lsock.close()


@pytest.mark.net
def test_corrupt_bin1_frame_is_quarantined_not_fatal():
    # after negotiating bin1, send bytes that fail to decode: the server
    # must answer with a typed CodecError and keep the connection alive
    server = BrokerServer(InMemoryBroker(visibility_timeout=0.5)).start()
    try:
        with socket.create_connection(("127.0.0.1", server.port),
                                      timeout=2.0) as s:
            _send_frame(s, {"op": "hello", "codecs": ["bin1", "json"]})
            assert _recv_frame(s)["codec"] == "bin1"
            garbage = b"\x99\x01\x02"
            s.sendall(struct.pack(">I", len(garbage)) + garbage)
            resp = BIN_CODEC.decode(_recv_raw(s))
            assert not resp["ok"]
            assert resp["error_type"] == "CodecError"
            # connection survives: a well-formed frame still works
            _send_frame(s, {"op": "qsize"}, codec=BIN_CODEC)
            resp = BIN_CODEC.decode(_recv_raw(s))
            assert resp["ok"] and resp["n"] == 0
        assert server.stats["codec_errors"] >= 1
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# seeded fuzz (chaos tier): corrupt frames decode to CodecError or a
# value — never a hang, MemoryError, or interpreter-level blowup
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_fuzz_bitflips_and_truncations():
    rng = np.random.default_rng(0xC0DEC)
    seeds = [BIN_CODEC.encode(v) for v in (
        {"op": "put_many", "tasks": [{"id": "t", "payload":
                                      {"x": [1.0] * 32}}] * 4},
        {"arr": np.arange(64, dtype=np.float64).reshape(8, 8)},
        ["str", b"bytes", 2 ** 70, None, {"k": [True, False]}],
    )]
    for _ in range(400):
        frame = bytearray(seeds[rng.integers(len(seeds))])
        for _ in range(rng.integers(1, 4)):
            frame[rng.integers(len(frame))] ^= 1 << rng.integers(8)
        if rng.random() < 0.3:
            frame = frame[:rng.integers(len(frame) + 1)]
        try:
            BIN_CODEC.decode(bytes(frame))
        except CodecError:
            pass  # the contract: typed error, nothing else


@pytest.mark.chaos
def test_fuzz_random_bytes_never_crash_decoder():
    rng = np.random.default_rng(7)
    for _ in range(300):
        blob = rng.integers(0, 256, size=rng.integers(0, 128),
                            dtype=np.uint8).tobytes()
        try:
            BIN_CODEC.decode(blob)
        except CodecError:
            pass
