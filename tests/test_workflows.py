"""Workflow archetypes end-to-end: ensemble executor, active-learning
optimization loop (Sec. 3.2), calibrate->forecast cascade (Sec. 3.3)."""
import time

import jax
import numpy as np
import pytest

from repro.core import (Bundler, EnsembleExecutor, MerlinRuntime, Step,
                        StudySpec, WorkerPool)
from repro.core.active import (OptimizationLoop, propose_batch,
                               train_surrogate)
from repro.core.cascade import CalibrationCascade
from repro.core.hierarchy import HierarchyCfg
from repro.sim import jag_simulate, seir_simulate


def test_ensemble_executor_fused_bundles(tmp_path):
    b = Bundler(str(tmp_path))
    ex = EnsembleExecutor(jag_simulate, b)
    samples = np.random.default_rng(0).random((24, 5)).astype(np.float32)
    ex.run_bundle(0, 12, samples[:12])
    ex.run_bundle(12, 24, samples[12:])
    data = b.load_all()
    assert data["yield"].shape == (24,)
    assert data["images"].shape == (24, 4, 16, 16)
    assert ex.stats["samples"] == 24


def test_surrogate_learns_smooth_function():
    rng = np.random.default_rng(0)
    X = rng.random((256, 3)).astype(np.float32)
    y = np.sin(3 * X[:, 0]) + X[:, 1] ** 2
    y = (y - y.min()) / (y.max() - y.min())
    sur = train_surrogate(X, y, steps=400)
    mu, sd = sur.predict(X)
    assert float(np.mean((mu - y) ** 2)) < 0.02
    assert sd.shape == mu.shape


def test_propose_batch_three_way_split():
    rng = np.random.default_rng(0)
    X = rng.random((64, 5)).astype(np.float32)
    y = -np.sum((X - 0.6) ** 2, axis=1)
    sur = train_surrogate(X, (y - y.min()) / (y.max() - y.min()), steps=200)
    Xn = propose_batch(sur, None, X, y, n=30, dims=5)
    assert Xn.shape == (30, 5)
    assert Xn.min() >= 0 and Xn.max() <= 1
    best = X[np.argmax(y)]
    # a third of points cluster near the best observed design
    d = np.linalg.norm(Xn[:10] - best, axis=1)
    assert np.median(d) < 0.25


def test_optimization_loop_improves(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path),
                       hierarchy=HierarchyCfg(max_fanout=4, bundle=12))
    loop = OptimizationLoop(rt, jag_simulate, batch_per_iter=36, max_iters=3,
                            seed=1)
    with WorkerPool(rt, n_workers=2) as pool:
        loop.start()
        t0 = time.time()
        while len(loop.history) < 3 and time.time() - t0 < 240:
            time.sleep(0.2)
        pool.drain(timeout=60)
    assert len(loop.history) == 3
    assert loop.history[-1]["best"] >= loop.history[0]["best"]
    assert loop.history[-1]["n"] > loop.history[0]["n"]  # data accumulates


def test_cascade_calibrates_then_forecasts(tmp_path):
    rng = np.random.default_rng(0)
    truth = {}
    for m in ["NYC", "SEA"]:
        u = rng.uniform(0.3, 0.7, 6).astype(np.float32)
        truth[m] = np.asarray(jax.jit(seir_simulate)(
            u, jax.random.PRNGKey(1))["daily_cases"])
    rt = MerlinRuntime(workspace=str(tmp_path),
                       hierarchy=HierarchyCfg(max_fanout=4, bundle=16))
    casc = CalibrationCascade(rt, seir_simulate, truth, n_calib=32,
                              n_posterior=8)
    with WorkerPool(rt, n_workers=2) as pool:
        casc.start()
        t0 = time.time()
        while time.time() - t0 < 240:
            if all(len(casc.results.get(m, {})) >= 4 for m in truth):
                break
            time.sleep(0.2)
        pool.drain(timeout=60)
    for m in truth:
        r = casc.results[m]
        assert "posterior_rmse" in r
        # NPIs reduce the peak monotonically
        assert r["strong_npi"]["peak_median"] <= \
            r["baseline"]["peak_median"] + 1e-6


def test_serving_engine_generates(tmp_path):
    from repro.configs import registry
    from repro.models import lm
    from repro.serve.engine import ServeEngine
    cfg = registry.reduced_config("granite-3-8b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_len=64)
    import jax.numpy as jnp
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    out = eng.generate(toks, n_new=6)
    assert out.shape == (2, 6)
    assert eng.stats["decode_tokens"] == 10
