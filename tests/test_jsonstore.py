"""jsonstore: the one shared-JSON-on-a-directory implementation (atomic
save, tolerant load, locked read-modify-write, signature-cached reload)."""
import json
import os
import threading

import pytest

from repro.core import jsonstore


def test_save_and_load_roundtrip(tmp_path):
    p = str(tmp_path / "doc.json")
    assert jsonstore.save_json(p, {"a": 1})
    assert jsonstore.load_json(p) == {"a": 1}
    assert not any(n.startswith(".tmp-") for n in os.listdir(tmp_path))


def test_load_missing_and_torn(tmp_path):
    assert jsonstore.load_json(str(tmp_path / "nope.json")) is None
    assert jsonstore.load_json(str(tmp_path / "nope.json"), default={}) == {}
    torn = str(tmp_path / "torn.json")
    open(torn, "w").write('{"a": ')
    assert jsonstore.load_json(torn, default="d") == "d"


def test_save_strict_raises(tmp_path):
    bad = str(tmp_path / "f.json" / "nested.json")  # parent is a file
    open(str(tmp_path / "f.json"), "w").write("{}")
    assert jsonstore.save_json(bad, {}) is False
    with pytest.raises(OSError):
        jsonstore.save_json(bad, {}, strict=True)


def test_update_json_merges_under_contention(tmp_path):
    p = str(tmp_path / "shared.json")
    n_threads, per_thread = 8, 25

    def writer(tid):
        for i in range(per_thread):
            jsonstore.update_json(
                p, lambda doc: doc.update({f"{tid}:{i}": 1}))

    ts = [threading.Thread(target=writer, args=(t,)) for t in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    doc = jsonstore.load_json(p)
    assert len(doc) == n_threads * per_thread  # no dropped merges


def test_update_json_replacement_return(tmp_path):
    p = str(tmp_path / "r.json")
    jsonstore.save_json(p, {"old": 1})
    out = jsonstore.update_json(p, lambda doc: {"new": 2})
    assert out == {"new": 2}
    assert jsonstore.load_json(p) == {"new": 2}


def test_shared_config_signature_cache(tmp_path):
    p = str(tmp_path / "cfg.json")
    cfg = jsonstore.SharedJsonConfig(p)
    assert cfg.load_if_changed() is None  # missing file
    jsonstore.save_json(p, {"q": 5})
    assert cfg.load_if_changed() == {"q": 5}
    assert cfg.load_if_changed() is None  # unchanged -> one stat, no read
    # an update through the same handle does not re-apply its own write
    cfg.update(lambda doc: doc.update({"r": 6}))
    assert cfg.load_if_changed() is None
    # ...but a foreign write is picked up
    other = jsonstore.SharedJsonConfig(p)
    other.update(lambda doc: doc.update({"s": 7}))
    assert cfg.load_if_changed() == {"q": 5, "r": 6, "s": 7}
    cfg.forget()
    assert cfg.load_if_changed() is not None  # forced re-read


def test_file_signature(tmp_path):
    p = str(tmp_path / "x.json")
    assert jsonstore.file_signature(p) is None
    jsonstore.save_json(p, {})
    sig = jsonstore.file_signature(p)
    assert sig is not None and len(sig) == 2
