"""Named-queue routing, batch leasing, retry accounting parity between the
broker backends, and cross-process crash-resume.

The ``broker`` fixture runs every test over four backends: the two local
ones AND a NetBroker client against a real-socket BrokerServer fronting
each of them, so routing isolation, retry parity, and redelivery semantics
are verified over the wire too (``-m 'not net'`` deselects the socket
variants in restricted sandboxes)."""
import os
import time

import numpy as np
import pytest

from repro.core import Bundler, MerlinRuntime, Step, StudySpec, WorkerPool
from repro.core.hierarchy import HierarchyCfg
from repro.core.netbroker import BrokerServer, NetBroker
from repro.core.queue import (PRIORITY_GEN, PRIORITY_REAL, FileBroker,
                              InMemoryBroker, new_task)

NET = pytest.mark.net
BROKER_PARAMS = ["mem", "file",
                 pytest.param("net-mem", marks=NET),
                 pytest.param("net-file", marks=NET)]


def _make_backend(param, tmp_path, visibility_timeout=0.2):
    if param.endswith("mem"):
        return InMemoryBroker(visibility_timeout=visibility_timeout)
    return FileBroker(str(tmp_path / "q"),
                      visibility_timeout=visibility_timeout)


@pytest.fixture(params=BROKER_PARAMS)
def broker(request, tmp_path):
    backend = _make_backend(request.param, tmp_path)
    if not request.param.startswith("net"):
        yield backend
        return
    server = BrokerServer(backend).start()
    client = NetBroker(server.address, reconnect_timeout=2.0)
    yield client
    client.close()
    server.stop()


# ---------------------------------------------------------------------------
# routing / isolation
# ---------------------------------------------------------------------------

def test_named_queue_isolation(broker):
    """A task on queue 'sims' is never delivered to an 'ml' subscriber."""
    broker.put(new_task("real", {"who": "sim"}, queue="sims"))
    broker.put(new_task("real", {"who": "ml"}, queue="ml"))
    assert broker.get(timeout=0.1, queues=("nosuch",)) is None
    lease = broker.get(timeout=1, queues=("ml",))
    assert lease.task.payload["who"] == "ml"
    assert lease.task.queue == "ml"
    broker.ack(lease.tag)
    # the sims task is still there, untouched by the ml subscriber
    assert broker.get(timeout=0.1, queues=("ml",)) is None
    lease = broker.get(timeout=1, queues=("sims",))
    assert lease.task.payload["who"] == "sim"


def test_subscribe_all_sees_every_queue(broker):
    for q in ("a", "b", "c"):
        broker.put(new_task("real", {"q": q}, queue=q))
    got = {broker.get(timeout=1).task.payload["q"] for _ in range(3)}
    assert got == {"a", "b", "c"}


def test_priority_order_across_queues(broker):
    """Real outranks gen even when they live on different named queues."""
    broker.put(new_task("gen", {"i": "g1"}, priority=PRIORITY_GEN, queue="gen"))
    broker.put(new_task("real", {"i": "r1"}, priority=PRIORITY_REAL, queue="real"))
    broker.put(new_task("gen", {"i": "g2"}, priority=PRIORITY_GEN, queue="gen"))
    broker.put(new_task("real", {"i": "r2"}, priority=PRIORITY_REAL, queue="real"))
    kinds = [broker.get(timeout=1).task.kind for _ in range(4)]
    assert kinds == ["real", "real", "gen", "gen"]


def test_string_queue_selector(broker):
    broker.put(new_task("real", {}, queue="only"))
    assert broker.get(timeout=1, queues="only") is not None


def test_qsize_per_queue(broker):
    for _ in range(3):
        broker.put(new_task("real", {}, queue="a"))
    broker.put(new_task("real", {}, queue="b"))
    assert broker.qsize(("a",)) == 3
    assert broker.qsize(("b",)) == 1
    assert broker.qsize() == 4
    assert set(broker.queue_names()) == {"a", "b"}


# ---------------------------------------------------------------------------
# batch operations
# ---------------------------------------------------------------------------

def test_get_many_ack_many(broker):
    broker.put_many([new_task("real", {"i": i}) for i in range(10)])
    leases = broker.get_many(4, timeout=1)
    assert [l.task.payload["i"] for l in leases] == [0, 1, 2, 3]
    broker.ack_many([l.tag for l in leases])
    rest = broker.get_many(100, timeout=1)
    assert [l.task.payload["i"] for l in rest] == [4, 5, 6, 7, 8, 9]
    broker.ack_many([l.tag for l in rest])
    assert broker.idle()
    assert broker.stats["acked"] == 10


def test_get_many_returns_partial_not_empty(broker):
    broker.put(new_task("real", {}))
    leases = broker.get_many(8, timeout=1)
    assert len(leases) == 1
    assert broker.get_many(8, timeout=0.05) == []


# ---------------------------------------------------------------------------
# retry accounting parity (satellite: FileBroker.nack must bump retries)
# ---------------------------------------------------------------------------

def test_nack_increments_retries(broker):
    broker.put(new_task("real", {"x": 1}))
    lease = broker.get(timeout=1)
    assert lease.task.retries == 0
    broker.nack(lease.tag)
    lease2 = broker.get(timeout=1)
    assert lease2.task.retries == 1
    broker.nack(lease2.tag)
    lease3 = broker.get(timeout=1)
    assert lease3.task.retries == 2
    assert broker.stats["redelivered"] == 2


def test_lease_expiry_increments_retries(broker):
    broker.put(new_task("real", {"x": 1}))
    lease = broker.get(timeout=1)
    assert broker.get(timeout=0.05) is None  # leased, invisible
    time.sleep(0.35)  # > visibility_timeout
    lease2 = broker.get(timeout=1)
    assert lease2 is not None
    assert lease2.task.retries == 1
    assert broker.stats["redelivered"] >= 1


def test_filebroker_stats(tmp_path):
    b = FileBroker(str(tmp_path / "q"))
    b.put_many([new_task("real", {"i": i}) for i in range(3)])
    assert b.stats["enqueued"] == 3
    lease = b.get(timeout=1)
    b.nack(lease.tag)
    assert b.stats["redelivered"] == 1
    for _ in range(3):
        b.ack(b.get(timeout=1).tag)
    assert b.stats["acked"] == 3
    assert b.idle()


def test_filebroker_tmp_leak_sweep(tmp_path):
    """A crashed producer's temp file is reaped by the expiry sweep."""
    b = FileBroker(str(tmp_path / "q"), visibility_timeout=0.1)
    b.put(new_task("real", {}, queue="sims"))
    leak = os.path.join(b._qdir("sims"), ".tmp-deadbeef")
    with open(leak, "w") as f:
        f.write("{partial")
    old = time.time() - 120
    os.utime(leak, (old, old))
    b._requeue_expired()
    assert not os.path.exists(leak)
    # the real pending task is unaffected
    assert b.get(timeout=1) is not None


def test_filebroker_shared_instance_thread_safety(tmp_path):
    """WorkerPool threads share ONE FileBroker: the cached index must not
    race (peek-then-pop on the heaps) under concurrent get_many."""
    import threading
    b = FileBroker(str(tmp_path / "q"))
    n = 200
    b.put_many([new_task("real", {"i": i}) for i in range(n)])
    got, errors, lock = [], [], threading.Lock()

    def worker():
        try:
            while True:
                leases = b.get_many(4, timeout=0.2)
                if not leases:
                    return
                b.ack_many([l.tag for l in leases])
                with lock:
                    got.extend(l.task.payload["i"] for l in leases)
        except Exception as e:  # pragma: no cover - the bug under test
            errors.append(e)

    ts = [threading.Thread(target=worker) for _ in range(8)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert errors == []
    assert sorted(got) == list(range(n))


def test_filebroker_poison_file_dead_letters(tmp_path):
    """An unparseable task file is quarantined, not redelivered forever."""
    b = FileBroker(str(tmp_path / "q"), visibility_timeout=0.1)
    b.put(new_task("real", {"ok": 1}))
    # a corrupt file sorted FIRST in the queue dir
    with open(os.path.join(b._qdir("default"), "000-000000000000-x.json"), "w") as f:
        f.write("{not json")
    lease = b.get(timeout=1)
    assert lease.task.payload == {"ok": 1}
    b.ack(lease.tag)
    # the next dry poll rescans the dir, finds the poison, quarantines it
    assert b.get(timeout=0.1) is None
    assert b.idle()  # poison is in dead/, not pinning qsize/inflight
    dead = os.listdir(os.path.join(str(tmp_path / "q"), "dead"))
    assert len(dead) == 1 and dead[0].endswith("x.json")


def test_attach_with_different_hierarchy_cfg(tmp_path):
    """A resumed runtime must take the stage's bundle size from the task
    payload, not its own (possibly different) HierarchyCfg."""
    ws = str(tmp_path / "ws")
    qdir = str(tmp_path / "q")
    rt1 = MerlinRuntime(broker=FileBroker(qdir), workspace=ws,
                        hierarchy=HierarchyCfg(max_fanout=4, bundle=10))
    spec = StudySpec(name="cfg", steps=[Step(name="sim", fn="sim")])
    sid = rt1.run(spec, np.zeros((40, 1), np.float32))
    del rt1
    # attaching runtime uses the DEFAULT config (bundle=1)
    rt2 = MerlinRuntime(broker=FileBroker(qdir), workspace=ws)
    done = []
    # record per sub-range: the engine may fuse contiguous bundles into
    # one invocation, but sub_ranges carries the payload-sized spans
    rt2.register("sim", lambda ctx: done.extend(
        tuple(r) for r in ctx.sub_ranges))
    rt2.attach(sid)
    with WorkerPool(rt2, n_workers=2):
        assert rt2.wait(sid, timeout=60)
    assert sorted(done) == [(i, i + 10) for i in range(0, 40, 10)]


def test_filebroker_cross_instance_routing(tmp_path):
    """Two broker objects on one dir = two processes sharing named queues."""
    b1 = FileBroker(str(tmp_path / "q"))
    b2 = FileBroker(str(tmp_path / "q"), rescan_interval=0.0)
    b1.put(new_task("real", {"from": "b1"}, queue="sims"))
    assert b2.get(timeout=0.3, queues=("ml",)) is None
    lease = b2.get(timeout=1, queues=("sims",))
    assert lease.task.payload["from"] == "b1"
    b2.ack(lease.tag)
    assert b1.idle()


# ---------------------------------------------------------------------------
# worker routing + crash-resume through a shared FileBroker
# ---------------------------------------------------------------------------

def test_worker_pool_respects_queue_subscription(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path / "ws"),
                       hierarchy=HierarchyCfg(max_fanout=4, bundle=4))
    done = []
    rt.register("sim", lambda ctx: done.append((ctx.lo, ctx.hi)))
    spec = StudySpec(name="iso", steps=[Step(name="sim", fn="sim")])
    # a pool pinned to an unrelated queue must never run anything
    with WorkerPool(rt, n_workers=2, queues=("elsewhere",)) as pool:
        sid = rt.run(spec, np.zeros((16, 1), np.float32))
        assert not rt.wait(sid, timeout=1.0)
        assert done == []
    # a pool on the study's real+gen queues drains it (batch leasing may
    # coalesce contiguous leaf tasks into fewer, larger step invocations)
    with WorkerPool(rt, n_workers=2,
                    queues=(rt.real_queue, rt.gen_queue), batch=4) as pool:
        assert rt.wait(sid, timeout=60)
    covered = sorted(i for lo, hi in done for i in range(lo, hi))
    assert covered == list(range(16))


def test_filebroker_crash_resume_two_runtimes(tmp_path):
    """Sec. 3 surge/restart: runtime A enqueues and 'crashes' mid-study; a
    fresh runtime B in a new 'allocation' attaches to the same workspace +
    broker dir and finishes, including leases A abandoned."""
    ws = str(tmp_path / "ws")
    qdir = str(tmp_path / "q")
    hcfg = HierarchyCfg(max_fanout=4, bundle=4)
    results = Bundler(str(tmp_path / "res"))

    rt1 = MerlinRuntime(broker=FileBroker(qdir, visibility_timeout=0.4),
                        workspace=ws, hierarchy=hcfg)
    spec = StudySpec(name="resume", steps=[Step(name="sim", fn="sim")])
    samples = np.arange(32, dtype=np.float32).reshape(32, 1)
    sid = rt1.run(spec, samples)
    # "crash": claim the root gen task and die without acking
    abandoned = rt1.broker.get(timeout=1)
    assert abandoned is not None
    del rt1

    rt2 = MerlinRuntime(broker=FileBroker(qdir, visibility_timeout=5.0),
                        workspace=ws, hierarchy=hcfg)
    rt2.register("sim", lambda ctx: results.write_bundle(
        ctx.lo, ctx.hi, {"y": ctx.sample_block[:, 0]}))
    rt2.attach(sid)
    with WorkerPool(rt2, n_workers=2) as pool:
        assert rt2.wait(sid, timeout=90)
        pool.drain(timeout=30)
    data = results.load_all()
    assert np.allclose(np.sort(data["y"]), np.arange(32))
    # the abandoned lease was redelivered with its retry recorded
    assert rt2.broker.stats["redelivered"] >= 1


@pytest.mark.net
@pytest.mark.parametrize("backend_kind", ["mem", "file"])
def test_crash_resume_two_runtimes_over_wire(tmp_path, backend_kind):
    """The paper's actual deployment: the queue lives in a broker SERVER
    process, not on a shared filesystem.  Runtime A enqueues over TCP and
    'crashes' mid-study holding a lease; runtime B connects with its own
    client, attaches to the workspace, and finishes — including A's
    abandoned lease, which expires server-side and redelivers."""
    ws = str(tmp_path / "ws")
    backend = _make_backend(backend_kind, tmp_path, visibility_timeout=0.8)
    server = BrokerServer(backend).start()
    hcfg = HierarchyCfg(max_fanout=4, bundle=4)
    results = Bundler(str(tmp_path / "res"))
    try:
        rt1 = MerlinRuntime(broker=NetBroker(server.address), workspace=ws,
                            hierarchy=hcfg)
        spec = StudySpec(name="netresume", steps=[Step(name="sim", fn="sim")])
        samples = np.arange(32, dtype=np.float32).reshape(32, 1)
        sid = rt1.run(spec, samples)
        # "crash": claim the root gen task over the wire, die without acking
        abandoned = rt1.broker.get(timeout=1)
        assert abandoned is not None
        rt1.broker.close()
        del rt1

        rt2 = MerlinRuntime(broker=NetBroker(server.address), workspace=ws,
                            hierarchy=hcfg)
        rt2.register("sim", lambda ctx: results.write_bundle(
            ctx.lo, ctx.hi, {"y": ctx.sample_block[:, 0]}))
        rt2.attach(sid)
        with WorkerPool(rt2, n_workers=2) as pool:
            assert rt2.wait(sid, timeout=90)
            pool.drain(timeout=30)
        data = results.load_all()
        assert np.allclose(np.sort(data["y"]), np.arange(32))
        assert rt2.broker.stats["redelivered"] >= 1
        rt2.broker.close()
    finally:
        server.stop()


@pytest.mark.net
def test_server_killed_mid_lease_reconnect_and_reack(tmp_path):
    """Kill the broker SERVER while a client holds a lease.  With a durable
    (FileBroker) backend the claim survives the server process: a restarted
    server on the same address serves the same queue, the client transparently
    reconnects, and its ack of the pre-crash lease still lands (tags are
    backend state, acks are idempotent)."""
    root = str(tmp_path / "q")
    server = BrokerServer(FileBroker(root, visibility_timeout=30.0)).start()
    port = server.port
    nb = NetBroker(server.address, reconnect_timeout=8.0)
    try:
        nb.put(new_task("real", {"x": 1}, queue="sims"))
        lease = nb.get(timeout=1)
        assert lease is not None
        server.stop()  # the server dies mid-lease

        # restart on the SAME port + queue dir (a new broker allocation)
        server = BrokerServer(FileBroker(root, visibility_timeout=30.0),
                              port=port).start()
        nb.ack(lease.tag)  # reconnects under the hood; ack lands
        assert nb.idle()
        assert nb.stats["net_reconnects"] >= 1
    finally:
        nb.close()
        server.stop()


@pytest.mark.net
def test_worker_pool_survives_broker_restart(tmp_path):
    """Workers polling a NetBroker must ride out a server restart: back off
    on BrokerUnavailable, reconnect, resubscribe, and finish the study."""
    ws = str(tmp_path / "ws")
    root = str(tmp_path / "q")
    server = BrokerServer(FileBroker(root, visibility_timeout=1.0)).start()
    port = server.port
    hcfg = HierarchyCfg(max_fanout=4, bundle=4)
    rt = MerlinRuntime(broker=NetBroker(server.address, reconnect_timeout=1.0,
                                        block_chunk=0.2),
                       workspace=ws, hierarchy=hcfg)
    done = []
    rt.register("sim", lambda ctx: done.append((ctx.lo, ctx.hi)))
    spec = StudySpec(name="restart", steps=[Step(name="sim", fn="sim")])
    try:
        with WorkerPool(rt, n_workers=2, batch=2) as pool:
            sid = rt.run(spec, np.zeros((32, 1), np.float32))
            time.sleep(0.15)          # let some leases get claimed
            server.stop()             # broker outage mid-study
            time.sleep(0.5)           # workers see BrokerUnavailable
            server = BrokerServer(FileBroker(root, visibility_timeout=1.0),
                                  port=port).start()
            assert rt.wait(sid, timeout=90)
            assert pool.drain(timeout=30)
        covered = sorted(i for lo, hi in done for i in range(lo, hi))
        assert covered == list(range(32))  # every sample ran exactly once
    finally:
        rt.broker.close()
        server.stop()
