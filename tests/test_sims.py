"""Simulator invariants (JAG-like ICF + SEIR epicast stand-in)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.sim import jag_simulate, jag_sample_inputs, seir_simulate
from repro.sim.jag import IMG, N_T, N_VIEWS


def test_jag_shapes_and_finiteness():
    out = jax.jit(jag_simulate)(jnp.full((5,), 0.5), jax.random.PRNGKey(0))
    assert out["burn_rate"].shape == (N_T,)
    assert out["images"].shape == (N_VIEWS, IMG, IMG)
    assert float(out["failed"]) == 0.0
    for k, v in out.items():
        assert bool(jnp.isfinite(v).all()), k


def test_jag_failure_region():
    # over-driven thin shell: scale ~ max, thickness ~ min
    u = jnp.array([0.999, 0.001, 0.5, 0.5, 0.5])
    out = jag_simulate(u, jax.random.PRNGKey(0))
    assert float(out["failed"]) == 1.0
    assert not bool(jnp.isfinite(out["yield"]))


@given(st.lists(st.floats(0, 1), min_size=5, max_size=5))
@settings(max_examples=30, deadline=None)
def test_jag_physics_monotonicities(u):
    u = jnp.array(u, jnp.float32)
    out = jag_simulate(u, jax.random.PRNGKey(0))
    # symmetric capsules outperform asymmetric ones at same drive
    u_sym = u.at[2].set(0.5).at[3].set(0.5)
    out_sym = jag_simulate(u_sym, jax.random.PRNGKey(0))
    if bool(jnp.isfinite(out["yield"])) and bool(jnp.isfinite(out_sym["yield"])):
        assert float(out_sym["yield"]) >= float(out["yield"]) - 1e-6 * float(
            out_sym["yield"])


def test_jag_vmap_consistency():
    u = jag_sample_inputs(jax.random.PRNGKey(1), 8)
    rngs = jax.vmap(jax.random.PRNGKey)(jnp.arange(8, dtype=jnp.uint32))
    batched = jax.vmap(jag_simulate)(u, rngs)
    single = jag_simulate(u[3], jax.random.PRNGKey(3))
    np.testing.assert_allclose(np.asarray(batched["yield"])[3],
                               np.asarray(single["yield"]), rtol=1e-6)


def test_seir_epidemic_properties():
    u = jnp.full((6,), 0.5)
    out = jax.jit(seir_simulate)(u, jax.random.PRNGKey(0))
    assert out["daily_cases"].shape == (60,)
    assert float(out["attack_rate"]) >= 0
    assert bool((out["daily_cases"] >= -1e-6).all())
    # stronger NPI compliance -> fewer total cases
    u_strong = u.at[4].set(1.0).at[5].set(0.0)  # max compliance, early start
    u_none = u.at[4].set(0.0)
    a_strong = float(seir_simulate(u_strong, jax.random.PRNGKey(0))["attack_rate"])
    a_none = float(seir_simulate(u_none, jax.random.PRNGKey(0))["attack_rate"])
    assert a_strong <= a_none


def test_seir_deterministic_given_key():
    u = jnp.full((6,), 0.4)
    a = seir_simulate(u, jax.random.PRNGKey(5))["daily_cases"]
    b = seir_simulate(u, jax.random.PRNGKey(5))["daily_cases"]
    assert np.array_equal(np.asarray(a), np.asarray(b))
