"""Resilience primitives: retries, speculative reissue, journal."""
import time

import numpy as np

from repro.core import Bundler, MerlinRuntime, Step, StudySpec, WorkerPool
from repro.core.hierarchy import HierarchyCfg
from repro.core.queue import InMemoryBroker, new_task
from repro.core.resilience import (BackoffPolicy, CircuitBreaker,
                                   CursorCrawler, RetryPolicy,
                                   SpeculativeReissuer, crawl_and_resubmit)


def test_retry_policy():
    t = new_task("real", {})
    p = RetryPolicy(max_retries=2)
    assert p.should_retry(t)
    t.retries = 2
    assert not p.should_retry(t)


def test_backoff_policy_exponential_capped_and_jittered():
    import random
    p = BackoffPolicy(base=0.1, cap=1.0, multiplier=2.0, jitter=0.0)
    assert p.delay(0) == 0.1
    assert p.delay(1) == 0.2
    assert p.delay(2) == 0.4
    assert p.delay(10) == 1.0  # capped
    assert p.delay(-3) == 0.1  # negative attempts clamp to the base
    # jitter only ever SHORTENS the delay (within [1-jitter, 1] x nominal)
    pj = BackoffPolicy(base=0.1, cap=1.0, jitter=0.5,
                       rng=random.Random(42))
    for a in range(8):
        nominal = BackoffPolicy(base=0.1, cap=1.0, jitter=0.0).delay(a)
        assert 0.5 * nominal <= pj.delay(a) <= nominal
    # seeded rng makes the schedule reproducible
    p1 = BackoffPolicy(jitter=0.25, rng=random.Random(7))
    p2 = BackoffPolicy(jitter=0.25, rng=random.Random(7))
    assert [p1.delay(a) for a in range(5)] == [p2.delay(a) for a in range(5)]


def test_circuit_breaker_state_machine():
    cb = CircuitBreaker(failure_threshold=2, reset_timeout=0.1)
    assert cb.state == CircuitBreaker.CLOSED and cb.allow()
    cb.record_failure()
    assert cb.state == CircuitBreaker.CLOSED  # below threshold
    cb.record_failure()
    assert cb.state == CircuitBreaker.OPEN and not cb.allow()
    time.sleep(0.12)  # reset window elapses -> half-open probe allowed
    assert cb.state == CircuitBreaker.HALF_OPEN and cb.allow()
    cb.record_failure()  # probe failed: straight back to open
    assert cb.state == CircuitBreaker.OPEN and not cb.allow()
    time.sleep(0.12)
    assert cb.allow()
    cb.record_success()  # probe succeeded: closed, counters cleared
    assert cb.state == CircuitBreaker.CLOSED
    cb.record_failure()
    assert cb.state == CircuitBreaker.CLOSED  # threshold counts from zero


def test_failed_attempt_retries_and_succeeds(tmp_path):
    """A step that fails once must re-execute (completion-marker idempotency,
    not attempt-marker)."""
    rt = MerlinRuntime(workspace=str(tmp_path / "ws"),
                       hierarchy=HierarchyCfg(max_fanout=4, bundle=2))
    b = Bundler(str(tmp_path / "res"))
    attempts = {}

    def flaky(ctx):
        n = attempts.setdefault(ctx.lo, 0)
        attempts[ctx.lo] = n + 1
        if n == 0 and (ctx.lo // 2) % 2 == 0:
            raise RuntimeError("first attempt dies")
        b.write_bundle(ctx.lo, ctx.hi, {"y": np.ones(ctx.hi - ctx.lo)})

    rt.register("flaky", flaky)
    spec = StudySpec(name="f", steps=[Step(name="flaky", fn="flaky")])
    with WorkerPool(rt, n_workers=3) as pool:
        sid = rt.run(spec, np.zeros((24, 1), np.float32))
        assert rt.wait(sid, timeout=60)
    assert len(b.crawl()[0]) == 24
    assert max(attempts.values()) == 2  # failures were retried exactly once


def test_speculative_reissue_first_finisher_wins(tmp_path):
    """Straggler mitigation: duplicate a stuck task; execution happens once."""
    broker = InMemoryBroker(visibility_timeout=30.0)
    rt = MerlinRuntime(broker=broker, workspace=str(tmp_path / "ws"),
                       hierarchy=HierarchyCfg(max_fanout=4, bundle=4))
    runs = []
    rt.register("sim", lambda ctx: runs.append(ctx.lo))
    spec = StudySpec(name="s", steps=[Step(name="sim", fn="sim")])
    sid = rt.run(spec, np.zeros((4, 1), np.float32))
    # take the single real task but DON'T ack (stuck straggler)
    gen_lease = broker.get(timeout=1)
    from repro.core import hierarchy as H
    # root covers one bundle -> already a real task
    assert gen_lease.task.kind == "real"
    reissuer = SpeculativeReissuer(broker, dup_after=0.05)
    time.sleep(0.1)
    assert reissuer.scan_once() == 1  # duplicate issued
    dup = broker.get(timeout=1)
    rt.execute_real(dup.task)
    broker.ack(dup.tag)
    # original straggler finally "finishes": no double execution
    rt.execute_real(gen_lease.task)
    broker.ack(gen_lease.tag)
    assert runs == [0]
    assert rt.study_done(sid)


def test_cursor_crawler_matches_full_crawl(tmp_path):
    """The incremental crawler resubmits the same missing ranges as the
    one-shot full crawl."""
    import numpy as np
    bundler = Bundler(str(tmp_path / "res"))
    for lo in (0, 8, 24):  # holes: [16, 24) and [32, 40)
        bundler.write_bundle(lo, lo + 8, {"y": np.ones(8)})
    full_broker, inc_broker = InMemoryBroker(), InMemoryBroker()
    tmpl = {"study": "s", "stage": 0, "combo": 0, "n_samples": 40,
            "real_queue": "sims"}
    n_missing_full, n_full = crawl_and_resubmit(
        Bundler(str(tmp_path / "res")), 40, full_broker, tmpl, bundle=8)
    crawler = CursorCrawler(bundler, expected_n=40)
    n_missing_inc, n_inc = crawler.sweep(inc_broker, tmpl, bundle=8)
    assert (n_missing_inc, n_inc) == (n_missing_full, n_full) == (16, 2)

    def drain_ranges(b):
        out = []
        while True:
            lease = b.get(timeout=0.1)
            if lease is None:
                return sorted(map(tuple, out))
            out.append(lease.task.payload["samples"])
            assert lease.task.queue == "sims"
            b.ack(lease.tag)
    assert drain_ranges(full_broker) == drain_ranges(inc_broker) \
        == [(16, 24), (32, 40)]


def test_cursor_crawler_is_incremental(tmp_path):
    """Subsequent sweeps only decompress NEW bundles and do not re-enqueue
    ranges resubmitted a sweep ago."""
    import numpy as np
    bundler = Bundler(str(tmp_path / "res"))
    bundler.write_bundle(0, 8, {"y": np.ones(8)})
    broker = InMemoryBroker()
    crawler = CursorCrawler(bundler, expected_n=24, resubmit_after=2)
    tmpl = {"real_queue": "sims"}
    assert crawler.sweep(broker, tmpl, bundle=8) == (16, 2)
    # a worker completes one missing range between sweeps
    bundler.write_bundle(8, 16, {"y": np.ones(8)})
    n_loads_before = len(bundler._file_cache)
    n_missing, n_tasks = crawler.sweep(broker, tmpl, bundle=8)
    assert n_missing == 8      # [16, 24) still missing
    assert n_tasks == 0        # resubmitted last sweep: cooldown holds
    assert len(bundler._file_cache) == n_loads_before + 1  # delta load only
    # after the cooldown the still-missing range goes out again
    n_missing, n_tasks = crawler.sweep(broker, tmpl, bundle=8)
    assert (n_missing, n_tasks) == (8, 1)
    assert crawler.present == set(range(16))


def test_cursor_crawler_cooldown_stable_for_unaligned_holes(tmp_path):
    """Chunk keys snap to the bundle grid, so a hole shrinking from one
    end keeps its remaining chunks' cooldown keys (no instant re-enqueue)."""
    import numpy as np
    bundler = Bundler(str(tmp_path / "res"))
    bundler.write_bundle(0, 4, {"y": np.ones(4)})   # hole: [4, 24)
    broker = InMemoryBroker()
    crawler = CursorCrawler(bundler, expected_n=24, resubmit_after=2)
    tmpl = {"real_queue": "sims"}
    n_missing, n_tasks = crawler.sweep(broker, tmpl, bundle=8)
    assert (n_missing, n_tasks) == (20, 3)  # (4,8), (8,16), (16,24)
    # the ragged head completes; the grid-aligned tail chunks keep their
    # keys and stay in cooldown instead of being reminted and re-enqueued
    bundler.write_bundle(4, 8, {"y": np.ones(4)})
    n_missing, n_tasks = crawler.sweep(broker, tmpl, bundle=8)
    assert (n_missing, n_tasks) == (16, 0)


def test_journal_survives_torn_writes(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path / "ws"))
    rt.journal.append({"ev": "a"})
    with open(rt.journal.path, "a") as f:
        f.write('{"ev": "torn')  # crashed writer
    rt.journal.append({"ev": "b"})
    evs = [e["ev"] for e in rt.journal.replay()]
    assert "a" in evs and "b" in evs
