"""Resilience primitives: retries, speculative reissue, journal."""
import time

import numpy as np

from repro.core import Bundler, MerlinRuntime, Step, StudySpec, WorkerPool
from repro.core.hierarchy import HierarchyCfg
from repro.core.queue import InMemoryBroker, new_task
from repro.core.resilience import RetryPolicy, SpeculativeReissuer


def test_retry_policy():
    t = new_task("real", {})
    p = RetryPolicy(max_retries=2)
    assert p.should_retry(t)
    t.retries = 2
    assert not p.should_retry(t)


def test_failed_attempt_retries_and_succeeds(tmp_path):
    """A step that fails once must re-execute (completion-marker idempotency,
    not attempt-marker)."""
    rt = MerlinRuntime(workspace=str(tmp_path / "ws"),
                       hierarchy=HierarchyCfg(max_fanout=4, bundle=2))
    b = Bundler(str(tmp_path / "res"))
    attempts = {}

    def flaky(ctx):
        n = attempts.setdefault(ctx.lo, 0)
        attempts[ctx.lo] = n + 1
        if n == 0 and (ctx.lo // 2) % 2 == 0:
            raise RuntimeError("first attempt dies")
        b.write_bundle(ctx.lo, ctx.hi, {"y": np.ones(ctx.hi - ctx.lo)})

    rt.register("flaky", flaky)
    spec = StudySpec(name="f", steps=[Step(name="flaky", fn="flaky")])
    with WorkerPool(rt, n_workers=3) as pool:
        sid = rt.run(spec, np.zeros((24, 1), np.float32))
        assert rt.wait(sid, timeout=60)
    assert len(b.crawl()[0]) == 24
    assert max(attempts.values()) == 2  # failures were retried exactly once


def test_speculative_reissue_first_finisher_wins(tmp_path):
    """Straggler mitigation: duplicate a stuck task; execution happens once."""
    broker = InMemoryBroker(visibility_timeout=30.0)
    rt = MerlinRuntime(broker=broker, workspace=str(tmp_path / "ws"),
                       hierarchy=HierarchyCfg(max_fanout=4, bundle=4))
    runs = []
    rt.register("sim", lambda ctx: runs.append(ctx.lo))
    spec = StudySpec(name="s", steps=[Step(name="sim", fn="sim")])
    sid = rt.run(spec, np.zeros((4, 1), np.float32))
    # take the single real task but DON'T ack (stuck straggler)
    gen_lease = broker.get(timeout=1)
    from repro.core import hierarchy as H
    # root covers one bundle -> already a real task
    assert gen_lease.task.kind == "real"
    reissuer = SpeculativeReissuer(broker, dup_after=0.05)
    time.sleep(0.1)
    assert reissuer.scan_once() == 1  # duplicate issued
    dup = broker.get(timeout=1)
    rt.execute_real(dup.task)
    broker.ack(dup.tag)
    # original straggler finally "finishes": no double execution
    rt.execute_real(gen_lease.task)
    broker.ack(gen_lease.tag)
    assert runs == [0]
    assert rt.study_done(sid)


def test_journal_survives_torn_writes(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path / "ws"))
    rt.journal.append({"ev": "a"})
    with open(rt.journal.path, "a") as f:
        f.write('{"ev": "torn')  # crashed writer
    rt.journal.append({"ev": "b"})
    evs = [e["ev"] for e in rt.journal.replay()]
    assert "a" in evs and "b" in evs
