"""Dry-run machinery: HLO collective parsing (pure), plus one real
lower+compile cell in a 512-device subprocess (slow, but it is the
deliverable)."""
import json
import os
import subprocess
import sys

import pytest

# importing dryrun sets XLA_FLAGS=--xla_force_host_platform_device_count=512
# in THIS process (it must, before jax init, for its own `python -m` use).
# Restore the env around the import: the suite's contract (conftest.py) is
# that in-process tests see ONE device — leaking 512 would silently flip
# every later-initializing jax test (e.g. the ensemble auto-mesh) into a
# forced-multi-device process.
_saved_xla_flags = os.environ.get("XLA_FLAGS")
from repro.launch.dryrun import _shape_bytes, collective_bytes  # noqa: E402

if _saved_xla_flags is None:
    os.environ.pop("XLA_FLAGS", None)
else:
    os.environ["XLA_FLAGS"] = _saved_xla_flags

HLO = """
ENTRY main {
  %p = f32[2048,512]{1,0} parameter(0)
  %ar = f32[2048,512]{1,0} all-reduce(f32[2048,512]{1,0} %p), replica_groups={}
  %ag = bf16[64,128]{1,0} all-gather(bf16[32,128]{1,0} %x), dimensions={0}
  %rs = f32[16]{0} reduce-scatter(f32[64]{0} %y), dimensions={0}
  %cp = u8[10]{0} collective-permute(u8[10]{0} %z), source_target_pairs={{0,1}}
  %dot = f32[4,4]{1,0} dot(f32[4,8]{1,0} %a, f32[8,4]{1,0} %b)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[2048,512]") == 2048 * 512 * 4
    assert _shape_bytes("bf16[3]") == 6
    assert _shape_bytes("pred[]") == 1
    assert _shape_bytes("token[]") == 0


def test_collective_bytes_parses_operands():
    c = collective_bytes(HLO)
    assert c["all-reduce"] == 2048 * 512 * 4
    assert c["all-gather"] == 32 * 128 * 2
    assert c["reduce-scatter"] == 64 * 4
    assert c["collective-permute"] == 10
    assert c["all-to-all"] == 0
    assert c["count"] == 4


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """One real (arch x shape) cell through the 512-device dry-run."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "jag-surrogate", "--shape", "train_4k", "--out",
         "/tmp/dryrun_test.json"],
        env=env, capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    res = json.load(open("/tmp/dryrun_test.json"))[0]
    assert res["ok"]
    assert res["chips"] == 256
    assert res["flops"] > 0
    assert res["memory"]["temp_bytes"] > 0
    assert res["reconstructed"]["flops"] > res["flops"] * 0.5
