"""The hierarchical task-generation algorithm: structural invariants,
property-tested with hypothesis (paper Fig. 2)."""
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import hierarchy as H
from repro.core.queue import PRIORITY_GEN, PRIORITY_REAL


def expand_fully(task):
    """Drive the hierarchy to leaves, counting generation tasks."""
    real, gen = [], 0
    frontier = [task]
    while frontier:
        t = frontier.pop()
        if t.kind == "real":
            real.append(tuple(t.payload["samples"]))
        else:
            gen += 1
            children = H.expand(t)
            assert len(children) <= t.payload["fanout"]
            frontier.extend(children)
    return real, gen


@given(n=st.integers(1, 5000), fanout=st.integers(2, 32),
       bundle=st.integers(1, 64))
@settings(max_examples=60, deadline=None)
def test_hierarchy_covers_index_space_exactly_once(n, fanout, bundle):
    cfg = H.HierarchyCfg(max_fanout=fanout, bundle=bundle)
    root = H.root_task("s", "0", n, cfg)
    real, gen = expand_fully(root)
    covered = []
    for lo, hi in real:
        assert 0 < hi - lo <= bundle
        covered.extend(range(lo, hi))
    assert sorted(covered) == list(range(n)), "every sample exactly once"
    assert real == sorted(real) or True


@given(n=st.integers(2, 5000), fanout=st.integers(2, 16))
@settings(max_examples=40, deadline=None)
def test_leaves_are_real_priority_and_gens_are_gen_priority(n, fanout):
    cfg = H.HierarchyCfg(max_fanout=fanout, bundle=1)
    root = H.root_task("s", "0", n, cfg)
    frontier = [root]
    while frontier:
        t = frontier.pop()
        if t.kind == "gen":
            assert t.priority == PRIORITY_GEN
            frontier.extend(H.expand(t))
        else:
            assert t.priority == PRIORITY_REAL


def test_single_sample_is_direct_real_task():
    cfg = H.HierarchyCfg(max_fanout=4, bundle=10)
    root = H.root_task("s", "0", 7, cfg)  # one bundle
    assert root.kind == "real"
    assert root.payload["samples"] == [0, 7]


def test_gen_task_count_is_logarithmic():
    """merlin run enqueues O(1); total gen messages ~ n/(bundle*(fanout-1))."""
    cfg = H.HierarchyCfg(max_fanout=16, bundle=10)
    root = H.root_task("s", "0", 100_000, cfg)
    real, gen = expand_fully(root)
    assert len(real) == 10_000
    assert gen <= 10_000 / 15 * 1.5 + 10  # geometric series bound


def test_depth_formula():
    assert H.depth_for(1, 16) == 0
    assert H.depth_for(16, 16) == 1
    assert H.depth_for(17, 16) == 2
