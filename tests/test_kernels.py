"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles across
shape/dtype sweeps, plus chunked-vs-sequential oracle equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.wkv6_scan import wkv6_scan

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def rk(*i):
    return jax.random.PRNGKey(sum((x + 1) * 7919 ** n for n, x in enumerate(i)))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,T,H,KV,D", [
    (1, 64, 64, 4, 4, 32),     # MHA square
    (2, 96, 96, 6, 2, 32),     # GQA, non-pow2 seq
    (1, 33, 70, 4, 1, 16),     # MQA, ragged cross shapes
    (2, 128, 128, 8, 4, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_kernel_vs_naive(B, S, T, H, KV, D, dtype, causal):
    q = jax.random.normal(rk(B, S, 0), (B, S, H, D), dtype)
    k = jax.random.normal(rk(B, T, 1), (B, T, KV, D), dtype)
    v = jax.random.normal(rk(B, T, 2), (B, T, KV, D), dtype)
    want = ref.naive_attention(q, k, v, causal=causal)
    got = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype], rtol=1e-2)


@pytest.mark.parametrize("window,softcap", [(16, None), (None, 30.0),
                                            (24, 20.0)])
def test_flash_kernel_window_softcap(window, softcap):
    B, S, H, D = 2, 80, 4, 32
    q = jax.random.normal(rk(1, 1, 3), (B, S, H, D))
    k = jax.random.normal(rk(1, 2, 3), (B, S, 2, D))
    v = jax.random.normal(rk(1, 3, 3), (B, S, 2, D))
    want = ref.naive_attention(q, k, v, causal=True, window=window,
                               softcap_val=softcap)
    got = flash_attention(q, k, v, causal=True, window=window,
                          softcap_val=softcap, block_q=32, block_k=16,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=1e-3)


def test_flash_ref_is_flash_shaped():
    """The jnp fallback must agree with the naive oracle too (it's the
    production CPU path)."""
    B, S, H, D = 2, 100, 4, 32
    q = jax.random.normal(rk(2, 1, 1), (B, S, H, D))
    k = jax.random.normal(rk(2, 2, 1), (B, S, 4, D))
    v = jax.random.normal(rk(2, 3, 1), (B, S, 4, D))
    want = ref.naive_attention(q, k, v, causal=True)
    got = ref.flash_attention_ref(q, k, v, causal=True, block_k=37)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# SSD (Mamba2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 64, 3, 8, 16, 16), (1, 128, 2, 16, 32, 32), (2, 96, 4, 8, 8, 48),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_vs_sequential(B, S, H, P, N, chunk, dtype):
    x = jax.random.normal(rk(B, S, 4), (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(rk(B, S, 5), (B, S, H))) * 0.5
    A = -jnp.exp(jax.random.normal(rk(H, 0, 6), (H,)))
    B_ = jax.random.normal(rk(B, S, 7), (B, S, N), dtype)
    C = jax.random.normal(rk(B, S, 8), (B, S, N), dtype)
    want = ref.ssd_scan_ref(x, dt, A, B_, C)
    got = ssd_scan(x, dt, A, B_, C, chunk=chunk, interpret=True)
    scale = float(jnp.abs(want.astype(jnp.float32)).max()) + 1e-6
    err = float(jnp.abs(got.astype(jnp.float32)
                        - want.astype(jnp.float32)).max())
    assert err / scale < (1e-4 if dtype == jnp.float32 else 4e-2)


def test_ssd_decode_matches_scan_tail():
    B, S, H, P, N = 2, 32, 3, 8, 16
    x = jax.random.normal(rk(9, 9, 9), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(rk(9, 9, 8), (B, S, H))) * 0.5
    A = -jnp.exp(jax.random.normal(rk(9, 9, 7), (H,)))
    B_ = jax.random.normal(rk(9, 9, 6), (B, S, N))
    C = jax.random.normal(rk(9, 9, 5), (B, S, N))
    full = ref.ssd_scan_ref(x, dt, A, B_, C)
    # run first S-1 steps, then decode the last
    from repro.models.ssm import _final_state
    h = _final_state(x[:, :S - 1], dt[:, :S - 1], A, B_[:, :S - 1],
                     C[:, :S - 1])
    h2, y = ref.ssd_decode_ref(h, x[:, -1], dt[:, -1], A, B_[:, -1], C[:, -1])
    np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, -1]),
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# WKV6 (RWKV)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,D,chunk", [
    (2, 64, 3, 16, 16), (1, 128, 2, 32, 32), (2, 96, 4, 16, 48),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_kernel_vs_sequential(B, S, H, D, chunk, dtype):
    r = jax.random.normal(rk(B, S, 10), (B, S, H, D), dtype)
    k = jax.random.normal(rk(B, S, 11), (B, S, H, D), dtype)
    v = jax.random.normal(rk(B, S, 12), (B, S, H, D), dtype)
    w = jax.nn.sigmoid(jax.random.normal(rk(B, S, 13), (B, S, H, D)) + 2.0)
    u = jax.random.normal(rk(H, D, 14), (H, D)) * 0.1
    want = ref.wkv6_scan_ref(r, k, v, w.astype(dtype), u)
    got = wkv6_scan(r, k, v, w.astype(dtype), u, chunk=chunk, interpret=True)
    scale = float(jnp.abs(want.astype(jnp.float32)).max()) + 1e-6
    err = float(jnp.abs(got.astype(jnp.float32)
                        - want.astype(jnp.float32)).max())
    assert err / scale < (1e-4 if dtype == jnp.float32 else 4e-2)


def test_wkv6_decode_matches_scan_tail():
    B, S, H, D = 2, 24, 2, 16
    r = jax.random.normal(rk(20, 1, 1), (B, S, H, D))
    k = jax.random.normal(rk(20, 2, 1), (B, S, H, D))
    v = jax.random.normal(rk(20, 3, 1), (B, S, H, D))
    w = jax.nn.sigmoid(jax.random.normal(rk(20, 4, 1), (B, S, H, D)) + 2.0)
    u = jax.random.normal(rk(20, 5, 1), (H, D)) * 0.1
    full = ref.wkv6_scan_ref(r, k, v, w, u)
    from repro.models.rwkv import _wkv_final_state
    st = _wkv_final_state(k[:, :S - 1], v[:, :S - 1], w[:, :S - 1])
    _, y = ref.wkv6_decode_ref(st, r[:, -1], k[:, -1], v[:, -1], w[:, -1], u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, -1]),
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# property: online softmax == full softmax under arbitrary block splits
# ---------------------------------------------------------------------------

@given(bk=st.integers(1, 64), s=st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_flash_ref_block_size_invariance(bk, s):
    q = jax.random.normal(rk(3, 3, 3), (1, s, 2, 8))
    k = jax.random.normal(rk(3, 3, 4), (1, s, 2, 8))
    v = jax.random.normal(rk(3, 3, 5), (1, s, 2, 8))
    want = ref.naive_attention(q, k, v, causal=True)
    got = ref.flash_attention_ref(q, k, v, causal=True, block_k=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)
