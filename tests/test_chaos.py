"""Chaos suite: the diamond DAG end-to-end under seeded broker fault
injection (errors, delays, lost leases, dropped acks) with an
exactly-once completion audit.

The audit rule: raw execution counts may legally exceed one (redelivery
after a lost ack re-runs work; once-markers make it a no-op), so the
assertions target COMPLETION accounting — persisted node state, the
bundle/stage counters, and the journal — which must be exactly-once no
matter what the broker did.
"""
import numpy as np
import pytest

from repro.core.chaos import ChaosBroker, FlakyFn
from repro.core.hierarchy import HierarchyCfg
from repro.core.queue import InMemoryBroker
from repro.core.runtime import MerlinRuntime
from repro.core.spec import Step, StudySpec
from repro.core.worker import WorkerPool

pytestmark = pytest.mark.chaos

N_SAMPLES = 16
BUNDLE = 4  # -> 4 bundles per parallel stage instance


def _diamond_spec():
    # generous retry budgets: visibility-timeout redeliveries (lost
    # leases, dropped acks) increment task.retries, and this suite tests
    # exactly-once completion, not retry exhaustion (test_worker_policy)
    kw = dict(max_retries=50)
    return StudySpec(name="dia", steps=[
        Step(name="prep", fn="prep", **kw),
        Step(name="left", fn="left", depends=("prep",), **kw),
        Step(name="right", fn="right", depends=("prep",), **kw),
        Step(name="join", fn="join", depends=("left", "right"),
             over_samples=False, **kw)])


def _register(rt):
    for name in ("prep", "left", "right", "join"):
        rt.register(name, lambda ctx: None)


def _run_chaotic(tmp_path, chaos):
    rt = MerlinRuntime(broker=chaos, workspace=str(tmp_path),
                       hierarchy=HierarchyCfg(max_fanout=4, bundle=BUNDLE))
    _register(rt)
    with WorkerPool(rt, n_workers=3, batch=2) as pool:
        study = rt.run(_diamond_spec(),
                       samples=np.zeros((N_SAMPLES, 2), np.float32))
        assert rt.wait(study, timeout=120)
        pool.drain(timeout=60)
    return rt, study


def _audit_exactly_once(rt, study):
    """Completion must be exactly-once regardless of duplicate delivery."""
    state = rt.dag_state(study)["state"]
    assert len(state) == 4
    assert all(v["status"] == "done" for v in state.values())

    events = [e for e in rt.journal.replay() if e.get("study") == study]

    # exactly one stage_done per node instance
    stage_done = [(e["stage"], e["combo"]) for e in events
                  if e["ev"] == "stage_done"]
    assert sorted(stage_done) == [(0, 0), (1, 0), (2, 0), (3, 0)]

    # bundle_done: no duplicates, and each parallel stage's ranges tile
    # [0, N_SAMPLES) exactly; the single join stage completes once
    for stage in (0, 1, 2):
        ranges = sorted((e["lo"], e["hi"]) for e in events
                        if e["ev"] == "bundle_done" and e["stage"] == stage)
        assert len(ranges) == len(set(ranges)), f"duplicate bundle s{stage}"
        assert ranges[0][0] == 0 and ranges[-1][1] == N_SAMPLES
        for (_, hi), (lo, _) in zip(ranges, ranges[1:]):
            assert hi == lo, f"gap/overlap in stage {stage}: {ranges}"
        # the crash-safe counter agrees with the journal
        assert rt.counters.get(f"{study}/s{stage}/c0") == len(ranges) \
            == N_SAMPLES // BUNDLE
    assert rt.counters.get(f"{study}/s3/c0") == 1
    assert len([e for e in events if e["ev"] == "study_done"]) == 1


@pytest.mark.parametrize("seed", [1, 2, 3, 4, 5])
def test_diamond_survives_broker_chaos(tmp_path, seed):
    chaos = ChaosBroker(InMemoryBroker(visibility_timeout=1.0), seed=seed,
                        p_error=0.05, p_delay=0.10, max_delay_s=0.02,
                        p_lose_lease=0.05)
    rt, study = _run_chaotic(tmp_path, chaos)
    # the run must actually have suffered for the audit to mean anything
    assert chaos.faults["errors"] + chaos.faults["delays"] \
        + chaos.faults["lost_leases"] > 0
    _audit_exactly_once(rt, study)


def test_diamond_survives_dropped_acks(tmp_path):
    chaos = ChaosBroker(InMemoryBroker(visibility_timeout=1.0), seed=99,
                        p_drop_ack=0.35)
    rt, study = _run_chaotic(tmp_path, chaos)
    assert chaos.faults["dropped_acks"] > 0
    _audit_exactly_once(rt, study)
    # chaos counters surface through the proxied stats
    assert chaos.stats["chaos"]["dropped_acks"] > 0


def test_diamond_survives_partition_window(tmp_path):
    chaos = ChaosBroker(InMemoryBroker(visibility_timeout=1.0), seed=7)
    rt = MerlinRuntime(broker=chaos, workspace=str(tmp_path),
                       hierarchy=HierarchyCfg(max_fanout=4, bundle=BUNDLE))
    _register(rt)
    with WorkerPool(rt, n_workers=3, batch=2) as pool:
        study = rt.run(_diamond_spec(),
                       samples=np.zeros((N_SAMPLES, 2), np.float32))
        chaos.partition(0.5)  # total outage mid-study; workers back off
        assert rt.wait(study, timeout=120)
        pool.drain(timeout=60)
    assert chaos.faults["partition_rejections"] > 0
    _audit_exactly_once(rt, study)


def test_diamond_survives_flaky_fn_plus_broker_chaos(tmp_path):
    chaos = ChaosBroker(InMemoryBroker(visibility_timeout=1.0), seed=11,
                        p_error=0.03, p_lose_lease=0.03)
    rt = MerlinRuntime(broker=chaos, workspace=str(tmp_path),
                       hierarchy=HierarchyCfg(max_fanout=4, bundle=BUNDLE))
    flaky = FlakyFn(lambda ctx: None, p_fail=0.5, max_failures=2, seed=11)
    for name in ("prep", "left", "right", "join"):
        rt.register(name, flaky)
    with WorkerPool(rt, n_workers=3, batch=2) as pool:
        study = rt.run(_diamond_spec(),
                       samples=np.zeros((N_SAMPLES, 2), np.float32))
        assert rt.wait(study, timeout=120)
        pool.drain(timeout=60)
    assert flaky.injected > 0  # handler-level faults actually fired
    _audit_exactly_once(rt, study)
